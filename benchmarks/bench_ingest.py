"""Paper Fig. 5: writing the lineitem table into the database.

Compared paths:
  * engine_bulk_append — monetdb_append analogue (columnar adoption)
  * engine_insert_loop — per-row INSERT emulation (the socket-protocol
    pathology the paper attributes to client-server systems)
  * numpy_copy        — raw memcpy floor for the same bytes
"""

from __future__ import annotations

import numpy as np

from repro.core import startup
from repro.data import tpch

from .common import row, timeit


def run(sf: float = 0.01) -> list[str]:
    data = tpch.generate(sf)
    cols, types, scales = data["lineitem"]
    nbytes = sum(v.nbytes if hasattr(v, "nbytes") else len(v) * 8
                 for v in cols.values())
    out = []

    def bulk():
        db = startup()
        db.create_table("lineitem", cols, types=types, scales=scales)
    med, _ = timeit(bulk, hot=3)
    out.append(row("ingest_engine_bulk_append", med,
                   f"{nbytes / med / 1e6:.0f}MBps"))

    # per-row insert emulation (bounded row count for CPU sanity)
    n_rows = min(2000, len(next(iter(cols.values()))))
    def insert_loop():
        db = startup()
        db.create_table("lineitem",
                        {k: v[:1] for k, v in cols.items()},
                        types=types, scales=scales)
        for i in range(1, n_rows):
            db.append("lineitem", {k: v[i:i + 1] for k, v in cols.items()})
    med_loop, _ = timeit(insert_loop, hot=1, cold=0)
    per_row = med_loop / n_rows
    total_rows = len(next(iter(cols.values())))
    out.append(row("ingest_engine_insert_loop", per_row * total_rows,
                   f"extrapolated_from_{n_rows}_rows"))

    numeric = {k: v for k, v in cols.items() if hasattr(v, "dtype")
               and v.dtype != object}
    def copy():
        return {k: v.copy() for k, v in numeric.items()}
    med_cp, _ = timeit(copy, hot=5)
    out.append(row("ingest_numpy_copy_floor", med_cp,
                   f"{sum(v.nbytes for v in numeric.values())/med_cp/1e6:.0f}MBps"))
    return out
