"""Paper Fig. 5: writing the lineitem table into the database.

Compared paths:
  * engine_bulk_append — monetdb_append analogue (columnar adoption)
  * engine_insert_loop — per-row INSERT emulation (the socket-protocol
    pathology the paper attributes to client-server systems)
  * numpy_copy        — raw memcpy floor for the same bytes

Delta-store section (``BENCH_ingest.json``): three claims of the ingest
subsystem, measured with ``memory_budget`` set to a QUARTER of the table —

  * **budgeted streaming ingest** — ``db.ingest`` loads the 4x-budget
    table in morsel-pinned delta appends with tracked ``peak <= budget``
    (threshold compaction folds the tail as it grows);
  * **O(delta) appends** — appending one chunk to the big table costs
    about the same as appending it to a tiny one (no O(table) rewrite);
  * **epoch-keyed cache survival** — a repeat distributed scan after an
    append re-uploads roughly the delta tail's bytes, not the table.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import startup
from repro.data import tpch

from .common import row, timeit


def run(sf: float = 0.01) -> list[str]:
    data = tpch.generate(sf)
    cols, types, scales = data["lineitem"]
    nbytes = sum(v.nbytes if hasattr(v, "nbytes") else len(v) * 8
                 for v in cols.values())
    out = []

    def bulk():
        db = startup()
        db.create_table("lineitem", cols, types=types, scales=scales)
    med, _ = timeit(bulk, hot=3)
    out.append(row("ingest_engine_bulk_append", med,
                   f"{nbytes / med / 1e6:.0f}MBps"))

    # per-row insert emulation (bounded row count for CPU sanity)
    n_rows = min(2000, len(next(iter(cols.values()))))
    def insert_loop():
        db = startup()
        db.create_table("lineitem",
                        {k: v[:1] for k, v in cols.items()},
                        types=types, scales=scales)
        for i in range(1, n_rows):
            db.append("lineitem", {k: v[i:i + 1] for k, v in cols.items()})
    med_loop, _ = timeit(insert_loop, hot=1, cold=0)
    per_row = med_loop / n_rows
    total_rows = len(next(iter(cols.values())))
    out.append(row("ingest_engine_insert_loop", per_row * total_rows,
                   f"extrapolated_from_{n_rows}_rows"))

    numeric = {k: v for k, v in cols.items() if hasattr(v, "dtype")
               and v.dtype != object}
    def copy():
        return {k: v.copy() for k, v in numeric.items()}
    med_cp, _ = timeit(copy, hot=5)
    out.append(row("ingest_numpy_copy_floor", med_cp,
                   f"{sum(v.nbytes for v in numeric.values())/med_cp/1e6:.0f}MBps"))
    out.extend(_delta_section(cols, types, scales))
    return out


def _chunks(cols, n, step):
    for s in range(0, n, step):
        yield {k: v[s:s + step] for k, v in cols.items()}


def _delta_section(cols, types, scales) -> list[str]:
    out: list[str] = []
    n = len(next(iter(cols.values())))
    res: dict = {"rows": n}

    # encoded footprint: every column lands as a fixed-width array (VARCHAR
    # becomes int32 codes), which is what the budget actually bounds
    probe = startup()
    probe.create_table("li", {k: v[:2048] for k, v in cols.items()},
                       types=types, scales=scales)
    row_bytes = probe.table("li").nbytes // 2048
    probe.shutdown()
    table_bytes = row_bytes * n
    budget = table_bytes // 4
    res["table_bytes"] = int(table_bytes)
    res["memory_budget"] = int(budget)

    # -- budgeted streaming ingest: 4x-budget table, peak <= budget ----------
    db = startup(memory_budget=budget, delta_compact_fraction=0.5)
    t0 = time.perf_counter()
    got = db.ingest("lineitem", _chunks(cols, n, max(1, n // 16)),
                    types=types, scales=scales)
    dt = time.perf_counter() - t0
    assert got == n
    st = db.buffer_manager.stats
    res["ingest_seconds"] = dt
    res["tracked_peak"] = int(st.peak)
    res["compactions"] = int(st.compactions)
    res["peak_over_budget"] = round(st.peak / budget, 3)
    assert st.peak <= budget, (st.peak, budget)
    out.append(row("ingest_delta_streaming", dt,
                   f"peak {st.peak} <= budget {budget}, "
                   f"{res['compactions']} compactions"))

    # -- O(delta) append: same chunk, huge vs tiny table ---------------------
    chunk = {k: v[:1024] for k, v in cols.items()}
    def app_big():
        db.append("lineitem", chunk)
    med_big, _ = timeit(app_big, hot=5)
    small = startup(delta_compact_fraction=0.0)
    small.create_table("lineitem", {k: v[:10_000] for k, v in cols.items()},
                       types=types, scales=scales)
    def app_small():
        small.append("lineitem", chunk)
    med_small, _ = timeit(app_small, hot=5)
    small.shutdown()
    db.shutdown()
    res["append_seconds_big_table"] = med_big
    res["append_seconds_small_table"] = med_small
    res["append_cost_ratio_big_over_small"] = round(
        med_big / max(med_small, 1e-9), 2)
    out.append(row("ingest_delta_append_cost", med_big,
                   f"{res['append_cost_ratio_big_over_small']}x the "
                   f"small-table append (O(delta), not O(table))"))

    # -- epoch-keyed cache survival: repeat scan moves tail bytes only -------
    from repro.core import Col
    scan_n = min(n, 1 << 21)
    keys = ("l_returnflag", "l_linestatus")
    vals = ("l_quantity", "l_extendedprice", "l_discount")
    sub = {k: cols[k][:scan_n] for k in keys + vals}
    batch_rows = max(4096, scan_n // 16)   # ~16 device batches at any sf
    dev = startup(device_budget=4 << 30, device_batch_rows=batch_rows,
                  delta_compact_fraction=0.0)
    dev.create_table("li", sub, types={k: types[k] for k in sub},
                     scales={k: scales.get(k, 0) for k in sub})
    q = (dev.scan("li").group_by(*keys)
         .agg(s=("sum", Col("l_extendedprice")), n=("count", None)))
    q.execute(distributed=True)
    cold = int(dev.last_stats.device_bytes_h2d)
    q.execute(distributed=True)
    warm = int(dev.last_stats.device_bytes_h2d)
    tail_rows = 4096
    dev.append("li", {k: v[:tail_rows] for k, v in sub.items()})
    q.execute(distributed=True)
    st = dev.last_stats
    after = int(st.device_bytes_h2d)
    res["scan_rows"] = int(scan_n)
    res["h2d_cold"] = cold
    res["h2d_warm_repeat"] = warm
    res["h2d_after_append"] = after
    res["h2d_after_append_delta_keyed"] = int(st.delta_bytes_h2d)
    res["delta_rows_scanned"] = int(st.delta_rows)
    res["h2d_survival_x"] = round(cold / max(after, 1), 2)
    dev.shutdown()
    assert after < cold / 2, res       # tail re-upload, not the table
    out.append(row("ingest_delta_cache_survival", 0.0,
                   f"h2d cold {cold} vs after-append {after} "
                   f"({res['h2d_survival_x']}x kept)"))

    with open("BENCH_ingest.json", "w") as f:
        json.dump(res, f, indent=1)
    return out
