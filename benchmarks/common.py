"""Shared benchmark utilities: paper-style timing protocol.

Paper §4.1: reported timings are the median of hot runs; the initial cold
run is ignored.  ``timeit`` reproduces that protocol (1 cold + N hot)."""

from __future__ import annotations

import time


def timeit(fn, *, hot: int = 5, cold: int = 1):
    """Returns (median_seconds, all_hot_seconds)."""
    for _ in range(cold):
        fn()
    times = []
    for _ in range(hot):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    n = len(times)
    med = 0.5 * (times[(n - 1) // 2] + times[n // 2])
    return med, times


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"
