"""Concurrent serving layer: throughput and tail latency vs client threads.

MonetDBLite is embedded in multi-threaded analytical hosts, so the unit
under test is the whole serving stack at once: N client threads each run a
repeat-heavy query mix (the plan cache's target workload) against one
database, contending for one ``memory_budget``/``device_budget`` through
the admission gate and sharing base column blocks through the device cache.

The scaling story is work *elimination*, not CPU parallelism: the cold
cost of a query mix — plan lowering, XLA compilation of the fused steps,
and the host→device upload of every column block — is paid ONCE per
database regardless of how many clients run the mix, because the plan
cache, the locked compiled-step cache and the single-flight block cache
all deduplicate it.  Aggregate throughput therefore grows with N even on
a single core: N clients amortize the same cold work over N times the
queries.  Each thread-count level runs in a fresh subprocess (fresh XLA
process cache) so no warm state leaks between levels.

Measured per level N ∈ {1, 2, 4, 8}:

* throughput (queries/s) and P50/P99 per-query latency — the acceptance
  bar is ≥2x the N=1 throughput at N=8 on this mix;
* bit-identity — every client's results equal a serial single-client
  reference run;
* budget invariants — ``peak <= memory_budget`` and
  ``device_bytes_peak <= device_budget`` after every run: admission plus
  atomic ``try_pin`` keep concurrent queries inside the same envelope one
  query gets;
* shared scans — host→device bytes stay at ~one table upload at every N
  (concurrent cold queries attach to one in-flight upload, not N).

Results land in ``BENCH_concurrent.json`` (cwd) for machine consumption.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time
from pathlib import Path

N_ROWS = 400_000
MEMORY_BUDGET = 256 << 20
DEVICE_BUDGET = 256 << 20
THREAD_COUNTS = (1, 2, 4, 8)
QUERIES_PER_THREAD = 12
_DEVICES = 4                      # matches the CI concurrent-job topology


def _dataset():
    import numpy as np
    rng = np.random.default_rng(23)
    return {
        "g": rng.integers(0, 16, N_ROWS).astype(np.int64),
        "h": rng.integers(0, 5, N_ROWS).astype(np.int64),
        "x": rng.uniform(0, 100, N_ROWS),
        "w": rng.integers(-50, 50, N_ROWS).astype(np.int64),
    }


def _mix(db):
    """Repeat-heavy mix: four distinct device-tier plans cycled by every
    client, so the plan cache, the compiled-step cache and the shared block
    cache see the same queries over and over — the serving layer's target
    workload."""
    from repro.core import Col

    def q1():
        return (db.scan("t").group_by("g")
                .agg(s=("sum", Col("x")), n=("count", None))
                .execute(distributed=True))

    def q2():
        return (db.scan("t").filter(Col("w") > 0).group_by("h")
                .agg(mx=("max", Col("x")), s=("sum", Col("w")))
                .execute(distributed=True))

    def q3():
        return (db.scan("t").group_by("g", "h")
                .agg(s=("sum", Col("w")), a=("avg", Col("x")))
                .execute(distributed=True))

    def q4():
        return (db.scan("t").filter(Col("x") > 5.0).group_by("g")
                .agg(mn=("min", Col("w")), s=("sum", Col("x")))
                .execute(distributed=True))

    return [q1, q2, q3, q4]


def _canon(res):
    import numpy as np
    return {k: np.asarray(v) for k, v in res.to_pydict().items()}


def _run_clients(db, n_threads):
    """Every thread runs the full mix QUERIES_PER_THREAD times; returns
    (wall_seconds, sorted per-query latencies, per-thread results,
    per-thread final device tiers)."""
    mix = _mix(db)
    latencies = [[] for _ in range(n_threads)]
    results = [None] * n_threads
    tiers = [None] * n_threads
    errors = []
    barrier = threading.Barrier(n_threads + 1)

    def client(slot):
        try:
            barrier.wait()
            mine = {}
            for rep in range(QUERIES_PER_THREAD):
                i = rep % len(mix)
                t0 = time.perf_counter()
                r = mix[i]()
                latencies[slot].append(time.perf_counter() - t0)
                mine[i] = _canon(r)
            results[slot] = mine
            # db.last_stats is a thread-local view: this thread sees the
            # stats of ITS final query, untouched by the other clients
            tiers[slot] = db.last_stats.device_tier
        except Exception as e:       # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=client, args=(s,))
               for s in range(n_threads)]
    for t in threads:
        t.start()
    barrier.wait()
    t0 = time.perf_counter()
    for t in threads:
        t.join(300)
    wall = time.perf_counter() - t0
    if errors:
        raise errors[0]
    flat = sorted(x for lane in latencies for x in lane)
    return wall, flat, results, tiers


def _pct(sorted_xs, p):
    i = min(len(sorted_xs) - 1, int(round(p / 100 * (len(sorted_xs) - 1))))
    return sorted_xs[i]


def _child(n_threads: int) -> dict:
    """One measurement level, run in a fresh process: N clients against one
    cold database, then a serial reference for bit-identity."""
    import numpy as np

    from repro.core import startup

    data = _dataset()
    db = startup(memory_budget=MEMORY_BUDGET, device_budget=DEVICE_BUDGET)
    db.create_table("t", data)
    wall, lats, results, tiers = _run_clients(db, n_threads)
    bst = db.buffer_manager.stats
    gate = db.admission_gate.stats

    # every client's final query ran on the device tier, resident
    assert all(t == "resident" for t in tiers), tiers
    # budget invariants survived the whole concurrent run
    assert bst.peak <= MEMORY_BUDGET, (bst.peak, MEMORY_BUDGET)
    assert bst.device_bytes_peak <= DEVICE_BUDGET, \
        (bst.device_bytes_peak, DEVICE_BUDGET)
    assert gate.host_reserved_peak <= MEMORY_BUDGET
    assert gate.device_reserved_peak <= DEVICE_BUDGET

    # bit-identity: serial single-client reference on a fresh database
    # (fresh device cache; the XLA steps are warm by now, which only makes
    # the reference faster, not different — batch geometry is pinned)
    ref_db = startup(memory_budget=MEMORY_BUDGET, device_budget=DEVICE_BUDGET)
    ref_db.create_table("t", data)
    reference = {i: _canon(q()) for i, q in enumerate(_mix(ref_db))}
    for slot_result in results:
        for i, ref in reference.items():
            got = slot_result[i]
            assert set(got) == set(ref)
            for k in ref:
                np.testing.assert_array_equal(got[k], ref[k])
    ref_db.shutdown()

    total = n_threads * QUERIES_PER_THREAD
    level = {"threads": n_threads,
             "wall_seconds": round(wall, 4),
             "qps": round(total / wall, 2),
             "p50_ms": round(_pct(lats, 50) * 1e3, 3),
             "p99_ms": round(_pct(lats, 99) * 1e3, 3),
             "plan_cache_hits": int(bst.plan_cache_hits),
             "plan_cache_misses": int(bst.plan_cache_misses),
             "shared_scan_attaches": int(bst.shared_scan_attaches),
             "admission_waits": int(bst.admission_waits),
             "h2d_bytes": int(bst.device_bytes_h2d),
             "device_bytes_peak": int(bst.device_bytes_peak),
             "peak": int(bst.peak),
             "host_reserved_peak": int(gate.host_reserved_peak),
             "device_reserved_peak": int(gate.device_reserved_peak),
             "bit_identical": True}
    db.shutdown()
    return level


def _spawn_level(n_threads: int) -> dict:
    """Run one level in a fresh interpreter so XLA's in-process caches are
    cold: each level pays (and amortizes) its own compile + upload work."""
    root = Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_DEVICES}"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_concurrent",
         "--level", str(n_threads)],
        cwd=root, env=env, capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        raise RuntimeError(
            f"level {n_threads} failed:\n{proc.stdout}\n{proc.stderr}")
    for line in reversed(proc.stdout.strip().splitlines()):
        if line.startswith("{"):
            return json.loads(line)
    raise RuntimeError(f"level {n_threads}: no JSON in output:\n"
                       f"{proc.stdout}\n{proc.stderr}")


def run() -> list[str]:
    from .common import row

    out_rows: list[str] = []
    res: dict = {"n_rows": N_ROWS, "memory_budget": MEMORY_BUDGET,
                 "device_budget": DEVICE_BUDGET, "devices": _DEVICES,
                 "queries_per_thread": QUERIES_PER_THREAD, "levels": {}}
    for n in THREAD_COUNTS:
        level = _spawn_level(n)
        res["levels"][str(n)] = level
        out_rows.append(row(f"concurrent_n{n}", level["p50_ms"] / 1e3,
                            f"qps={level['qps']:.0f} "
                            f"p99_ms={level['p99_ms']}"))

    base = res["levels"]["1"]["qps"]
    speedup = res["levels"]["8"]["qps"] / max(base, 1e-9)
    res["throughput_8v1_x"] = round(speedup, 2)
    res["bit_identical"] = all(
        lv["bit_identical"] for lv in res["levels"].values())
    # shared scans: cold upload volume must not grow with client count
    h2d = {lv["threads"]: lv["h2d_bytes"] for lv in res["levels"].values()}
    res["h2d_8v1_x"] = round(h2d[8] / max(h2d[1], 1), 2)
    out_rows.append(row("concurrent_scaling_8v1", 0.0, f"{speedup:.2f}x"))
    out_rows.append(row("concurrent_h2d_8v1", 0.0, f"{res['h2d_8v1_x']}x"))
    with open("BENCH_concurrent.json", "w") as f:
        json.dump(res, f, indent=1)
    return out_rows


if __name__ == "__main__":
    if "--level" in sys.argv:
        n = int(sys.argv[sys.argv.index("--level") + 1])
        print(json.dumps(_child(n)))
    else:
        print("name,us_per_call,derived")
        for line in run():
            print(line)
