"""Device-tier column cache: resident vs streamed vs host-fallback.

Three claims from the HBM tier (``core/device_cache.py`` +
``parallel.DistributedScanAgg``):

* **cache** — a repeated scan under a generous ``device_budget`` is served
  from the cross-query block cache: ``device_cache_hits > 0`` and zero new
  host→device bytes on the hot runs (cold-vs-cached timings);
* **streaming** — a table larger than a tight ``device_budget`` still runs
  on the device tier by streaming morsel batches with LRU eviction and
  double-buffered prefetch, instead of bailing to the host tier;
* **fallback** — a budget too small for even one batch routes the query to
  the host tier (the prior behaviour for *every* over-budget input);
* **join** — a star join + group-by at build-key granularity through the
  device join tier (``parallel.DistributedJoinAgg``): streamed-device must
  beat the host-parallel hash join by > 1.5x (hot runs);
* **sort** — the fused device lexsort (``kernels.sort.lexsort_indices``)
  vs ``np.lexsort`` over the same float keys.

Results land in ``BENCH_device.json`` (cwd) for machine consumption.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import Col, startup

from .common import row, timeit

N = 400_000
BATCH = 16_384
RESIDENT_BUDGET = 256 << 20
STREAM_BUDGET = 2 << 20          # > 2 batch working sets, << table bytes
TINY_BUDGET = 64 << 10           # < one batch working set: host fallback


def _dataset():
    rng = np.random.default_rng(11)
    return {
        "g": rng.integers(0, 4, N).astype(np.int64),
        "h": rng.integers(0, 3, N).astype(np.int64),
        "x": rng.uniform(0, 100, N),
        "w": rng.integers(-50, 50, N).astype(np.int64),
    }


def _mkdb(data, device_budget):
    db = startup(device_budget=device_budget, device_batch_rows=BATCH)
    db.create_table("t", data)
    return db


def _q(db):
    return (db.scan("t").filter(Col("x") > 5.0)
            .group_by("g", "h")
            .agg(s=("sum", "x"), c=("count", None),
                 mn=("min", "w"), mx=("max", "w"), a=("avg", "x")))


def run(sf: float = 0.0) -> list[str]:
    data = _dataset()
    out_rows: list[str] = []
    res: dict = {"rows": N, "batch_rows": BATCH}

    # Warm the global compiled-step cache on a throwaway database first, so
    # the "cold" timing below isolates host→device transfer + execution
    # (the repeated-query protocol never re-traces anyway).
    warm = _mkdb(data, RESIDENT_BUDGET)
    _q(warm).execute(distributed=True)

    # -- resident: cold transfer vs cross-query cache hits -------------------
    db = _mkdb(data, RESIDENT_BUDGET)
    q = _q(db)
    t0 = time.perf_counter()
    q.execute(distributed=True)
    cold = time.perf_counter() - t0
    st = db.last_stats
    assert st.device_tier == "resident", st.device_tier
    cold_h2d = st.device_bytes_h2d
    cached, _ = timeit(lambda: q.execute(distributed=True), hot=5)
    st = db.last_stats
    assert st.device_cache_hits > 0 and st.device_bytes_h2d == 0
    res["resident"] = {"cold_seconds": cold, "cached_seconds": cached,
                       "cold_h2d_bytes": int(cold_h2d),
                       "cached_h2d_bytes": int(st.device_bytes_h2d),
                       "cache_hits": int(st.device_cache_hits)}
    out_rows.append(row("device_resident_cold", cold, f"h2d={cold_h2d}"))
    out_rows.append(row("device_resident_cached", cached,
                        f"hits={st.device_cache_hits}"))

    # -- streamed: table larger than the device budget -----------------------
    db = _mkdb(data, STREAM_BUDGET)
    q = _q(db)
    streamed, _ = timeit(lambda: q.execute(distributed=True), hot=5)
    st = db.last_stats
    bst = db.buffer_manager.stats
    assert st.device_tier == "streamed", st.device_tier
    assert bst.device_evictions > 0
    assert bst.device_bytes_peak <= STREAM_BUDGET
    res["streamed"] = {"seconds": streamed,
                       "budget": STREAM_BUDGET,
                       "evictions": int(bst.device_evictions),
                       "prefetch_hits": int(bst.device_prefetch_hits),
                       "bytes_peak": int(bst.device_bytes_peak)}
    out_rows.append(row("device_streamed", streamed,
                        f"evictions={bst.device_evictions}"))

    # -- host fallback: not even one batch fits ------------------------------
    db = _mkdb(data, TINY_BUDGET)
    q = _q(db)
    fallback, _ = timeit(lambda: q.execute(distributed=True), hot=5)
    assert db.last_stats.device_tier == ""
    res["fallback"] = {"seconds": fallback, "budget": TINY_BUDGET}
    out_rows.append(row("device_host_fallback", fallback, "host tier"))

    res["cached_vs_cold_x"] = round(cold / max(cached, 1e-9), 2)
    res["streamed_vs_fallback_x"] = round(fallback / max(streamed, 1e-9), 3)
    out_rows.append(row("device_cached_speedup", 0.0,
                        f"{res['cached_vs_cold_x']}x"))
    out_rows.append(row("device_streamed_vs_fallback", 0.0,
                        f"{res['streamed_vs_fallback_x']}x"))

    # -- join tier: streamed device vs host-parallel hash join ---------------
    res["join"] = _join_cell(out_rows)
    # -- sort tier: fused device lexsort vs np.lexsort -----------------------
    res["sort"] = _sort_cell(out_rows)

    with open("BENCH_device.json", "w") as f:
        json.dump(res, f, indent=1)
    return out_rows


D_KEYS = 20_000
JOIN_STREAM_BUDGET = 3 << 20     # < build matrix + carry: must stream


def _mk_star(device_budget=None):
    rng = np.random.default_rng(11)
    db = startup(device_budget=device_budget, device_batch_rows=BATCH)
    db.create_table("fact", {
        "fk": rng.integers(0, D_KEYS, N).astype(np.int64),
        "x": rng.uniform(0, 100, N),
        "w": rng.integers(-50, 50, N).astype(np.int64),
    })
    db.create_table("dim", {
        "k": np.arange(D_KEYS).astype(np.int64),
        "grp": (np.arange(D_KEYS) % 25).astype(np.int64),
    })
    return db


def _star_q(db):
    return (db.scan("fact").filter(Col("x") > 5.0)
            .join(db.scan("dim"), left_on="fk", right_on="k")
            .group_by("fk", "grp")
            .agg(s=("sum", "x"), c=("count", None)))


def _join_cell(out_rows: list[str]) -> dict:
    host = _mk_star()
    t_host, _ = timeit(lambda: _star_q(host).execute(), hot=5)

    dev = _mk_star(device_budget=RESIDENT_BUDGET)
    t_res, _ = timeit(lambda: _star_q(dev).execute(distributed=True),
                      hot=5)
    assert dev.last_stats.device_tier == "join-resident"

    sdev = _mk_star(device_budget=JOIN_STREAM_BUDGET)
    t_str, _ = timeit(lambda: _star_q(sdev).execute(distributed=True),
                      hot=5)
    bst = sdev.buffer_manager.stats
    assert sdev.last_stats.device_tier == "join-streamed"
    assert bst.device_bytes_peak <= JOIN_STREAM_BUDGET

    speedup = round(t_host / max(t_str, 1e-9), 2)
    assert speedup > 1.5, speedup     # the tier's reason to exist
    out_rows.append(row("join_host_parallel", t_host, f"rows={N}"))
    out_rows.append(row("join_device_resident", t_res,
                        f"{round(t_host / max(t_res, 1e-9), 2)}x"))
    out_rows.append(row("join_device_streamed", t_str, f"{speedup}x"))
    return {"rows": N, "dim_keys": D_KEYS,
            "host_seconds": t_host, "resident_seconds": t_res,
            "streamed_seconds": t_str,
            "streamed_budget": JOIN_STREAM_BUDGET,
            "streamed_bytes_peak": int(bst.device_bytes_peak),
            "streamed_vs_host_x": speedup}


SORT_GROUPS = 4_000              # <= MAX_DENSE_GROUPS: device-eligible


def _sort_cell(out_rows: list[str]) -> dict:
    """ORDER BY <agg> DESC LIMIT 10 over a grouped aggregate: the device
    plan fuses the sort onto the assembly (``device_sorted`` — lexsort in
    HBM, only the top-10 rows fetched) vs the host plan's suffix sort.
    The raw kernel permutation is recorded as a sub-cell: standalone it
    pays h2d for every key and loses to np.lexsort — fusion over already-
    device-resident state is the whole point of the tier."""
    from repro.kernels.sort.ops import lexsort_indices
    rng = np.random.default_rng(7)
    data = {"g": rng.integers(0, SORT_GROUPS, N).astype(np.int64),
            "x": rng.uniform(0, 100, N)}

    def mk(device_budget=None):
        db = startup(device_budget=device_budget, device_batch_rows=BATCH)
        db.create_table("s", data)
        return db

    def sq(db):
        return (db.scan("s").group_by("g")
                .agg(s=("sum", "x"), c=("count", None))
                .order_by(("s", True), "g", limit=10))

    host = mk()
    t_host, _ = timeit(lambda: sq(host).execute(), hot=5)
    dev = mk(device_budget=RESIDENT_BUDGET)
    t_dev, _ = timeit(lambda: sq(dev).execute(distributed=True), hot=5)
    st = dev.last_stats
    assert st.device_tier == "resident" and st.device_sorted
    speedup = round(t_host / max(t_dev, 1e-9), 2)
    assert speedup > 1.0, speedup
    out_rows.append(row("sort_host_suffix", t_host,
                        f"groups={SORT_GROUPS}"))
    out_rows.append(row("sort_device_fused", t_dev, f"{speedup}x"))

    k0 = rng.standard_normal(N)
    k1 = rng.integers(0, 1000, N).astype(np.float64)
    t_np, _ = timeit(lambda: np.lexsort((k1, k0)), hot=5)
    t_kr, _ = timeit(lambda: lexsort_indices((k0, k1)), hot=5)
    out_rows.append(row("sort_np_lexsort_raw", t_np, f"rows={N}"))
    out_rows.append(row("sort_device_lexsort_raw", t_kr,
                        f"{round(t_np / max(t_kr, 1e-9), 2)}x"))
    return {"rows": N, "groups": SORT_GROUPS,
            "host_seconds": t_host, "device_seconds": t_dev,
            "device_vs_host_x": speedup,
            "raw_lexsort": {"np_seconds": t_np, "device_seconds": t_kr}}


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line)
