"""Device-tier column cache: resident vs streamed vs host-fallback.

Three claims from the HBM tier (``core/device_cache.py`` +
``parallel.DistributedScanAgg``):

* **cache** — a repeated scan under a generous ``device_budget`` is served
  from the cross-query block cache: ``device_cache_hits > 0`` and zero new
  host→device bytes on the hot runs (cold-vs-cached timings);
* **streaming** — a table larger than a tight ``device_budget`` still runs
  on the device tier by streaming morsel batches with LRU eviction and
  double-buffered prefetch, instead of bailing to the host tier;
* **fallback** — a budget too small for even one batch routes the query to
  the host tier (the prior behaviour for *every* over-budget input).

Results land in ``BENCH_device.json`` (cwd) for machine consumption.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import Col, startup

from .common import row, timeit

N = 400_000
BATCH = 16_384
RESIDENT_BUDGET = 256 << 20
STREAM_BUDGET = 2 << 20          # > 2 batch working sets, << table bytes
TINY_BUDGET = 64 << 10           # < one batch working set: host fallback


def _dataset():
    rng = np.random.default_rng(11)
    return {
        "g": rng.integers(0, 4, N).astype(np.int64),
        "h": rng.integers(0, 3, N).astype(np.int64),
        "x": rng.uniform(0, 100, N),
        "w": rng.integers(-50, 50, N).astype(np.int64),
    }


def _mkdb(data, device_budget):
    db = startup(device_budget=device_budget, device_batch_rows=BATCH)
    db.create_table("t", data)
    return db


def _q(db):
    return (db.scan("t").filter(Col("x") > 5.0)
            .group_by("g", "h")
            .agg(s=("sum", "x"), c=("count", None),
                 mn=("min", "w"), mx=("max", "w"), a=("avg", "x")))


def run(sf: float = 0.0) -> list[str]:
    data = _dataset()
    out_rows: list[str] = []
    res: dict = {"rows": N, "batch_rows": BATCH}

    # Warm the global compiled-step cache on a throwaway database first, so
    # the "cold" timing below isolates host→device transfer + execution
    # (the repeated-query protocol never re-traces anyway).
    warm = _mkdb(data, RESIDENT_BUDGET)
    _q(warm).execute(distributed=True)

    # -- resident: cold transfer vs cross-query cache hits -------------------
    db = _mkdb(data, RESIDENT_BUDGET)
    q = _q(db)
    t0 = time.perf_counter()
    q.execute(distributed=True)
    cold = time.perf_counter() - t0
    st = db.last_stats
    assert st.device_tier == "resident", st.device_tier
    cold_h2d = st.device_bytes_h2d
    cached, _ = timeit(lambda: q.execute(distributed=True), hot=5)
    st = db.last_stats
    assert st.device_cache_hits > 0 and st.device_bytes_h2d == 0
    res["resident"] = {"cold_seconds": cold, "cached_seconds": cached,
                       "cold_h2d_bytes": int(cold_h2d),
                       "cached_h2d_bytes": int(st.device_bytes_h2d),
                       "cache_hits": int(st.device_cache_hits)}
    out_rows.append(row("device_resident_cold", cold, f"h2d={cold_h2d}"))
    out_rows.append(row("device_resident_cached", cached,
                        f"hits={st.device_cache_hits}"))

    # -- streamed: table larger than the device budget -----------------------
    db = _mkdb(data, STREAM_BUDGET)
    q = _q(db)
    streamed, _ = timeit(lambda: q.execute(distributed=True), hot=5)
    st = db.last_stats
    bst = db.buffer_manager.stats
    assert st.device_tier == "streamed", st.device_tier
    assert bst.device_evictions > 0
    assert bst.device_bytes_peak <= STREAM_BUDGET
    res["streamed"] = {"seconds": streamed,
                       "budget": STREAM_BUDGET,
                       "evictions": int(bst.device_evictions),
                       "prefetch_hits": int(bst.device_prefetch_hits),
                       "bytes_peak": int(bst.device_bytes_peak)}
    out_rows.append(row("device_streamed", streamed,
                        f"evictions={bst.device_evictions}"))

    # -- host fallback: not even one batch fits ------------------------------
    db = _mkdb(data, TINY_BUDGET)
    q = _q(db)
    fallback, _ = timeit(lambda: q.execute(distributed=True), hot=5)
    assert db.last_stats.device_tier == ""
    res["fallback"] = {"seconds": fallback, "budget": TINY_BUDGET}
    out_rows.append(row("device_host_fallback", fallback, "host tier"))

    res["cached_vs_cold_x"] = round(cold / max(cached, 1e-9), 2)
    res["streamed_vs_fallback_x"] = round(fallback / max(streamed, 1e-9), 3)
    out_rows.append(row("device_cached_speedup", 0.0,
                        f"{res['cached_vs_cold_x']}x"))
    out_rows.append(row("device_streamed_vs_fallback", 0.0,
                        f"{res['streamed_vs_fallback_x']}x"))
    with open("BENCH_device.json", "w") as f:
        json.dump(res, f, indent=1)
    return out_rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line)
