"""Paper Table 1: TPC-H Q1-Q10 across engines.

Systems compared (all in-process, same data):
  * engine        — the columnar engine, optimized plans (MonetDBLite role)
  * engine_noopt  — same engine, optimizer off (ablation)
  * engine_dist   — shard_map distributed tier where the plan qualifies
  * volcano       — row-at-a-time interpreter (SQLite/Postgres role);
                    run at a reduced scale factor and extrapolated, as the
                    paper's timeout column does for SQLite
"""

from __future__ import annotations

import numpy as np

from repro.core import startup
from repro.core.optimizer import optimize
from repro.core.volcano import VolcanoExecutor
from repro.data import tpch
from repro.data.tpch_queries import ALL_QUERIES

from .common import row, timeit

VOLCANO_SF_CAP = 0.002          # row-at-a-time Python: keep it honest


def run(sf: float = 0.01, volcano: bool = True) -> list[str]:
    db = startup()
    tpch.load_into(db, sf)
    out = []
    totals = {"engine": 0.0, "volcano": 0.0}
    for name, qf in ALL_QUERIES.items():
        q = qf(db)
        med, _ = timeit(lambda: q.execute(), hot=3)
        out.append(row(f"tpch_{name}_engine", med, f"sf={sf}"))
        totals["engine"] += med
        med_no, _ = timeit(lambda: q.execute(do_optimize=False), hot=1)
        out.append(row(f"tpch_{name}_engine_noopt", med_no,
                       f"slowdown={med_no/med:.2f}x"))
        if name in ("q1", "q6"):
            med_d, _ = timeit(lambda: q.execute(distributed=True), hot=3)
            out.append(row(f"tpch_{name}_engine_dist", med_d,
                           "shard_map"))
    if volcano:
        vsf = min(sf, VOLCANO_SF_CAP)
        vdb = startup()
        tpch.load_into(vdb, vsf)
        scale = sf / vsf
        for name, qf in ALL_QUERIES.items():
            q = qf(vdb)
            plan = optimize(q.plan, vdb.catalog)
            ex = VolcanoExecutor(vdb)
            med, _ = timeit(lambda: ex.execute(plan), hot=1)
            out.append(row(f"tpch_{name}_volcano", med * scale,
                           f"extrapolated_{scale:.0f}x_from_sf{vsf}"))
            totals["volcano"] += med * scale
    out.append(row("tpch_total_engine", totals["engine"], f"sf={sf}"))
    if volcano:
        out.append(row("tpch_total_volcano", totals["volcano"],
                       f"speedup={totals['volcano']/max(totals['engine'],1e-9):.0f}x"))
    return out
