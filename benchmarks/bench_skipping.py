"""Imprint-driven data skipping: bytes moved vs selectivity.

Two claims from the skip-set wiring (``physplan.derive_skip_sets`` +
``parallel.DistributedScanAgg`` + the host spill path):

* **device** — on a shipdate-clustered table, a selective filter's
  non-qualifying morsel batches are never uploaded: cold host→device
  bytes drop roughly proportionally to selectivity, >= 2x at 1% vs the
  same query with skipping forced off (``data_skipping=False``);
* **spill** — under a tight host budget the grouped aggregate's spill
  volume tracks selectivity too (skipped blocks contribute zero rows to
  the partition streams), with ``bytes_skipped_spill`` accounting the
  filter-column bytes that were never read.

Every (selectivity, on/off) cell is a fresh database so block caches
cannot blur the cold-transfer comparison, and on-vs-off results are
asserted bit-identical before any number is recorded.

Results land in ``BENCH_skipping.json`` (cwd) for machine consumption.
"""

from __future__ import annotations

import json
import time

import numpy as np

from repro.core import Col, startup
from repro.core.expression import Lit
from repro.core.types import DBType

from .common import row

N = 64 * 2048                    # 64 imprint blocks
BATCH = 16_384                   # 8 batches of 8 blocks each
DEVICE_BUDGET = 256 << 20
SPILL_BUDGET = 256 << 10
SELS = (0.0, 0.01, 0.5, 1.0)


def _dataset():
    rng = np.random.default_rng(11)
    return {
        "ship": np.sort(rng.integers(8000, 9200, N)).astype(np.int32),
        "g": rng.integers(0, 4, N).astype(np.int64),
        "h": rng.integers(0, 3, N).astype(np.int64),
        "k": rng.integers(0, N // 2, N).astype(np.int64),
        "price": np.round(rng.uniform(900, 105000, N), 2),
        "disc": np.round(rng.uniform(0.0, 0.10, N), 2),
    }


def _cut(ship, sel):
    if sel <= 0.0:
        return int(ship.min()) - 1
    if sel >= 1.0:
        return int(ship.max()) + 1
    return int(np.quantile(ship, sel))


def _q(db, cut):
    return (db.scan("t").filter(Col("ship") <= Lit(cut))
            .group_by("g", "h")
            .agg(s=("sum", "price"), d=("sum", "disc"),
                 n=("count", None)))


def _q_spill(db, cut):
    """High-cardinality grouping: surviving rows stream through grace-hash
    partitions, so spill volume tracks the filter's selectivity."""
    return (db.scan("t").filter(Col("ship") <= Lit(cut))
            .group_by("k")
            .agg(s=("sum", "price"), n=("count", None)))


def _bits_equal(a, b):
    for c in a:
        np.testing.assert_array_equal(np.asarray(a[c], dtype=float),
                                      np.asarray(b[c], dtype=float),
                                      err_msg=c)


def run(sf: float = 0.0) -> list[str]:
    data = _dataset()
    ship = data["ship"]
    out_rows: list[str] = []
    res: dict = {"rows": N, "batch_rows": BATCH, "cells": {}}

    def mkdb(skipping, device):
        kw = (dict(device_budget=DEVICE_BUDGET, device_batch_rows=BATCH)
              if device else dict(memory_budget=SPILL_BUDGET))
        db = startup(data_skipping=skipping, **kw)
        db.create_table("t", data, types={"ship": DBType.DATE})
        return db

    # Warm the compiled-step cache so cold cells isolate transfer volume.
    warm = mkdb(True, True)
    _q(warm, _cut(ship, 0.5)).execute(distributed=True)
    warm.shutdown()

    for sel in SELS:
        cut = _cut(ship, sel)
        cell: dict = {"cutoff": cut}

        # -- device tier: cold h2d bytes, skipping on vs forced off ----------
        got = {}
        for skipping in (True, False):
            db = mkdb(skipping, True)
            q = _q(db, cut)
            t0 = time.perf_counter()
            got[skipping] = q.execute(distributed=True).to_pydict()
            dt = time.perf_counter() - t0
            st = db.last_stats
            tag = "on" if skipping else "off"
            cell[f"bytes_h2d_{tag}"] = int(st.device_bytes_h2d)
            cell[f"seconds_device_{tag}"] = dt
            if skipping:
                cell["bytes_skipped_h2d"] = int(st.bytes_skipped_h2d)
                cell["blocks_skipped_device"] = int(st.blocks_skipped)
            db.shutdown()
        _bits_equal(got[True], got[False])

        # -- spill tier: budgeted group-by, spilled bytes vs selectivity -----
        got = {}
        for skipping in (True, False):
            db = mkdb(skipping, False)
            got[skipping] = _q_spill(db, cut).execute().to_pydict()
            st = db.last_stats
            tag = "on" if skipping else "off"
            cell[f"bytes_spilled_{tag}"] = int(st.bytes_spilled_raw)
            if skipping:
                cell["bytes_skipped_spill"] = int(st.bytes_skipped_spill)
                cell["blocks_skipped_host"] = int(st.blocks_skipped)
            db.shutdown()
        _bits_equal(got[True], got[False])

        res["cells"][str(sel)] = cell
        out_rows.append(row(
            f"skipping_sel_{sel}", cell["seconds_device_on"],
            f"h2d {cell['bytes_h2d_on']} vs {cell['bytes_h2d_off']}, "
            f"spill {cell['bytes_spilled_on']}"))

    c1 = res["cells"]["0.01"]
    res["h2d_reduction_at_1pct_x"] = round(
        c1["bytes_h2d_off"] / max(c1["bytes_h2d_on"], 1), 2)
    full_spill = res["cells"]["1.0"]["bytes_spilled_on"]
    res["spill_reduction_at_1pct_x"] = round(
        full_spill / max(c1["bytes_spilled_on"], 1), 2)
    res["spill_halves_at_50pct_x"] = round(
        full_spill / max(res["cells"]["0.5"]["bytes_spilled_on"], 1), 2)
    assert res["h2d_reduction_at_1pct_x"] >= 2.0, res
    out_rows.append(row("skipping_h2d_reduction_1pct", 0.0,
                        f"{res['h2d_reduction_at_1pct_x']}x"))
    out_rows.append(row("skipping_spill_reduction_1pct", 0.0,
                        f"{res['spill_reduction_at_1pct_x']}x"))
    with open("BENCH_skipping.json", "w") as f:
        json.dump(res, f, indent=1)
    return out_rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line)
