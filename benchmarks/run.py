"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Modules:
  bench_ingest  — Fig. 5 (data ingestion)
  bench_export  — Fig. 6 (data export / zero-copy)
  bench_tpch    — Table 1 (TPC-H Q1-Q10, engine vs volcano row-store)
  bench_acs     — Fig. 7/8 (ACS wide-table load + statistics)
  bench_kernels — §3 hot-spot kernels
  bench_spill   — out-of-core tier: spill codec ratio + prefetch overlap
  bench_device  — device tier: resident cache vs streamed vs host fallback
  bench_concurrent — serving layer: throughput/P99 vs client threads
  bench_skipping — imprint data skipping: bytes moved vs selectivity
"""

from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: "
                         "ingest,export,tpch,acs,kernels,spill,device,"
                         "concurrent,skipping")
    ap.add_argument("--sf", type=float, default=0.01)
    ap.add_argument("--no-volcano", action="store_true")
    args = ap.parse_args()
    which = set(args.only.split(",")) if args.only else {
        "ingest", "export", "tpch", "acs", "kernels", "spill", "device",
        "concurrent", "skipping"}

    print("name,us_per_call,derived")
    rows: list[str] = []
    if "ingest" in which:
        from .bench_ingest import run as r
        rows += r(args.sf)
        _flush(rows)
    if "export" in which:
        from .bench_export import run as r
        rows += r(args.sf)
        _flush(rows)
    if "tpch" in which:
        from .bench_tpch import run as r
        rows += r(args.sf, volcano=not args.no_volcano)
        _flush(rows)
    if "acs" in which:
        from .bench_acs import run as r
        rows += r()
        _flush(rows)
    if "kernels" in which:
        from .bench_kernels import run as r
        rows += r()
        _flush(rows)
    if "spill" in which:
        from .bench_spill import run as r
        rows += r(max(args.sf, 0.02))
        _flush(rows)
    if "device" in which:
        from .bench_device import run as r
        rows += r(args.sf)
        _flush(rows)
    if "concurrent" in which:
        from .bench_concurrent import run as r
        rows += r()
        _flush(rows)
    if "skipping" in which:
        from .bench_skipping import run as r
        rows += r()
        _flush(rows)


_printed = 0


def _flush(rows):
    global _printed
    for line in rows[_printed:]:
        print(line, flush=True)
    _printed = len(rows)


if __name__ == "__main__":
    main()
