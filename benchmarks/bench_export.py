"""Paper Fig. 6: loading lineitem from the database into the host tool.

Compared paths:
  * zero_copy_lazy  — LazyFrame export, touch numeric columns (O(1) per
    column; the paper's headline mechanism)
  * eager_decode    — full decode of every column (the conversion cost)
  * row_fetch       — row-at-a-time fetch loop (the client-protocol
    pathology, SQLite-style)

Also asserts the paper's zero-copy claim: export time is independent of
row count (O(1) in data size).
"""

from __future__ import annotations

import numpy as np

from repro.core import startup
from repro.core.exchange import export_table
from repro.data import tpch

from .common import row, timeit


def run(sf: float = 0.01) -> list[str]:
    db = startup()
    tpch.load_into(db, sf, tables=["lineitem"])
    res = db.scan("lineitem").execute()
    numeric_cols = [c.name for c in res.schema.columns
                    if c.dbtype.value in ("int64", "float64")]
    out = []

    def lazy():
        lf = export_table(res, lazy=True)
        for c in numeric_cols:
            _ = lf[c]
    med, _ = timeit(lazy, hot=7)
    out.append(row("export_zero_copy_lazy", med,
                   f"{len(numeric_cols)}cols"))

    def eager():
        export_table(res, lazy=False)
    med_e, _ = timeit(eager, hot=3)
    out.append(row("export_eager_decode", med_e, f"{res.num_cols}cols"))

    n_rows = min(2000, res.num_rows)
    decoded = res.to_pydict()
    def rows():
        out_rows = []
        for i in range(n_rows):
            out_rows.append({k: decoded[k][i] for k in decoded})
        return out_rows
    med_r, _ = timeit(rows, hot=3)
    out.append(row("export_row_fetch_loop",
                   med_r / n_rows * res.num_rows,
                   f"extrapolated_from_{n_rows}_rows"))

    # O(1) claim: zero-copy export cost must not scale with rows
    db2 = startup()
    tpch.load_into(db2, sf * 4, tables=["lineitem"])
    res4 = db2.scan("lineitem").execute()
    def lazy4():
        lf = export_table(res4, lazy=True)
        for c in numeric_cols:
            _ = lf[c]
    med4, _ = timeit(lazy4, hot=7)
    ratio = med4 / max(med, 1e-9)
    out.append(row("export_zero_copy_scaling", med4,
                   f"4x_rows_time_ratio={ratio:.2f}"))
    return out
