"""Paper Fig. 7/8: the ACS wide-table (274 columns) workload.

Phase 1 (Fig. 7): load the survey table into the store.
Phase 2 (Fig. 8): survey statistics — grouped means of person weights and
incomes over the replicate-weight columns, split between in-engine
aggregation and host-side post-processing exactly like the survey package
splits work between SQL and R.
"""

from __future__ import annotations

import numpy as np

from repro.core import Col, startup
from repro.core.exchange import export_table
from repro.data.synth import N_WEIGHT_REPLICATES, generate_acs

from .common import row, timeit


def run(n_rows: int = 30_000) -> list[str]:
    cols, types, scales = generate_acs(n_rows)
    out = []

    def load():
        db = startup()
        db.create_table("acs", cols, types=types, scales=scales)
        return db
    med, _ = timeit(load, hot=3)
    out.append(row("acs_load", med, f"{len(cols)}cols_{n_rows}rows"))

    db = load()

    def stats():
        # in-engine: grouped aggregation over states
        res = (db.scan("acs")
               .filter(Col("agep") >= 16)
               .group_by("st")
               .agg(mean_wage=("avg", "wagp"),
                    pop=("sum", "pwgtp"),
                    n=("count", None))
               .execute())
        # host side: replicate-weight variance (the "in R" part)
        lf = export_table(db.scan("acs").select(
            *[f"pwgtp{i}" for i in range(1, 9)], "pwgtp").execute())
        reps = np.stack([lf[f"pwgtp{i}"] for i in range(1, 9)])
        base = lf["pwgtp"]
        rep_var = 4.0 / 80.0 * ((reps - base) ** 2).sum(axis=0).mean()
        return res, rep_var
    med_s, _ = timeit(stats, hot=3)
    out.append(row("acs_statistics", med_s,
                   f"{N_WEIGHT_REPLICATES}replicates"))

    def stats_sql():
        return db.connect().query(
            "SELECT st, avg(wagp) mean_wage, sum(pwgtp) pop, count(*) n "
            "FROM acs WHERE agep >= 16 GROUP BY st ORDER BY st").to_pydict()
    med_q, _ = timeit(stats_sql, hot=3)
    out.append(row("acs_statistics_sql", med_q, "sql_path"))
    return out
