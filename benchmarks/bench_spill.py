"""Spill pipeline v2: codec compression ratio + prefetch overlap.

Two claims from the out-of-core tier (ROADMAP follow-ons to PR 1):

* **compression** — frame-of-reference + byte-shuffle run files cut
  ``bytes_spilled`` >= 2x on sorted/clustered int64 keys.  Measured with
  TPC-H Q1 re-grained to the order key (the classic over-budget variant:
  grouping state ~ |orders|) over a lineitem table clustered on
  ``l_orderkey``, raw codec vs FOR codec, same budget.
* **prefetch** — double-buffered background partition loading overlaps
  run-file I/O/decode with partition processing on a budgeted grace-hash
  join; wall-clock off vs on.

Results also land in ``BENCH_spill.json`` (cwd) for machine consumption.
"""

from __future__ import annotations

import json

import numpy as np

from repro.core import Col, DateLit, startup
from repro.data import tpch

from .common import row, timeit

SPILL_BUDGET = 256 << 10        # forces every blocking op out of core
JOIN_BUDGET = 1 << 20


def _q1_order_grain(db):
    """TPC-H Q1 shape with the group key at order grain: the grouping state
    (~|orders| groups) and the sort both exceed the budget and spill."""
    return (db.scan("lineitem")
            .filter(Col("l_shipdate") <= DateLit("1998-09-02"))
            .group_by("l_orderkey")
            .agg(sum_qty=("sum", Col("l_quantity")),
                 sum_base_price=("sum", Col("l_extendedprice")),
                 count_order=("count", None))
            .order_by(("sum_qty", True), "l_orderkey"))


def _compression(sf: float) -> tuple[list[str], dict]:
    tables = tpch.generate(sf)
    li, types, scales = tables["lineitem"]
    order = np.argsort(li["l_orderkey"], kind="stable")   # cluster on key
    li = {c: np.asarray(v)[order] for c, v in li.items()}

    out_rows, res = [], {}
    baseline = None
    for codec in ("raw", "for"):
        db = startup(memory_budget=SPILL_BUDGET, spill_codec=codec)
        db.create_table("lineitem", li, types, scales)
        q = _q1_order_grain(db)
        med, _ = timeit(lambda: q.execute(), hot=3)
        st = db.last_stats                       # per-query spill deltas
        assert st.spilled_ops > 0, "Q1-order-grain must spill"
        if baseline is None:
            baseline = q.execute().to_pydict()
        else:                                    # codec never changes bits
            got = q.execute().to_pydict()
            for c in baseline:
                np.testing.assert_array_equal(baseline[c], got[c])
        res[codec] = {"seconds": med,
                      "bytes_spilled": int(st.bytes_spilled_compressed),
                      "bytes_spilled_raw": int(st.bytes_spilled_raw)}
        out_rows.append(row(f"spill_q1_codec_{codec}", med,
                            f"spilled={st.bytes_spilled_compressed}"))
    red = res["raw"]["bytes_spilled"] / max(1, res["for"]["bytes_spilled"])
    res["reduction_x"] = round(red, 2)
    out_rows.append(row("spill_codec_reduction", 0.0, f"{red:.2f}x"))
    return out_rows, res


def _prefetch(n: int = 600_000) -> tuple[list[str], dict]:
    rng = np.random.default_rng(17)
    fact = {"k": rng.integers(0, 50_000, n).astype(np.int64),
            "v": rng.normal(size=n)}
    dim = {"dk": np.arange(50_000, dtype=np.int64),
           "label": rng.integers(0, 11, 50_000).astype(np.int64)}

    qs, dbs = {}, {}
    for pf in (False, True):
        db = startup(memory_budget=JOIN_BUDGET, spill_prefetch=pf)
        db.create_table("t", fact)
        db.create_table("d", dim)
        dbs["on" if pf else "off"] = db
        qs["on" if pf else "off"] = (
            db.scan("t").join(db.scan("d"), left_on="k", right_on="dk")
            .group_by("label").agg(s=("sum", "v"), c=("count", None)))

    # alternate off/on hot runs back-to-back so machine drift between two
    # separate measurement phases cannot masquerade as a speedup either way
    import time
    times = {"off": [], "on": []}
    for key in ("off", "on"):
        qs[key].execute()                        # cold run, discarded
    for _ in range(9):
        for key in ("off", "on"):
            t0 = time.perf_counter()
            qs[key].execute()
            times[key].append(time.perf_counter() - t0)

    out_rows, res = [], {}
    for key in ("off", "on"):
        ts = sorted(times[key])
        med = 0.5 * (ts[(len(ts) - 1) // 2] + ts[len(ts) // 2])
        st = dbs[key].last_stats
        assert st.spilled_ops > 0, "budgeted join must spill"
        res[key] = {"seconds": med,
                    "prefetch_hits": int(st.prefetch_hits)}
        out_rows.append(row(f"spill_join_prefetch_{key}", med,
                            f"hits={st.prefetch_hits}"))
    speed = res["off"]["seconds"] / max(res["on"]["seconds"], 1e-9)
    res["speedup_x"] = round(speed, 3)
    out_rows.append(row("spill_prefetch_speedup", 0.0, f"{speed:.3f}x"))
    return out_rows, res


def run(sf: float = 0.02) -> list[str]:
    rows_c, comp = _compression(sf)
    rows_p, pref = _prefetch()
    with open("BENCH_spill.json", "w") as f:
        json.dump({"sf": sf, "budget_compression": SPILL_BUDGET,
                   "budget_prefetch": JOIN_BUDGET,
                   "compression": comp, "prefetch": pref}, f, indent=1)
    return rows_c + rows_p


if __name__ == "__main__":
    print("name,us_per_call,derived")
    for line in run():
        print(line)
