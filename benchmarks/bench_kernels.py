"""Kernel microbenchmarks (paper §3 hot spots).

On this CPU container the Pallas kernels run in interpret mode (Python), so
wall-clock favors the host mirrors; the benchmark's role here is (a) the
host-tier numbers the engine actually uses, and (b) the derived
bytes-touched column used in EXPERIMENTS.md §Perf napkin math for the TPU
target.
"""

from __future__ import annotations

import numpy as np

from repro.kernels.hash_group import ops as hops
from repro.kernels.imprint import ops as iops
from repro.kernels.scan_agg import ops as sops

from .common import row, timeit


def run(n: int = 2_000_000) -> list[str]:
    rng = np.random.default_rng(0)
    out = []

    vals = rng.uniform(0, 1000, n)
    nulls = np.zeros(n, bool)
    med, _ = timeit(lambda: iops.build_zone_maps(vals, nulls, 2048, 16),
                    hot=3)
    out.append(row("kernel_imprint_build_host", med,
                   f"{vals.nbytes/med/1e9:.2f}GBps"))

    cols = rng.uniform(0, 100, (4, n))
    ranges = np.array([[10, 90], [-np.inf, np.inf], [0, 50],
                       [-np.inf, np.inf]])
    pairs = ((1, 3), (2, -1))
    med, _ = timeit(lambda: sops.fused_filter_agg(
        cols, ranges, pairs, use_pallas=False), hot=3)
    out.append(row("kernel_scan_agg_host", med,
                   f"{cols.nbytes/med/1e9:.2f}GBps"))

    # separate (unfused) passes for comparison: filter then per-agg
    def unfused():
        m = np.ones(n, bool)
        m &= (cols[0] >= 10) & (cols[0] <= 90)
        m &= (cols[2] >= 0) & (cols[2] <= 50)
        (cols[1] * cols[3])[m].sum()
        cols[2][m].sum()
    med_u, _ = timeit(unfused, hot=3)
    out.append(row("kernel_scan_agg_unfused_host", med_u,
                   f"fusion_speedup={med_u/med:.2f}x"))

    gid = rng.integers(0, 256, n)
    v2 = rng.normal(size=(4, n))
    med, _ = timeit(lambda: hops.grouped_aggregate(
        gid, v2, 256, use_pallas=False), hot=3)
    out.append(row("kernel_hash_group_host", med,
                   f"{v2.nbytes/med/1e9:.2f}GBps"))

    # interpret-mode pallas correctness-path timing (small n)
    small = 65_536
    med, _ = timeit(lambda: sops.fused_filter_agg(
        cols[:, :small], ranges, pairs, interpret=True), hot=2)
    out.append(row("kernel_scan_agg_pallas_interpret", med,
                   "correctness_path"))

    # imprint ablation (paper §3.1 motivation): selective range query on
    # clustered data, zone-map pruning on vs off
    from repro.core import Col, startup
    db = startup()
    db.create_table("c", {"x": np.sort(rng.uniform(0, 1000, n))})
    q = db.scan("c").filter((Col("x") >= 100.0) & (Col("x") <= 102.0)) \
        .agg(cnt=("count", None))
    med_on, _ = timeit(lambda: q.execute(), hot=5)
    im = db.index_manager
    class _Off:
        def imprint_mask(self, *a, **k):
            return None
        auto_order_index = staticmethod(lambda *a, **k: None)
    db.index_manager = _Off()
    med_off, _ = timeit(lambda: q.execute(), hot=5)
    db.index_manager = im
    out.append(row("imprint_range_select_on", med_on,
                   f"speedup={med_off/med_on:.2f}x"))
    out.append(row("imprint_range_select_off", med_off, "no_zone_maps"))
    return out
