"""repro: MonetDBLite as a JAX/TPU-native embedded analytical engine,
embedded into a multi-pod LM training/serving framework."""

__version__ = "0.1.0"
