"""Runtime lock-order witness.

Static analysis cannot see dynamic deadlock shapes — lock A taken under
lock B on one thread and B under A on another, or a blocking
``Condition.wait`` entered while a second lock is still held (the PR 6
collective-dispatch deadlock was exactly the latter).  The witness wraps
the engine's *named* locks in thin proxies that record, per thread, the
set of witnessed locks held at every acquire.  Each acquire appends
``held -> acquired`` edges to a global acquisition-order graph *before*
blocking, so even a real deadlock leaves the offending edge behind.

Usage (opt-in; zero overhead when not installed)::

    from repro.analysis import witness
    w = witness.LockWitness()
    witness.install(w)            # instruments every Database built after
    ...run the concurrent suite...
    witness.uninstall()
    w.assert_ok()                 # raises on cycles / held-lock waits

or set ``REPRO_WITNESS=1`` and run pytest — ``tests/conftest.py``
installs a session-scoped witness and checks it at teardown.

Reentrant re-acquisition of the same named lock (RLock) is not an
ordering edge and is skipped.  ``Condition.wait`` releases its own lock,
so waiting while *other* witnessed locks are held is recorded as a
violation: those locks stay held for the full wait and any thread that
needs one of them to reach ``notify`` deadlocks.
"""

from __future__ import annotations

import threading
from collections import defaultdict
from typing import Optional


class LockOrderError(AssertionError):
    """Raised by :meth:`LockWitness.assert_ok` on a violation."""


class LockWitness:
    """Records the lock acquisition-order graph across all threads."""

    def __init__(self):
        self._graph_lock = threading.Lock()
        # edge (held_name, acquired_name) -> example thread name
        self.edges: dict[tuple, str] = {}
        # blocking waits taken while other witnessed locks were held
        self.wait_violations: list[str] = []
        self.acquire_count = 0
        self._local = threading.local()

    # -- per-thread held stack ------------------------------------------------
    def _held(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    # -- hooks called by _WitnessedLock --------------------------------------
    def note_acquire(self, name: str) -> None:
        """Record edges BEFORE the underlying acquire may block."""
        held = self._held()
        with self._graph_lock:
            self.acquire_count += 1
            for h in held:
                if h != name:                 # RLock reentrancy: no self-edge
                    self.edges.setdefault(
                        (h, name), threading.current_thread().name)

    def note_acquired(self, name: str) -> None:
        self._held().append(name)

    def note_release(self, name: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                return

    def note_wait(self, name: str) -> None:
        """``Condition.wait`` on ``name``: its own lock is released for the
        duration, but every *other* held witnessed lock stays held."""
        others = [h for h in self._held() if h != name]
        if others:
            with self._graph_lock:
                self.wait_violations.append(
                    f"{threading.current_thread().name}: blocking wait on "
                    f"{name} while holding {sorted(set(others))}")

    # -- analysis -------------------------------------------------------------
    def cycles(self) -> list:
        """All elementary cycles reachable in the recorded graph (DFS)."""
        with self._graph_lock:
            adj = defaultdict(set)
            for a, b in self.edges:
                adj[a].add(b)
        out, state = [], {}          # state: 1=on stack, 2=done

        def dfs(node, path):
            state[node] = 1
            path.append(node)
            for nxt in sorted(adj[node]):
                if state.get(nxt) == 1:
                    out.append(path[path.index(nxt):] + [nxt])
                elif state.get(nxt) is None:
                    dfs(nxt, path)
            path.pop()
            state[node] = 2

        for node in sorted(adj):
            if state.get(node) is None:
                dfs(node, [])
        return out

    def report(self) -> str:
        lines = [f"witness: {self.acquire_count} acquisitions, "
                 f"{len(self.edges)} distinct order edges"]
        for (a, b), thr in sorted(self.edges.items()):
            lines.append(f"  {a} -> {b}   (first seen on {thr})")
        for c in self.cycles():
            lines.append(f"  CYCLE: {' -> '.join(c)}")
        for v in self.wait_violations:
            lines.append(f"  HELD-LOCK WAIT: {v}")
        return "\n".join(lines)

    def assert_ok(self) -> None:
        problems = []
        for c in self.cycles():
            problems.append(f"lock-order cycle: {' -> '.join(c)}")
        problems.extend(f"held-lock wait: {v}" for v in self.wait_violations)
        if problems:
            raise LockOrderError(
                "lock-order witness failed:\n  " + "\n  ".join(problems)
                + "\n" + self.report())


class _WitnessedLock:
    """Proxy around Lock/RLock/Condition reporting to a LockWitness."""

    def __init__(self, inner, name: str, witness: LockWitness):
        self._inner = inner
        self._name = name
        self._witness = witness

    def acquire(self, *args, **kwargs):
        self._witness.note_acquire(self._name)
        got = self._inner.acquire(*args, **kwargs)
        if got:
            self._witness.note_acquired(self._name)
        return got

    def release(self):
        self._inner.release()
        self._witness.note_release(self._name)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    # Condition protocol — delegated; wait() is a witness event because the
    # calling thread blocks while every OTHER held lock stays held.
    def wait(self, timeout: Optional[float] = None):
        self._witness.note_wait(self._name)
        return self._inner.wait(timeout)

    def wait_for(self, predicate, timeout: Optional[float] = None):
        self._witness.note_wait(self._name)
        return self._inner.wait_for(predicate, timeout)

    def notify(self, n: int = 1):
        return self._inner.notify(n)

    def notify_all(self):
        return self._inner.notify_all()

    def locked(self):
        return self._inner.locked()

    def __repr__(self):
        return f"<witnessed {self._name} wrapping {self._inner!r}>"


def _wrap(obj, attr: str, name: str, witness: LockWitness) -> None:
    inner = getattr(obj, attr, None)
    if inner is None or isinstance(inner, _WitnessedLock):
        return
    setattr(obj, attr, _WitnessedLock(inner, name, witness))


def instrument_database(db, witness: LockWitness) -> None:
    """Wrap the named locks of one Database's managers in place."""
    bm = getattr(db, "buffer_manager", None)
    if bm is not None:
        _wrap(bm, "_lock", "BufferManager._lock", witness)
        _wrap(bm, "_query_cond", "BufferManager._query_cond", witness)
    dm = getattr(db, "device_manager", None)
    if dm is not None:
        _wrap(dm, "_lock", "DeviceBufferManager._lock", witness)
        flight = getattr(dm, "_flight", None)
        if flight is not None:
            _wrap(flight, "_lock", "SingleFlight._lock", witness)
    gate = getattr(db, "admission_gate", None)
    if gate is not None:
        _wrap(gate, "_cond", "AdmissionGate._cond", witness)
    pc = getattr(db, "plan_cache", None)
    if pc is not None:
        _wrap(pc, "_lock", "PlanCache._lock", witness)


def instrument_modules(witness: LockWitness) -> list:
    """Wrap the process-wide module locks (dispatch, step cache, open-DB
    registry, device key sequencing).  Returns ``(obj, attr, original)``
    restore records for :func:`uninstall`."""
    from repro.core import parallel, session
    from repro.core.device_cache import DeviceBlockKeys
    restores = []
    for obj, attr, name in [
            (parallel, "_DEVICE_DISPATCH_LOCK", "_DEVICE_DISPATCH_LOCK"),
            (parallel, "_STEP_CACHE_LOCK", "_STEP_CACHE_LOCK"),
            (session, "_open_lock", "session._open_lock"),
            (DeviceBlockKeys, "_seq_lock", "DeviceBlockKeys._seq_lock")]:
        orig = getattr(obj, attr, None)
        if orig is not None and not isinstance(orig, _WitnessedLock):
            restores.append((obj, attr, orig))
            _wrap(obj, attr, name, witness)
    return restores


_installed: Optional[tuple] = None


def install(witness: LockWitness) -> None:
    """Instrument module locks now and every Database built from here on
    (by wrapping ``Database.__init__``).  Idempotent per process; call
    :func:`uninstall` to restore."""
    global _installed
    if _installed is not None:
        return
    from repro.core import session
    restores = instrument_modules(witness)
    orig_init = session.Database.__init__

    def witnessed_init(self, *args, **kwargs):
        orig_init(self, *args, **kwargs)
        instrument_database(self, witness)

    session.Database.__init__ = witnessed_init
    _installed = (orig_init, restores)


def uninstall() -> None:
    global _installed
    if _installed is None:
        return
    from repro.core import session
    orig_init, restores = _installed
    session.Database.__init__ = orig_init
    for obj, attr, orig in restores:
        setattr(obj, attr, orig)
    _installed = None
