"""Invariant lint + lock-order witness for the embedded engine.

MonetDBLite's pitch is an embedded engine that is safe to link into a
multi-threaded host process with zero administration — which makes the
concurrency and resource contracts of the core (budget accounting,
spill-file lifecycle, serialized device dispatch) API guarantees, not
implementation details.  PRs 2-6 found a ``would_exceed``+``pin`` TOCTOU,
spill-file leaks on exception paths and an XLA collective rendezvous
deadlock entirely by hand; this package encodes those hand-won invariants
as checked rules so the next regression is caught by CI:

* ``repro.analysis.lint`` — an AST-walking static pass with five
  project-specific checkers (``python -m repro.analysis.lint src/``):
  guarded-by, check-then-act, acquire-release pairing, device-dispatch
  and stats-discipline.  See ``checkers.py`` for the rules and
  ``README.md`` for how to annotate code.
* ``repro.analysis.witness`` — an opt-in runtime shim that wraps the
  engine's named locks, records the acquisition-order graph while the
  concurrent test suite runs, and fails on cycles or on blocking
  condition waits taken while other locks are held — the dynamic
  deadlock shapes the static pass cannot see.
"""

from .core import Finding, SourceFile, run_lint  # noqa: F401
