"""The five invariant checkers.

Each checker is a pure function ``SourceFile -> list[Finding]``; the rule
configuration (guarded-attribute registry, acquire/release pairs, dispatch
producers, stats aliases) lives in ``registry.py``.  Checkers are lexical
and deliberately conservative: they encode the specific bug classes PRs
2-6 fixed by hand, not a general alias analysis — see README.md for the
exact contracts and their escape hatches.
"""

from __future__ import annotations

import ast
from dataclasses import dataclass
from typing import Callable, Iterable, Optional

from .core import Finding, SourceFile, expr_repr, in_core
from .registry import (ACQUIRE_PAIRS, DISPATCH_LOCK, DISPATCH_PRODUCERS,
                       GUARDED_REGISTRY, MUTATING_METHODS,
                       STATS_MANAGER_ALIASES, STATS_OWNER_CLASSES,
                       TOCTOU_MUTATORS, TOCTOU_PREDICATES)


@dataclass(frozen=True)
class Checker:
    rule: str
    doc: str
    fn: Callable[[SourceFile], list]

    def check(self, src: SourceFile) -> list:
        return self.fn(src)


def _call_name(node: ast.Call) -> Optional[str]:
    if isinstance(node.func, ast.Name):
        return node.func.id
    if isinstance(node.func, ast.Attribute):
        return node.func.attr
    return None


def _stmt_bodies(fn: ast.AST) -> Iterable[list]:
    """Every statement list in a function (bodies, orelse, handlers,
    finally) — the granularity at which guard-clause flow is visible."""
    for node in ast.walk(fn):
        for attr in ("body", "orelse", "finalbody"):
            block = getattr(node, attr, None)
            if isinstance(block, list) and block \
                    and isinstance(block[0], ast.stmt):
                yield block


# ---------------------------------------------------------------------------
# 1. guarded-by
# ---------------------------------------------------------------------------


def check_guarded_by(src: SourceFile) -> list:
    defined = {n.name for n in ast.walk(src.tree)
               if isinstance(n, ast.ClassDef)}
    guards: dict[str, tuple] = {}
    for cls, attrs in GUARDED_REGISTRY.items():
        if cls in defined:
            for attr, lock in attrs.items():
                guards[attr] = (cls, lock)
    guards.update(src.comment_guards)
    if not guards:
        return []
    findings = []
    for fu in src.functions:
        for node in ast.walk(fu.node):
            if not isinstance(node, ast.Attribute):
                continue
            hit = guards.get(node.attr)
            if hit is None:
                continue
            owner, lock = hit
            recv = expr_repr(node.value)
            if recv in ("self", "cls"):
                # only the owning class's own methods; construction in
                # __init__ happens before the object is shared
                if fu.cls != owner or fu.name == "__init__":
                    continue
            if (recv, lock) in fu.held_at(node):
                continue
            findings.append(Finding(
                "guarded-by", src.path, node.lineno,
                f"{recv}.{node.attr} is declared guarded by {lock} "
                f"(on {owner}) but is accessed without holding "
                f"{recv or 'module'}.{lock}"))
    return findings


# ---------------------------------------------------------------------------
# 2. check-then-act
# ---------------------------------------------------------------------------


def _predicate_receivers(test: ast.AST) -> set:
    """Receivers whose state the if-condition samples: ``bm`` for
    ``bm.would_exceed(n)``, ``devman`` for ``key in devman``."""
    out = set()
    for node in ast.walk(test):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in TOCTOU_PREDICATES:
            out.add(expr_repr(node.func.value))
        elif isinstance(node, ast.Compare) \
                and any(isinstance(op, (ast.In, ast.NotIn))
                        for op in node.ops):
            for comp in node.comparators:
                if isinstance(comp, (ast.Name, ast.Attribute)):
                    out.add(expr_repr(comp))
    return out


def _ends_flow(stmts: list) -> bool:
    return bool(stmts) and isinstance(
        stmts[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


_FRESH_CTORS = {"dict", "list", "set", "OrderedDict", "defaultdict", "deque"}


def _local_fresh_names(fn: ast.AST) -> set:
    """Local names bound to a freshly constructed container inside this
    function — predicates on those are not shared state (the dedup-list
    idiom), so check-then-act does not apply to them."""
    fresh: set = set()
    for node in ast.walk(fn):
        value, targets = None, []
        if isinstance(node, ast.Assign):
            value, targets = node.value, node.targets
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            value, targets = node.value, [node.target]
        if value is None:
            continue
        if isinstance(value, (ast.Dict, ast.List, ast.Set, ast.ListComp,
                              ast.SetComp, ast.DictComp)) \
                or (isinstance(value, ast.Call)
                    and _call_name(value) in _FRESH_CTORS):
            fresh.update(t.id for t in targets if isinstance(t, ast.Name))
    return fresh


def check_toctou(src: SourceFile) -> list:
    findings = []
    for fu in src.functions:
        fresh = _local_fresh_names(fu.node)
        for body in _stmt_bodies(fu.node):
            for i, stmt in enumerate(body):
                if not isinstance(stmt, ast.If):
                    continue
                if fu.held_at(stmt):
                    continue       # predicate sampled under a lock
                preds = _predicate_receivers(stmt.test) - fresh
                if not preds:
                    continue
                # the gated region: both branches, plus — when the taken
                # branch is a guard clause that ends control flow — the
                # rest of the enclosing block
                region = list(stmt.body) + list(stmt.orelse)
                if _ends_flow(stmt.body):
                    region += body[i + 1:]
                flagged = False
                for rn in region:
                    if flagged:
                        break
                    for node in ast.walk(rn):
                        if isinstance(node, ast.Call) \
                                and isinstance(node.func, ast.Attribute) \
                                and node.func.attr in TOCTOU_MUTATORS \
                                and expr_repr(node.func.value) in preds \
                                and not fu.held_at(node):
                            findings.append(Finding(
                                "check-then-act", src.path, stmt.lineno,
                                f"predicate on "
                                f"{expr_repr(node.func.value)} gates "
                                f"{node.func.attr}() (line {node.lineno}) "
                                f"outside any lock — two threads can both "
                                f"pass the check; use an atomic "
                                f"reserve-or-fail helper (try_pin-style)"))
                            flagged = True
                            break
    return findings


# ---------------------------------------------------------------------------
# 3. acquire-release pairing
# ---------------------------------------------------------------------------


def _protected_nodes(fn: ast.AST) -> set:
    """ids of nodes lexically inside a finally block or except handler —
    the regions that still run when the protected body raises."""
    out: set = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Try):
            regions = list(node.finalbody)
            for h in node.handlers:
                regions.extend(h.body)
            for stmt in regions:
                out.update(id(n) for n in ast.walk(stmt))
    return out


def check_pairing(src: SourceFile) -> list:
    methods: dict[str, dict] = {}
    for fu in src.functions:
        if fu.cls:
            methods.setdefault(fu.cls, {})[fu.name] = fu
    findings = []
    for fu in src.functions:
        if fu.transfers:
            continue
        with_calls = set()
        for node in ast.walk(fu.node):
            if isinstance(node, ast.With):
                with_calls.update(id(item.context_expr)
                                  for item in node.items)
        acquires = []
        for node in ast.walk(fu.node):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ACQUIRE_PAIRS \
                    and id(node) not in with_calls:
                acquires.append(node)
        if not acquires:
            continue
        protected = _protected_nodes(fu.node)
        released = {node.func.attr for node in ast.walk(fu.node)
                    if isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and id(node) in protected}
        for node in acquires:
            name = node.func.attr
            if node.lineno in src.transfer_lines:
                continue
            if ACQUIRE_PAIRS[name] & released:
                continue
            if fu.name == "__enter__" and fu.cls:
                ex = methods.get(fu.cls, {}).get("__exit__")
                if ex is not None and any(
                        isinstance(n, ast.Call)
                        and isinstance(n.func, ast.Attribute)
                        and n.func.attr in ACQUIRE_PAIRS[name]
                        for n in ast.walk(ex.node)):
                    continue
            findings.append(Finding(
                "acquire-release", src.path, node.lineno,
                f"{name}() is not exception-safe: no "
                f"{'/'.join(sorted(ACQUIRE_PAIRS[name]))} in a "
                f"finally/except of this function, not a `with` context, "
                f"and no # transfers-ownership annotation"))
    return findings


# ---------------------------------------------------------------------------
# 4. device-dispatch
# ---------------------------------------------------------------------------


def check_dispatch(src: SourceFile) -> list:
    findings = []
    annotated = {fu.name for fu in src.functions
                 if ("", DISPATCH_LOCK) in fu.requires}
    for fu in src.functions:
        handles: set = set()
        for node in ast.walk(fu.node):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call) \
                    and _call_name(node.value) in DISPATCH_PRODUCERS:
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        handles.add(t.id)
                    elif isinstance(t, ast.Tuple):
                        handles.update(e.id for e in t.elts
                                       if isinstance(e, ast.Name))
        for node in ast.walk(fu.node):
            if not isinstance(node, ast.Call):
                continue
            held = ("", DISPATCH_LOCK) in fu.held_at(node)
            if isinstance(node.func, ast.Name) and node.func.id in handles \
                    and not held:
                findings.append(Finding(
                    "device-dispatch", src.path, node.lineno,
                    f"{node.func.id}() executes a jitted collective step "
                    f"outside {DISPATCH_LOCK} — concurrent collective "
                    f"dispatch deadlocks the XLA rendezvous"))
            name = _call_name(node)
            if name in annotated and name != fu.name and not held:
                findings.append(Finding(
                    "device-dispatch", src.path, node.lineno,
                    f"{name}() is annotated requires-lock: "
                    f"{DISPATCH_LOCK} but is called here without it"))
    return findings


# ---------------------------------------------------------------------------
# 5. stats discipline
# ---------------------------------------------------------------------------


def _is_lock_ctor(node: ast.AST) -> bool:
    return isinstance(node, ast.Call) and _call_name(node) in {
        "Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}


def _is_mutable_ctor(node: ast.AST) -> bool:
    if isinstance(node, (ast.Dict, ast.List, ast.Set)):
        return True
    return isinstance(node, ast.Call) and _call_name(node) in {
        "dict", "list", "set", "OrderedDict", "defaultdict", "deque"}


def _module_assigns(tree: ast.Module):
    for node in tree.body:
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    yield t.id, node
        elif isinstance(node, ast.AnnAssign) and node.value is not None \
                and isinstance(node.target, ast.Name):
            yield node.target.id, node


def _associated_lock(name: str, locks: set) -> Optional[str]:
    exact = f"{name}_LOCK"
    for lk in sorted(locks):
        if lk.upper() == exact.upper():
            return lk
    tok = name.strip("_").split("_")[0].lower()
    for lk in sorted(locks):
        if lk.strip("_").split("_")[0].lower() == tok:
            return lk
    return None


def check_stats(src: SourceFile) -> list:
    if not in_core(src.path):
        return []
    findings = []

    # (a) direct writes to a shared stats object
    for fu in src.functions:
        for node in ast.walk(fu.node):
            if not isinstance(node, (ast.Assign, ast.AugAssign)):
                continue
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if not isinstance(t, ast.Attribute):
                    continue
                base = t.value
                owner_repr = None
                if isinstance(base, ast.Attribute) and base.attr == "stats":
                    rep = expr_repr(base.value)
                    if rep == "self":
                        if fu.cls in STATS_OWNER_CLASSES:
                            owner_repr = "self"
                    elif rep.split(".")[-1] in STATS_MANAGER_ALIASES:
                        owner_repr = rep
                elif isinstance(base, ast.Name) \
                        and base.id in STATS_MANAGER_ALIASES:
                    owner_repr = base.id
                if owner_repr is None:
                    continue
                if any(r == owner_repr for r, _ in fu.held_at(node)):
                    continue
                findings.append(Finding(
                    "stats-discipline", src.path, node.lineno,
                    f"unlocked write to shared stats "
                    f"({expr_repr(t)}) — an unsynchronized "
                    f"read-modify-write loses updates; use the manager's "
                    f"bump() helper or a stats_base/stats_apply_delta "
                    f"window"))

    # (b) module-level mutable caches need an associated module lock
    mod_locks = {n for n, node in _module_assigns(src.tree)
                 if _is_lock_ctor(node.value)}
    mutables = {n: node.lineno for n, node in _module_assigns(src.tree)
                if _is_mutable_ctor(node.value)}
    if mutables:
        for fu in src.functions:
            for node in ast.walk(fu.node):
                name = None
                if isinstance(node, (ast.Assign, ast.AugAssign, ast.Delete)):
                    targets = node.targets if isinstance(
                        node, (ast.Assign, ast.Delete)) else [node.target]
                    for t in targets:
                        if isinstance(t, ast.Subscript) \
                                and isinstance(t.value, ast.Name) \
                                and t.value.id in mutables:
                            name = t.value.id
                elif isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Attribute) \
                        and isinstance(node.func.value, ast.Name) \
                        and node.func.value.id in mutables \
                        and node.func.attr in MUTATING_METHODS:
                    name = node.func.value.id
                if name is None:
                    continue
                lock = src.module_guards.get(name) \
                    or _associated_lock(name, mod_locks)
                if lock is None:
                    findings.append(Finding(
                        "stats-discipline", src.path, node.lineno,
                        f"module-level mutable {name} (line "
                        f"{mutables[name]}) is mutated at runtime but has "
                        f"no associated module-level lock"))
                elif ("", lock) not in fu.held_at(node):
                    findings.append(Finding(
                        "stats-discipline", src.path, node.lineno,
                        f"mutation of module-level {name} without "
                        f"holding {lock}"))
    return findings


CHECKERS = [
    Checker("guarded-by",
            "declared-guarded attributes are only touched under their lock",
            check_guarded_by),
    Checker("check-then-act",
            "predicates must not gate mutations outside the same lock",
            check_toctou),
    Checker("acquire-release",
            "resource acquires must be exception-safe",
            check_pairing),
    Checker("device-dispatch",
            "jitted collective steps run only under _DEVICE_DISPATCH_LOCK",
            check_dispatch),
    Checker("stats-discipline",
            "shared stats mutate through locked helpers; module caches "
            "have locks",
            check_stats),
]
