"""CLI entry point: ``python -m repro.analysis.lint src/``.

Exits 0 when no checker reports a finding, 1 otherwise.  ``--rule`` can
be given multiple times to run a subset of checkers; ``--list`` prints
the active rules.
"""

from __future__ import annotations

import argparse
import sys

from .core import run_lint


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Invariant lint for the engine's concurrency and "
                    "resource contracts.")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files or directories to lint (default: src)")
    parser.add_argument("--rule", action="append", dest="rules",
                        metavar="RULE",
                        help="run only this rule (repeatable)")
    parser.add_argument("--list", action="store_true",
                        help="list the registered checkers and exit")
    args = parser.parse_args(argv)

    if args.list:
        from .checkers import CHECKERS
        for c in CHECKERS:
            print(f"{c.rule:18s} {c.doc}")
        return 0

    findings = run_lint(args.paths or ["src"], rules=args.rules)
    for f in findings:
        print(f)
    if findings:
        print(f"\n{len(findings)} finding(s).", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
