"""Shared machinery for the invariant lint pass.

The framework is deliberately lexical: a ``with <recv>.<lock>:`` block (or
a ``# requires-lock:`` annotation on the enclosing function) establishes
that ``(<recv>, <lock>)`` is held for every statement inside it, and the
checkers in ``checkers.py`` compare the locks held at an AST node against
what the rule demands there.  Nested ``def``/``lambda`` bodies execute
later, outside the ``with`` — they inherit *nothing*.

Annotation comments the framework understands (see ``README.md``):

``# guarded-by: <lock>``
    On an attribute assignment (``self.x = ...`` in a method, or a
    class-body / module-level assignment): declares that every later
    read/write of the attribute must hold ``<lock>`` on the same receiver.
``# requires-lock: <lock>``
    On (or directly above) a ``def``: the function is only ever called
    with ``<lock>`` held, so its body is analyzed as if inside the
    ``with``.  Uppercase names denote module-level locks.
``# transfers-ownership``
    On (or directly above) a ``def``, or on an acquire call: the acquired
    resource is handed to the caller / another owner, which releases it —
    exempts the function from the local acquire-release pairing rule.
``# lint: ignore[<rule>]``
    On a flagged line: suppress that rule there.  ``core/`` carries no
    suppressions; fixtures and genuinely-special sites may.
"""

from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Iterable, Optional

_GUARDED_RE = re.compile(r"#\s*guarded-by:\s*([A-Za-z_][A-Za-z_0-9]*)")
_REQUIRES_RE = re.compile(r"#\s*requires-lock:\s*([A-Za-z_][A-Za-z_0-9]*)")
_TRANSFERS_RE = re.compile(r"#\s*transfers-ownership")
_IGNORE_RE = re.compile(r"#\s*lint:\s*ignore\[([a-z\-*,\s]+)\]")

# Attribute / module-global names that denote locks: _lock, _cond,
# _query_cond, _seq_lock, _STEP_CACHE_LOCK, _DEVICE_DISPATCH_LOCK, ...
_LOCKISH_RE = re.compile(r"(?:^|_)(?:lock|cond|mutex)s?$", re.IGNORECASE)


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


def expr_repr(node: ast.AST) -> str:
    """Dotted source text of a receiver expression (``self.bufman``)."""
    try:
        return ast.unparse(node)
    except Exception:                      # pragma: no cover - malformed ast
        return "?"


def lock_token(node: ast.AST) -> Optional[tuple]:
    """``(receiver, lockname)`` if ``node`` denotes a lock, else None.

    ``with self._lock:`` -> ("self", "_lock"); ``with gate._cond:`` ->
    ("gate", "_cond"); ``with _STEP_CACHE_LOCK:`` -> ("", "_STEP_CACHE_LOCK").
    """
    if isinstance(node, ast.Attribute) and _LOCKISH_RE.search(node.attr):
        return (expr_repr(node.value), node.attr)
    if isinstance(node, ast.Name) and _LOCKISH_RE.search(node.id):
        return ("", node.id)
    return None


class LockScopeMap:
    """Maps every AST node of one function body to the lexical set of
    held ``(receiver, lockname)`` pairs.  Nested function/lambda bodies
    reset to the empty set (they run outside the ``with``)."""

    def __init__(self, func: ast.AST, base: frozenset = frozenset()):
        self._held: dict[int, frozenset] = {}
        self._walk_stmts(getattr(func, "body", []), base)

    def at(self, node: ast.AST) -> frozenset:
        return self._held.get(id(node), frozenset())

    def _walk_stmts(self, stmts: Iterable[ast.AST], held: frozenset) -> None:
        for s in stmts:
            self._walk(s, held)

    def _walk(self, node: ast.AST, held: frozenset) -> None:
        self._held[id(node)] = held
        if isinstance(node, ast.With):
            for item in node.items:
                self._walk(item.context_expr, held)
                if item.optional_vars is not None:
                    self._walk(item.optional_vars, held)
            got = {t for item in node.items
                   if (t := lock_token(item.context_expr)) is not None}
            self._walk_stmts(node.body, held | frozenset(got))
            return
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                self._walk(dec, held)
            self._walk_stmts(node.body, frozenset())
            return
        if isinstance(node, ast.Lambda):
            self._walk(node.body, frozenset())
            return
        for child in ast.iter_child_nodes(node):
            self._walk(child, held)


@dataclass
class FuncUnit:
    """One analysis unit: a module-level function or a (possibly nested-
    class) method, with its lexical lock map and annotations resolved."""

    node: ast.AST
    cls: Optional[str]            # enclosing class name, if a method
    name: str
    requires: frozenset           # locks the caller is declared to hold
    transfers: bool               # function-level # transfers-ownership
    scopes: LockScopeMap = field(init=False)

    def __post_init__(self):
        self.scopes = LockScopeMap(self.node, base=self.requires)

    def held_at(self, node: ast.AST) -> frozenset:
        return self.scopes.at(node)


class SourceFile:
    """One parsed module: source text, AST, comment directives, guarded-
    attribute declarations and per-function analysis units."""

    def __init__(self, path: str, text: Optional[str] = None):
        self.path = path
        if text is None:
            with open(path, "r", encoding="utf-8") as f:
                text = f.read()
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)

        # ---- comment directives, by line number ----
        self.guard_comments: dict[int, str] = {}
        self.require_comments: dict[int, str] = {}
        self.transfer_lines: set[int] = set()
        self.ignores: dict[int, set] = {}
        for i, ln in enumerate(self.lines, start=1):
            if (m := _GUARDED_RE.search(ln)):
                self.guard_comments[i] = m.group(1)
            if (m := _REQUIRES_RE.search(ln)):
                self.require_comments[i] = m.group(1)
            if _TRANSFERS_RE.search(ln):
                self.transfer_lines.add(i)
            if (m := _IGNORE_RE.search(ln)):
                self.ignores[i] = {r.strip() for r in m.group(1).split(",")}

        # ---- guarded attributes declared by comment ----
        # {attr: (owning class or None for module level, lockname)}
        self.comment_guards: dict[str, tuple] = {}
        for cls in [n for n in ast.walk(self.tree)
                    if isinstance(n, ast.ClassDef)]:
            for node in ast.walk(cls):
                if not isinstance(node, (ast.Assign, ast.AnnAssign)):
                    continue
                lock = self.guard_comments.get(node.lineno)
                if lock is None:
                    continue
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    if isinstance(t, ast.Attribute) \
                            and isinstance(t.value, ast.Name) \
                            and t.value.id == "self":
                        self.comment_guards[t.attr] = (cls.name, lock)
                    elif isinstance(t, ast.Name):   # class-body attribute
                        self.comment_guards[t.id] = (cls.name, lock)

        # ---- module-level guarded globals by comment ----
        self.module_guards: dict[str, str] = {}
        for node in self.tree.body:
            if isinstance(node, (ast.Assign, ast.AnnAssign)):
                lock = self.guard_comments.get(node.lineno)
                if lock is None:
                    continue
                targets = node.targets if isinstance(node, ast.Assign) \
                    else [node.target]
                for t in targets:
                    if isinstance(t, ast.Name):
                        self.module_guards[t.id] = lock

        # ---- function units (module-level defs + methods) ----
        self.functions: list[FuncUnit] = []
        self._collect_functions(self.tree, None)

    # -- directive helpers ----------------------------------------------------
    def _near_def(self, table: dict, lineno: int):
        """Directive on the def line or up to two lines above it."""
        for ln in (lineno, lineno - 1, lineno - 2):
            if ln in table:
                return table[ln]
        return None

    def _collect_functions(self, parent: ast.AST, cls: Optional[str]) -> None:
        for node in ast.iter_child_nodes(parent):
            if isinstance(node, ast.ClassDef):
                self._collect_functions(node, node.name)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                req = self._near_def(self.require_comments, node.lineno)
                if req is None:
                    requires = frozenset()
                elif req.isupper():
                    requires = frozenset({("", req)})     # module-level lock
                else:
                    requires = frozenset({("self", req), ("cls", req)})
                transfers = any(
                    ln in self.transfer_lines
                    for ln in (node.lineno, node.lineno - 1, node.lineno - 2))
                self.functions.append(
                    FuncUnit(node, cls, node.name, requires, transfers))
                # nested defs are analyzed within the parent unit (empty
                # held set) — do not also lift them to their own unit

    def ignored(self, rule: str, line: int) -> bool:
        rules = self.ignores.get(line)
        return rules is not None and (rule in rules or "*" in rules)


def in_core(path: str) -> bool:
    """True for engine-core modules (and anything outside ``src/repro`` —
    test fixtures exercise every rule).  Non-core subpackages (models/,
    kernels/, launch/, ...) are exempt from the core-scoped rules."""
    parts = os.path.normpath(path).split(os.sep)
    if "repro" not in parts:
        return True
    return "core" in parts or "analysis" in parts


def collect_files(paths: Iterable[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
    return out


def run_lint(paths: Iterable[str], rules: Optional[Iterable[str]] = None
             ) -> list[Finding]:
    """Parse every ``.py`` under ``paths`` and run the registered
    checkers; returns findings sorted by (path, line)."""
    from .checkers import CHECKERS
    selected = [c for c in CHECKERS
                if rules is None or c.rule in set(rules)]
    findings: list[Finding] = []
    for path in collect_files(paths):
        try:
            src = SourceFile(path)
        except SyntaxError as e:
            findings.append(Finding("parse-error", path, e.lineno or 0,
                                    f"syntax error: {e.msg}"))
            continue
        for checker in selected:
            findings.extend(f for f in checker.check(src)
                            if not src.ignored(f.rule, f.line))
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))
