"""Project-specific rule configuration: which attributes are guarded by
which locks, which call pairs must be exception-safe, and which names
produce jitted collective dispatch handles.

The guarded-by registry is seeded for the engine's five shared-state
classes; new fields can be declared either here or inline with a
``# guarded-by: <lock>`` comment on the assignment (see README.md).
"""

from __future__ import annotations

# ---------------------------------------------------------------------------
# guarded-by: class -> {attribute: lock attribute}
# ---------------------------------------------------------------------------
# Applies inside the defining module: `self.<attr>` in the owning class's
# methods and `<recv>.<attr>` anywhere (e.g. the admission ticket touching
# `gate._host_reserved`) must hold the named lock on the same receiver.
# `__init__` of the owning class (construction) is exempt.

GUARDED_REGISTRY: dict[str, dict[str, str]] = {
    "BufferManager": {
        "_files": "_lock",
        "_seq": "_lock",
        "_spill_dir": "_lock",
        "_dir_ready": "_lock",
        "_active_queries": "_query_cond",
        "_cleanup_deferred": "_query_cond",
    },
    "DeviceBufferManager": {
        "_blocks": "_lock",
        "_host": "_lock",
        "_resident": "_lock",
        "_table_hits": "_lock",
    },
    "DeltaTable": {
        # merge-on-read memoization: concurrent readers race to build the
        # merged column dict; the lock makes the merge happen once
        "_merged": "_merge_lock",
    },
    "AdmissionGate": {
        "_host_reserved": "_cond",
        "_device_reserved": "_cond",
    },
    "PlanCache": {
        "_entries": "_lock",
        "_cards": "_lock",
    },
    "SingleFlight": {
        "_calls": "_lock",
    },
}

# ---------------------------------------------------------------------------
# check-then-act: predicate names whose result must not gate a mutation
# outside a lock (the pre-PR-6 `would_exceed()` + `pin()` bug class), and
# the mutators they must not gate.  `try_pin` is the atomic replacement
# and is deliberately NOT a predicate.
# ---------------------------------------------------------------------------

TOCTOU_PREDICATES = {"would_exceed", "contains", "fits"}
TOCTOU_MUTATORS = {"pin", "put", "adopt", "add", "append", "reserve"}

# ---------------------------------------------------------------------------
# acquire-release pairing: acquire method -> acceptable releases.  A call
# to an acquire must be exception-safe: used as a `with` context, released
# in a `finally`/`except` within the same function, paired through
# `__enter__`/`__exit__`, or annotated `# transfers-ownership`.
# ---------------------------------------------------------------------------

ACQUIRE_PAIRS: dict[str, frozenset] = {
    "pin": frozenset({"unpin", "drop"}),          # byte pins + device keys
    "try_pin": frozenset({"unpin"}),
    "acquire_lock": frozenset({"release_lock"}),
    "new_spill_file": frozenset({"release_file", "abort"}),
    "begin_query": frozenset({"end_query"}),
    "admit": frozenset({"release"}),              # gate reserve -> release
}

# Methods returning an RAII object (safe when used as a `with` context).
CONTEXT_ACQUIRES = {"pinned", "query_scope", "admit"}

# ---------------------------------------------------------------------------
# device-dispatch: calling a handle returned by one of these factories
# lowers/executes a jitted collective step; a concurrent dispatch
# deadlocks the XLA rendezvous (PR 6), so every such call must hold
# _DEVICE_DISPATCH_LOCK (lexically or via `# requires-lock`).  AOT
# inspection (`handle.lower(...)`) does not execute and is not dispatch.
# ---------------------------------------------------------------------------

DISPATCH_PRODUCERS = {"_cached_batch_step", "_cached_query_step",
                      "build_batch_step", "build_query_step",
                      "_cached_join_build_step", "_cached_join_probe_step",
                      "build_join_build_step", "build_join_probe_step",
                      "_cached_scalar_step", "build_scalar_step",
                      "_cached_assemble_step", "build_assemble_step"}
DISPATCH_LOCK = "_DEVICE_DISPATCH_LOCK"

# ---------------------------------------------------------------------------
# stats discipline: classes whose `self.stats` is the SHARED BufferStats /
# AdmissionStats object, and local-variable aliases that reach a shared
# stats object from operator code.  Direct `X.stats.field += n` on these is
# an unlocked read-modify-write (lost updates) — increments go through the
# manager's locked `bump(**deltas)` helper or the `stats_base` /
# `stats_apply_delta` delta window instead.  Per-query `ExecStats`
# (`self.stats` on Executor) is thread-local and exempt.
# ---------------------------------------------------------------------------

STATS_OWNER_CLASSES = {"BufferManager", "DeviceBufferManager",
                       "AdmissionGate"}
STATS_MANAGER_ALIASES = {"bm", "bufman", "devman", "dm",
                         "buffer_manager", "device_manager", "bstats"}

# module-level mutable containers that functions mutate must have a
# module-level lock whose name shares their leading token (e.g.
# _STEP_CACHE / _STEP_CACHE_LOCK, _open_dirs / _open_lock) or an explicit
# `# guarded-by:` comment; import-time (module-body) mutation is exempt.
MUTATING_METHODS = {"append", "add", "pop", "popitem", "setdefault",
                    "update", "clear", "extend", "insert", "discard",
                    "remove"}
