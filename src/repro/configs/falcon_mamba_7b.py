"""falcon-mamba-7b [ssm]: 64L d4096 attn-free V65024, ssm_state=16 —
Mamba-1 architecture [arXiv:2410.05355; unverified].  Sub-quadratic:
long_500k decode carries only the (B, d_inner, N) recurrent state."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
    d_ff=0, vocab=65024,
    ssm_state=16, ssm_version=1, ssm_expand=2, ssm_conv=4, ssm_chunk=16,
)
