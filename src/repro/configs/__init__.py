from .registry import ARCH_IDS, SHAPES, all_cells, cells, get_config  # noqa
