"""dbrx-132b [moe]: 40L d6144 48H (GQA kv=8) ff10752/expert V100352,
16 experts top-4 fine-grained [hf:databricks/dbrx-base; unverified].
Experts sharded over the model axis (expert parallelism)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, d_head=128,
    n_experts=16, top_k=4, capacity_factor=1.25,
    rope_theta=500_000.0, act="swiglu", router_group_tokens=512,
)
