"""h2o-danube-3-4b [dense]: 24L d3840 32H (GQA kv=8) ff10240 V32000 —
llama+mistral mix, sliding-window attention [arXiv:2401.16818; unverified].
SWA makes it sub-quadratic: long_500k runs with a window-sized ring cache."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
    d_ff=10240, vocab=32000, d_head=120,
    sliding_window=4096, rope_theta=10_000.0, act="swiglu",
)
