"""Architecture registry: --arch <id> -> ModelConfig + input shapes.

One module per assigned architecture in this package; each exposes CONFIG.
Shapes are the assigned LM shape set; applicability skips are encoded here
(see DESIGN.md §Arch-applicability)."""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from ..models.config import ModelConfig

ARCH_IDS = [
    "qwen2_5_14b",
    "phi3_medium_14b",
    "h2o_danube3_4b",
    "deepseek_7b",
    "falcon_mamba_7b",
    "llava_next_34b",
    "seamless_m4t_large_v2",
    "dbrx_132b",
    "moonshot_v1_16b_a3b",
    "zamba2_2_7b",
]

# canonical external ids (with dashes) also accepted
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


@dataclass(frozen=True)
class ShapeCell:
    name: str                  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = [
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
]


def get_config(arch: str) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def sub_quadratic(cfg: ModelConfig) -> bool:
    """long_500k applicability: SSM/hybrid state or sliding-window attn."""
    return cfg.family in ("ssm", "hybrid") or cfg.sliding_window is not None


def cells(arch: str):
    """The (arch x shape) cells to dry-run; long_500k skipped for pure
    full-attention archs (recorded skip, DESIGN.md)."""
    cfg = get_config(arch)
    out = []
    for s in SHAPES:
        if s.name == "long_500k" and not sub_quadratic(cfg):
            continue
        out.append(s)
    return out


def all_cells():
    for a in ARCH_IDS:
        for s in cells(a):
            yield a, s
