"""llava-next-34b [vlm]: 60L d7168 56H (GQA kv=8) ff20480 V64000 — anyres
tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].  The vision
frontend is a stub: input_specs() feeds precomputed patch embeddings
(B, T, d) for train/prefill; decode is standard token decode."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, d_head=128,
    rope_theta=5_000_000.0, act="swiglu", embeds_input=True,
)
