"""zamba2-2.7b [hybrid]: 54L d2560 32H (kv=32) ff10240 V32000,
ssm_state=64 — Mamba-2 blocks + a weight-shared attention block applied
every 6 layers [arXiv:2411.15242; hf].  Sub-quadratic (SSM state + one
shared attn cache): long_500k runs."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000, d_head=80,
    ssm_state=64, ssm_version=2, ssm_expand=2, ssm_conv=4,
    ssm_head_dim=64, ssm_chunk=64,
    attn_every=6, act="gelu",
)
