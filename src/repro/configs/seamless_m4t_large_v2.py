"""seamless-m4t-large-v2 [audio]: enc-dec, 24L enc + 24L dec, d1024 16H
(kv=16) ff8192 V256206 [arXiv:2308.11596; hf].  The audio frontend is a
stub: input_specs() provides precomputed frame embeddings for the encoder;
decode shapes lower the *decoder* step over a precomputed encoder memory."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab=256206, d_head=64,
    act="gelu", cross_attn=True, embeds_input=False,
)
