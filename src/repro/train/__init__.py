from .optimizer import AdamWConfig, adamw_update, init_opt_state  # noqa
from .train_step import make_train_step  # noqa
