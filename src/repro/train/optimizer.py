"""AdamW with distributed-training machinery: sharded (ZeRO-1-style) state,
gradient clipping, cosine schedule, and optional int8 gradient compression
with error feedback.

No optax dependency — the optimizer is a pair of pure functions over
pytrees, with a spec pytree mirroring the parameter sharding so optimizer
state shards exactly like ZeRO-1 (each state leaf inherits the param's
PartitionSpec; for replicated params the m/v moments additionally shard
their first axis over ``data`` when divisible — the classic ZeRO trick).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # gradient compression (int8 + error feedback); applied before the DP
    # all-reduce so collective bytes drop 4x on the wire
    compress_grads: bool = False


def schedule(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params):
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_spec(param_spec):
    """ZeRO-1: moments shard like the params themselves."""
    return {"m": param_spec, "v": param_spec, "step": P()}


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads), g


# ---------------------------------------------------------------------------
# int8 gradient compression (error feedback)
# ---------------------------------------------------------------------------


def compress_int8(x):
    """Per-tensor symmetric int8 quantization; returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_tree(grads, errors):
    """Quantize grads+error-feedback; returns (q_tree, scales, new_errors).

    The all-reduce then moves int8 (4x fewer wire bytes); the residual is
    carried to the next step (error feedback keeps convergence)."""
    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = compress_int8(x)
        deq = decompress_int8(q, s)
        return q, s, x - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errors)
    qs, ss, ne = zip(*[one(g, e) for g, e in zip(flat_g, flat_e)])
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, ss),
            jax.tree.unflatten(treedef, ne))


# ---------------------------------------------------------------------------
# the update
# ---------------------------------------------------------------------------


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        # decoupled weight decay on matrices only (ndim >= 2)
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"lr": lr, "grad_norm": gnorm}
