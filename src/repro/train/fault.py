"""Fault tolerance & straggler mitigation for 1000+-node runs.

Mechanisms (single-host container: the *protocols* are implemented and unit
tested; multi-host wiring is the jax.distributed bootstrap in launch/train):

* **Heartbeats** — every host touches ``hb/<host>.json`` with step + wall
  time; the coordinator scans for hosts whose heartbeat is older than
  ``dead_after_s`` and declares the job degraded -> restart from latest
  checkpoint on the surviving mesh (elastic re-shard via checkpoint.py's
  full-shape leaves).
* **Straggler detection** — per-step durations per host in a ring buffer;
  a host whose rolling median exceeds ``straggler_factor`` x the fleet
  median is flagged.  Remedies, in order: re-balance input shards away from
  it (cheap), then exclude + elastic restart (expensive).  TPU SPMD steps
  are synchronous, so mitigation is always at the data/input layer.
* **Preemption-safe stepping** — steps are only committed after the
  checkpoint fence; on restart the trainer resumes from ``latest`` and
  replays the data pipeline from the recorded cursor (the embedded engine
  snapshot gives exactly-once batches: the cursor is a row offset into an
  immutable table version — DESIGN.md §2).
"""

from __future__ import annotations

import json
import os
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional


@dataclass
class Heartbeat:
    root: str
    host: str
    dead_after_s: float = 60.0

    def path(self, host: Optional[str] = None) -> str:
        return os.path.join(self.root, f"{host or self.host}.json")

    def beat(self, step: int, now: Optional[float] = None) -> None:
        os.makedirs(self.root, exist_ok=True)
        tmp = self.path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host, "step": step,
                       "time": now if now is not None else time.time()}, f)
        os.replace(tmp, self.path())

    def scan(self, now: Optional[float] = None) -> dict:
        """Returns {host: status} with status in {alive, dead}."""
        now = now if now is not None else time.time()
        out = {}
        if not os.path.isdir(self.root):
            return out
        for fn in os.listdir(self.root):
            if not fn.endswith(".json"):
                continue
            try:
                with open(os.path.join(self.root, fn)) as f:
                    rec = json.load(f)
            except (json.JSONDecodeError, OSError):
                continue   # torn write: treat as missing this round
            age = now - rec["time"]
            out[rec["host"]] = "alive" if age < self.dead_after_s else "dead"
        return out

    def dead_hosts(self, now: Optional[float] = None) -> list[str]:
        return [h for h, s in self.scan(now).items() if s == "dead"]


@dataclass
class StragglerDetector:
    window: int = 32
    straggler_factor: float = 1.5
    _durations: dict = field(default_factory=dict)

    def record(self, host: str, step_time_s: float) -> None:
        dq = self._durations.setdefault(host, deque(maxlen=self.window))
        dq.append(step_time_s)

    def _median(self, xs) -> float:
        s = sorted(xs)
        n = len(s)
        return 0.5 * (s[(n - 1) // 2] + s[n // 2])

    def medians(self) -> dict:
        return {h: self._median(d) for h, d in self._durations.items() if d}

    def stragglers(self) -> list[str]:
        med = self.medians()
        if len(med) < 2:
            return []
        fleet = self._median(list(med.values()))
        return [h for h, m in med.items()
                if m > self.straggler_factor * fleet]

    def rebalance_plan(self, shards_per_host: dict) -> dict:
        """Move one input shard from each straggler to the fastest host."""
        med = self.medians()
        strag = self.stragglers()
        if not strag or not med:
            return dict(shards_per_host)
        fastest = min(med, key=med.get)
        plan = dict(shards_per_host)
        for h in strag:
            if plan.get(h, 0) > 1 and h != fastest:
                plan[h] -= 1
                plan[fastest] = plan.get(fastest, 0) + 1
        return plan


@dataclass
class RestartPolicy:
    """Decides restart vs continue on failure signals."""
    max_restarts: int = 20
    restarts: int = 0

    def on_failure(self, dead_hosts: list[str], world: int):
        """Returns action: 'continue' | 'elastic_restart' | 'abort'."""
        if not dead_hosts:
            return "continue"
        self.restarts += 1
        if self.restarts > self.max_restarts:
            return "abort"
        # elastic restart: surviving hosts re-form the mesh; checkpoint
        # leaves are full-shape so re-sharding is mechanical
        return "elastic_restart"


def elastic_mesh_shape(n_hosts_alive: int, chips_per_host: int = 4,
                       model_parallel: int = 16):
    """Largest (data, model) mesh from surviving chips, keeping the model
    axis fixed (TP degree is a property of the checkpointed layout we want
    to keep) and shrinking data parallelism."""
    chips = n_hosts_alive * chips_per_host
    data = max(1, chips // model_parallel)
    return (data, model_parallel)
