"""The pjit'd training step: loss -> grads -> (optionally compressed)
reduction -> AdamW, with microbatch gradient accumulation.

Sharding comes entirely from in/out shardings on jit (GSPMD): batch over
(pod, data); params/moments per model_spec.  With scan-over-layers + remat,
XLA overlaps the DP reduce-scatter of layer grads with the previous layer's
backward (no hand-written overlap needed — verified in the dry-run HLO by
the interleaving of collective-start/done with dot ops).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.transformer import train_loss
from .optimizer import (AdamWConfig, adamw_update, compress_tree,
                        decompress_int8)


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  Call under jit with shardings from launch.shardings."""

    def loss_fn(params, batch):
        total, (loss, aux) = train_loss(params, cfg, batch)
        return total, (loss, aux)

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                gacc, lacc = carry
                (tot, (loss, aux)), g = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gacc, g)
                return (gacc, lacc + loss), None

            gz = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(
                acc_step, (gz, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            aux = jnp.zeros((), jnp.float32)
        else:
            (tot, (loss, aux)), grads = grad_fn(params, batch)

        if opt_cfg.compress_grads:
            # int8 + error feedback; the quant/dequant pair is inserted
            # before the (GSPMD) data-parallel reduction so wire bytes
            # shrink 4x.  Error state lives in opt_state["err"].
            err = opt_state.get("err")
            if err is None:
                err = jax.tree.map(
                    lambda g: jnp.zeros(g.shape, jnp.float32), grads)
            q, scales, new_err = compress_tree(grads, err)
            grads = jax.tree.map(decompress_int8, q, scales)
            opt_state = dict(opt_state, err=new_err)

        err_state = opt_state.pop("err", None)
        params, opt_state, metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        if err_state is not None:
            opt_state["err"] = err_state
        metrics = dict(metrics, loss=loss, aux_loss=aux)
        return params, opt_state, metrics

    return train_step
