"""Fault-tolerant checkpointing: mesh-agnostic sharded save/restore.

Format: one directory per step; each param/opt leaf saved as a full-shape
npz entry (host-gathered) plus a JSON manifest with tree structure, shapes,
dtypes and the logical PartitionSpec.  Because leaves are stored at full
logical shape, restore re-shards onto *any* mesh — the elastic-scaling path:
a job restarted on a shrunk/grown mesh re-places the same arrays with new
NamedShardings (tested in tests/test_checkpoint.py).

Durability: write to a temp dir + atomic rename; a `latest` symlink flips
last.  Retention keeps the newest K checkpoints.  Async mode hands the
host-side write to a background thread (double-buffered), overlapping
checkpoint IO with the next training steps — the standard hiding trick.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Optional

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save_checkpoint(ckpt_dir: str, step: int, params, opt_state,
                    extra: Optional[dict] = None, retain: int = 3,
                    async_write: bool = False):
    """Returns immediately if async_write (thread does IO)."""
    host_tree = jax.tree.map(lambda x: np.asarray(x), {
        "params": params, "opt_state": opt_state})

    def do_write():
        os.makedirs(ckpt_dir, exist_ok=True)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
        try:
            leaves = _flatten_with_paths(host_tree)
            np.savez(os.path.join(tmp, "leaves.npz"),
                     **{k.replace("/", "__"): v for k, v in leaves.items()})
            manifest = {
                "step": step,
                "leaves": {k: {"shape": list(v.shape),
                               "dtype": str(v.dtype)}
                           for k, v in leaves.items()},
                "extra": extra or {},
            }
            with open(os.path.join(tmp, MANIFEST), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            _update_latest(ckpt_dir, final)
            _apply_retention(ckpt_dir, retain)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    if async_write:
        t = threading.Thread(target=do_write, daemon=True)
        t.start()
        return t
    do_write()
    return None


def _update_latest(ckpt_dir: str, final: str):
    link = os.path.join(ckpt_dir, "latest")
    tmp_link = link + ".tmp"
    if os.path.lexists(tmp_link):
        os.unlink(tmp_link)
    os.symlink(os.path.basename(final), tmp_link)
    os.replace(tmp_link, link)


def _apply_retention(ckpt_dir: str, retain: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for d in steps[:-retain]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    link = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(link):
        return None
    name = os.path.basename(os.path.realpath(link))
    return int(name.split("_")[1])


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       shardings=None):
    """Load (params, opt_state, extra).  If ``shardings`` (matching pytree of
    NamedSharding) is given, leaves are device_put with them — this is where
    elastic re-meshing happens."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "leaves.npz")) as z:
        leaves = {k.replace("__", "/"): z[k] for k in z.files}

    def rebuild(prefix, template=None):
        # reconstruct nested dict structure from the path keys
        tree: dict = {}
        for key, arr in leaves.items():
            if not key.startswith(prefix + "/"):
                continue
            parts = key[len(prefix) + 1:].split("/")
            node = tree
            for p in parts[:-1]:
                node = node.setdefault(p, {})
            node[parts[-1]] = arr
        return tree

    params = rebuild("params")
    opt_state = rebuild("opt_state")
    if shardings is not None:
        def place(tree, sh_tree):
            return jax.tree.map(
                lambda a, s: jax.device_put(a, s), tree, sh_tree)
        params = place(params, shardings["params"])
        opt_state = place(opt_state, shardings["opt_state"])
    return params, opt_state, manifest["extra"], step
