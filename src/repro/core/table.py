"""Tables: ordered collections of equal-length column versions.

A ``Table`` is immutable; the transactional layer (transactions.py) swaps
whole-table versions atomically.  This is the unit the snapshot isolation
model works on (paper §3.1 "Concurrency Control").
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Optional

import numpy as np

from .column import Column
from .types import ColumnSchema, DBType, TableSchema


@dataclass
class Table:
    schema: TableSchema
    columns: dict[str, Column] = field(default_factory=dict)
    version: int = 0

    def __post_init__(self):
        lens = {len(c) for c in self.columns.values()}
        if len(lens) > 1:
            raise ValueError(f"ragged table {self.schema.name}: lengths {lens}")

    # ---- constructors ----------------------------------------------------
    @classmethod
    def from_dict(cls, name: str, data: dict, types: Optional[dict] = None,
                  scales: Optional[dict] = None) -> "Table":
        """Build from {col: values}. Types inferred from numpy dtypes unless
        given explicitly."""
        types = types or {}
        scales = scales or {}
        cols: dict[str, Column] = {}
        schemas: list[ColumnSchema] = []
        for cname, values in data.items():
            t = types.get(cname)
            if t is None:
                t = _infer_type(values)
            sc = scales.get(cname, 2 if t == DBType.DECIMAL else 0)
            col = Column.from_values(values, t, scale=sc)
            cols[cname] = col
            schemas.append(ColumnSchema(cname, t, scale=sc))
        return cls(TableSchema(name, tuple(schemas)), cols)

    # ---- accessors ---------------------------------------------------------
    @property
    def name(self) -> str:
        return self.schema.name

    @property
    def num_rows(self) -> int:
        if not self.columns:
            return 0
        return len(next(iter(self.columns.values())))

    @property
    def num_cols(self) -> int:
        return len(self.columns)

    def column(self, name: str) -> Column:
        return self.columns[name]

    def __getitem__(self, name: str) -> Column:
        return self.columns[name]

    @property
    def nbytes(self) -> int:
        return sum(c.nbytes for c in self.columns.values())

    # ---- delta-store geometry (delta.py overrides; a plain table is all
    # base, epoch 0) ---------------------------------------------------------
    @property
    def base_version(self) -> int:
        return self.version

    @property
    def delta_epoch(self) -> int:
        return 0

    @property
    def base_rows(self) -> int:
        return self.num_rows

    @property
    def delta_rows(self) -> int:
        return 0

    def tail_array(self, name: str, start: int) -> np.ndarray:
        """Raw storage values of rows ``[start:]`` (DeltaTable overrides
        with an O(tail) implementation that avoids the merge)."""
        return np.asarray(self.columns[name].data)[start:]

    # ---- functional updates ------------------------------------------------
    def take(self, idx: np.ndarray) -> "Table":
        return Table(self.schema,
                     {n: c.take(idx) for n, c in self.columns.items()},
                     version=self.version)

    def slice_rows(self, start: int, stop: int) -> "Table":
        """Zero-copy row window [start, stop) — the ingest path's re-chunking
        primitive (views share the source arrays; no heap work)."""
        cols = {n: Column(c.dbtype, np.asarray(c.data)[start:stop],
                          heap=c.heap, scale=c.scale)
                for n, c in self.columns.items()}
        return Table(self.schema, cols, version=self.version)

    def select_columns(self, names: Iterable[str]) -> "Table":
        names = list(names)
        sch = TableSchema(self.schema.name,
                          tuple(self.schema.column(n) for n in names))
        return Table(sch, {n: self.columns[n] for n in names},
                     version=self.version)

    def append_table(self, other: "Table") -> "Table":
        if set(other.columns) != set(self.columns):
            raise ValueError("append schema mismatch")
        cols = {n: self.columns[n].append(other.columns[n])
                for n in self.columns}
        return Table(self.schema, cols, version=self.version + 1)

    def rename(self, name: str) -> "Table":
        sch = TableSchema(name, self.schema.columns)
        return Table(sch, dict(self.columns), version=self.version)

    def to_pydict(self) -> dict[str, np.ndarray]:
        """Decode all columns (the eager-conversion path; see exchange.py
        for the zero-copy / lazy paths)."""
        return {n: c.to_numpy() for n, c in self.columns.items()}

    def head(self, n: int = 5) -> dict[str, np.ndarray]:
        return {k: v[:n] for k, v in self.to_pydict().items()}


def _infer_type(values) -> DBType:
    if isinstance(values, np.ndarray):
        dt = values.dtype
        if dt == np.int32:
            return DBType.INT32
        if np.issubdtype(dt, np.integer):
            return DBType.INT64
        if dt == np.float32:
            return DBType.FLOAT32
        if np.issubdtype(dt, np.floating):
            return DBType.FLOAT64
        if dt == np.bool_:
            return DBType.BOOL
        if dt.kind in ("U", "S", "O"):
            return DBType.VARCHAR
        raise TypeError(f"cannot infer DBType for dtype {dt}")
    for v in values:
        if v is None:
            continue
        if isinstance(v, bool):
            return DBType.BOOL
        if isinstance(v, (int, np.integer)):
            return DBType.INT64
        if isinstance(v, (float, np.floating)):
            return DBType.FLOAT64
        if isinstance(v, str):
            return DBType.VARCHAR
    return DBType.INT64
