"""Column-at-a-time execution: relational tree -> MAL program -> columns.

Late materialization: filters produce boolean *selection masks* (MonetDB's
candidate lists, recast branch-free for the TPU idiom) that flow alongside
the columns; rows are only compacted at blocking boundaries (join, group,
sort, result).  Tactical decisions (paper optimization level 3) happen here
at runtime: join implementation and index use are chosen per-instruction
from cardinalities and available indexes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import numpy as np

from .column import Column
from .expression import Col, EvalContext, ExprResult
from .mal import Instr, MALProgram
from .optimizer import split_conjuncts
from .physplan import TierPolicy, _simple_range
from .relalg import (AggregateNode, FilterNode, JoinNode, LimitNode,
                     OrderByNode, PlanNode, ProjectNode, ScanNode)
from .types import DBType, NULL_SENTINEL, STORAGE_DTYPE, is_float

# ---------------------------------------------------------------------------
# compile: plan -> MALProgram
# ---------------------------------------------------------------------------


@dataclass
class RelInfo:
    """Compile-time shape of an intermediate relation."""
    cols: dict[str, str]                 # column name -> register
    mask: Optional[str] = None           # selection-mask register
    base_table: Optional[str] = None     # set iff this is an unfiltered scan
    pure: bool = True                    # no projection applied yet


def compile_plan(plan: PlanNode, catalog) -> MALProgram:
    prog = MALProgram()
    ri = _compile(plan, prog, catalog)
    regs = []
    names = []
    if ri.mask is not None:
        (idx,) = prog.emit("midx", (ri.mask,), hint="idx")
        for name, reg in ri.cols.items():
            (reg,) = prog.emit("take", (reg, idx), hint="c")
            regs.append(reg)
            names.append(name)
    else:
        for name, reg in ri.cols.items():
            regs.append(reg)
            names.append(name)
    prog.emit("result", tuple(regs), payload=tuple(names), n_out=0)
    prog.result_names = names
    return prog


def _binding_args(binding: dict[str, str]) -> tuple[str, ...]:
    return tuple(sorted(set(binding.values())))


def _compile(node: PlanNode, prog: MALProgram, catalog) -> RelInfo:
    if isinstance(node, ScanNode):
        cols = {}
        names = node.columns or catalog.table(node.table).schema.names
        for c in names:
            (r,) = prog.emit("load", (), payload=(node.table, c), hint="c")
            cols[c] = r
        return RelInfo(cols, base_table=node.table)

    if isinstance(node, FilterNode):
        ri = _compile(node.child, prog, catalog)
        binding = dict(ri.cols)
        mask = ri.mask
        for conj in split_conjuncts(node.predicate):
            used = {c: binding[c] for c in conj.columns()}
            (m,) = prog.emit(
                "select", _binding_args(used),
                payload=dict(expr=conj, binding=used,
                             base_table=ri.base_table if ri.pure else None),
                hint="m")
            mask = m if mask is None else prog.emit("mand", (mask, m),
                                                    hint="m")[0]
        return RelInfo(dict(ri.cols), mask=mask,
                       base_table=ri.base_table, pure=ri.pure)

    if isinstance(node, ProjectNode):
        ri = _compile(node.child, prog, catalog)
        cols = {}
        for e, name in node.exprs:
            if isinstance(e, Col) and e.name in ri.cols:
                cols[name] = ri.cols[e.name]
                continue
            used = {c: ri.cols[c] for c in e.columns()}
            (r,) = prog.emit("expr", _binding_args(used),
                             payload=dict(expr=e, binding=used), hint="e")
            cols[name] = r
        return RelInfo(cols, mask=ri.mask, base_table=ri.base_table,
                       pure=False)

    if isinstance(node, JoinNode):
        lri = _compile(node.left, prog, catalog)
        rri = _compile(node.right, prog, catalog)
        lkeys = tuple(lri.cols[k] for k in node.left_keys)
        rkeys = tuple(rri.cols[k] for k in node.right_keys)
        args = lkeys + rkeys
        masks = []
        if lri.mask is not None:
            masks.append(lri.mask)
        if rri.mask is not None:
            masks.append(rri.mask)
        payload = dict(n_keys=len(lkeys), how=node.how,
                       lmask=lri.mask is not None,
                       rmask=rri.mask is not None,
                       left_base=lri.base_table if lri.pure else None,
                       right_base=rri.base_table if rri.pure else None,
                       left_keys=node.left_keys, right_keys=node.right_keys)
        n_out = 1 if node.how in ("semi", "anti") else 2
        outs = prog.emit("join", args + tuple(masks), payload=payload,
                         n_out=n_out, hint="idx")
        cols = {}
        for name, reg in lri.cols.items():
            (r,) = prog.emit("fetch", (reg, outs[0]), hint="c")
            cols[name] = r
        if node.how in ("inner", "left"):
            fill = node.how == "left"
            for name, reg in rri.cols.items():
                if name in cols:
                    continue
                (r,) = prog.emit("fetch", (reg, outs[1]),
                                 payload=dict(fill_null=fill), hint="c")
                cols[name] = r
        return RelInfo(cols, mask=None, base_table=None, pure=False)

    if isinstance(node, AggregateNode):
        ri = _compile(node.child, prog, catalog)
        keys = tuple(ri.cols[k] for k in node.group_by)
        args = keys + ((ri.mask,) if ri.mask is not None else ())
        rep = False
        if not keys and ri.mask is None and ri.cols:
            # zero-key global aggregate: pass one column so the runtime
            # knows the row count
            args = (next(iter(ri.cols.values())),)
            rep = True
        gid, nreg, idx = prog.emit(
            "group", args,
            payload=dict(n_keys=len(keys), has_mask=ri.mask is not None,
                         rep=rep,
                         base_table=ri.base_table if ri.pure else None,
                         key_names=node.group_by),
            n_out=3, hint="g")
        cols = {}
        for k, reg in zip(node.group_by, keys):
            (r,) = prog.emit("gkey", (reg, gid, nreg, idx), hint="c")
            cols[k] = r
        for spec in node.aggs:
            if spec.expr is None:
                vreg = None
            elif isinstance(spec.expr, Col):
                vreg = ri.cols[spec.expr.name]
            else:
                used = {c: ri.cols[c] for c in spec.expr.columns()}
                (vreg,) = prog.emit("expr", _binding_args(used),
                                    payload=dict(expr=spec.expr,
                                                 binding=used), hint="e")
            a = (vreg, gid, nreg, idx) if vreg else (gid, nreg, idx)
            (r,) = prog.emit("agg", a,
                             payload=dict(fn=spec.fn,
                                          has_value=vreg is not None),
                             hint="a")
            cols[spec.name] = r
        return RelInfo(cols, mask=None, base_table=None, pure=False)

    if isinstance(node, OrderByNode):
        ri = _compile(node.child, prog, catalog)
        cols = dict(ri.cols)
        if ri.mask is not None:
            (idx,) = prog.emit("midx", (ri.mask,), hint="idx")
            cols = {n: prog.emit("take", (r, idx), hint="c")[0]
                    for n, r in cols.items()}
        keys = tuple(cols[k] for k, _ in node.keys)
        (sidx,) = prog.emit("sort", keys,
                            payload=dict(descs=tuple(d for _, d in node.keys),
                                         limit=node.limit), hint="idx")
        cols = {n: prog.emit("take", (r, sidx), hint="c")[0]
                for n, r in cols.items()}
        return RelInfo(cols, mask=None, pure=False)

    if isinstance(node, LimitNode):
        ri = _compile(node.child, prog, catalog)
        cols = dict(ri.cols)
        if ri.mask is not None:
            (idx,) = prog.emit("midx", (ri.mask,), hint="idx")
            cols = {n: prog.emit("take", (r, idx), hint="c")[0]
                    for n, r in cols.items()}
        cols = {n: prog.emit("slice", (r,), payload=node.n, hint="c")[0]
                for n, r in cols.items()}
        return RelInfo(cols, mask=None, pure=False)

    raise TypeError(f"cannot compile {type(node).__name__}")


# ---------------------------------------------------------------------------
# runtime helpers (host/numpy tier)
# ---------------------------------------------------------------------------


def _res_nulls(r: ExprResult) -> np.ndarray:
    if r.null is not None:
        return np.asarray(r.null)
    if is_float(r.dbtype):
        return np.isnan(r.values)
    return np.asarray(r.values) == NULL_SENTINEL[r.dbtype]


def _factorize(results: list[ExprResult],
               idx: Optional[np.ndarray] = None) -> tuple[np.ndarray, int]:
    """Combine N key columns into dense group codes (int64)."""
    combined = None
    for r in results:
        v = np.asarray(r.values)
        if idx is not None:
            v = v[idx]
        if r.dbtype == DBType.VARCHAR:
            codes, n = v.astype(np.int64), len(r.heap)
        else:
            uniq, codes = np.unique(v, return_inverse=True)
            codes, n = codes.astype(np.int64), len(uniq)
        if combined is None:
            combined = codes
            card = n
        else:
            combined = combined * n + codes
            card *= n
    if combined is None:
        return np.zeros(0, dtype=np.int64), 1
    if card > (1 << 62) or card > 16 * len(combined) + 16:
        uniq, combined = np.unique(combined, return_inverse=True)
        card = len(uniq)
    return combined.astype(np.int64), int(card)


def _dense_gid(codes: np.ndarray) -> tuple[np.ndarray, int, np.ndarray]:
    """codes -> (dense gid in first-occurrence order?, n, rep positions).

    Group order follows sorted key order (stable, deterministic)."""
    uniq, first_pos, gid = np.unique(codes, return_index=True,
                                     return_inverse=True)
    return gid.astype(np.int64), len(uniq), first_pos


def _join_codes(lres, rres, n_keys) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Factorize join keys jointly; returns (lc, rc, lnull, rnull)."""
    lc = rc = None
    lnull = np.zeros(len(np.asarray(lres[0].values)), dtype=bool)
    rnull = np.zeros(len(np.asarray(rres[0].values)), dtype=bool)
    from .column import heaps_equal
    for lr, rr in zip(lres, rres):
        lv, rv = np.asarray(lr.values), np.asarray(rr.values)
        lnull |= _res_nulls(lr)
        rnull |= _res_nulls(rr)
        if lr.dbtype == DBType.VARCHAR and rr.dbtype == DBType.VARCHAR \
                and not heaps_equal(lr.heap, rr.heap):
            # distinct dictionaries (by content, not object identity —
            # separately-loaded copies of one table compare codes directly):
            # fall back to the decoded strings
            lv = lr.heap.decode(lv).astype(str)
            rv = rr.heap.decode(rv).astype(str)
        allv = np.concatenate([lv, rv])
        uniq, inv = np.unique(allv, return_inverse=True)
        la, ra = inv[:len(lv)].astype(np.int64), inv[len(lv):].astype(np.int64)
        if lc is None:
            lc, rc, card = la, ra, len(uniq)
        else:
            lc = lc * len(uniq) + la
            rc = rc * len(uniq) + ra
            card *= len(uniq)
    return lc, rc, lnull, rnull


def _hash_join(lc, rc, how, r_order=None):
    """Vectorized 'hash' join: sorted build side + binary-search probe.

    ``r_order`` may come from a persisted order index (merge-join tactical
    path); otherwise we argsort (build phase of the hash table analogue)."""
    order = np.argsort(rc, kind="stable") if r_order is None else r_order
    rs = rc[order]
    lo = np.searchsorted(rs, lc, "left")
    hi = np.searchsorted(rs, lc, "right")
    cnt = hi - lo
    if how == "semi":
        return np.nonzero(cnt > 0)[0], None
    if how == "anti":
        return np.nonzero(cnt == 0)[0], None
    if how == "left":
        if len(rs) == 0:
            # empty build side (e.g. every right key NULL): every probe row
            # survives unmatched.  The general path below would index the
            # empty order array eagerly inside np.where.
            return (np.arange(len(lc), dtype=np.int64),
                    np.full(len(lc), -1, dtype=np.int64))
        total = int(cnt.sum())
        cnt1 = np.maximum(cnt, 1)
        lidx = np.repeat(np.arange(len(lc), dtype=np.int64), cnt1)
        offs = np.concatenate([[0], np.cumsum(cnt1)])[:-1]
        pos = np.arange(int(cnt1.sum()), dtype=np.int64) - np.repeat(offs, cnt1)
        ridx = np.where(np.repeat(cnt, cnt1) == 0, -1,
                        order[np.minimum(np.repeat(lo, cnt1) + pos,
                                         len(rs) - 1 if len(rs) else 0)])
        return lidx, ridx
    lidx = np.repeat(np.arange(len(lc), dtype=np.int64), cnt)
    offs = np.concatenate([[0], np.cumsum(cnt)])[:-1]
    pos = np.arange(int(cnt.sum()), dtype=np.int64) - np.repeat(offs, cnt)
    ridx = order[np.repeat(lo, cnt) + pos]
    return lidx, ridx


def _sort_key_float(r: ExprResult, desc: bool) -> np.ndarray:
    v = np.asarray(r.values)
    if r.dbtype == DBType.VARCHAR:
        k = v.astype(np.float64)
        nulls = v == 0
    else:
        k = r.as_float(np)
        nulls = _res_nulls(r)
    k = np.where(nulls, np.inf, -k if desc else k)   # NULLs always last
    return k


_AGG_FLOAT = {"sum", "avg", "median", "var", "std"}


def _run_agg(fn: str, val: Optional[ExprResult], gid: np.ndarray, n: int,
             idx: np.ndarray) -> ExprResult:
    if fn == "count" and val is None:
        out = np.bincount(gid, minlength=n).astype(np.int64)
        return ExprResult(out, DBType.INT64)
    assert val is not None, f"{fn} requires a value expression"
    v = np.asarray(val.values)[idx]
    nulls = _res_nulls(val)[idx]
    ok = ~nulls
    if fn == "count":
        out = np.bincount(gid[ok], minlength=n).astype(np.int64)
        return ExprResult(out, DBType.INT64)
    if fn == "count_distinct":
        pair = gid[ok] * np.int64(2**32) + _rank(v[ok])
        upair = np.unique(pair)
        out = np.bincount((upair // np.int64(2**32)).astype(np.int64),
                          minlength=n).astype(np.int64)
        return ExprResult(out, DBType.INT64)
    if fn in ("min", "max"):
        if val.dbtype == DBType.VARCHAR:
            init = np.iinfo(np.int64).max if fn == "min" else 0
            out = np.full(n, init, dtype=np.int64)
            op = np.minimum if fn == "min" else np.maximum
            op.at(out, gid[ok], v[ok].astype(np.int64))
            out = np.where(out == init, 0, out).astype(np.int32)
            return ExprResult(out, DBType.VARCHAR, heap=val.heap)
        f = val.as_float(np)[idx]
        out = np.full(n, np.inf if fn == "min" else -np.inf)
        op = np.minimum if fn == "min" else np.maximum
        op.at(out, gid[ok], f[ok])
        empty = np.isinf(out)
        if val.dbtype in (DBType.INT32, DBType.INT64, DBType.DATE,
                          DBType.DECIMAL) and not empty.any():
            enc = out * (10 ** val.scale) if val.dbtype == DBType.DECIMAL \
                else out
            return ExprResult(
                np.round(enc).astype(STORAGE_DTYPE[val.dbtype]),
                val.dbtype, scale=val.scale)
        out = np.where(empty, np.nan, out)
        return ExprResult(out, DBType.FLOAT64)
    f = val.as_float(np)[idx]
    fz = np.where(nulls, 0.0, f)
    cnt = np.bincount(gid[ok], minlength=n).astype(np.float64)
    if fn == "sum":
        out = np.bincount(gid, weights=fz, minlength=n)
        out = np.where(cnt == 0, np.nan, out)
        return ExprResult(out, DBType.FLOAT64)
    if fn == "avg":
        s = np.bincount(gid, weights=fz, minlength=n)
        out = s / np.maximum(cnt, 1)
        out = np.where(cnt == 0, np.nan, out)
        return ExprResult(out, DBType.FLOAT64)
    if fn in ("var", "std"):
        s = np.bincount(gid, weights=fz, minlength=n)
        s2 = np.bincount(gid, weights=fz * fz, minlength=n)
        m = s / np.maximum(cnt, 1)
        var = s2 / np.maximum(cnt, 1) - m * m
        var = np.maximum(var, 0.0)
        out = np.sqrt(var) if fn == "std" else var
        out = np.where(cnt == 0, np.nan, out)
        return ExprResult(out, DBType.FLOAT64)
    if fn == "median":
        # blocking op (paper Fig. 2): per-group sort then pick middles
        ordr = np.lexsort((f, np.where(ok, gid, n)))
        g_sorted = np.where(ok, gid, n)[ordr]
        f_sorted = f[ordr]
        starts = np.searchsorted(g_sorted, np.arange(n), "left")
        ends = np.searchsorted(g_sorted, np.arange(n), "right")
        m = ends - starts
        midlo = starts + np.maximum(m - 1, 0) // 2
        midhi = starts + m // 2
        safe = m > 0
        out = np.where(
            safe,
            0.5 * (f_sorted[np.minimum(midlo, len(f_sorted) - 1)]
                   + f_sorted[np.minimum(midhi, len(f_sorted) - 1)]),
            np.nan)
        return ExprResult(out, DBType.FLOAT64)
    if fn == "first":
        _, fpos = np.unique(gid, return_index=True)
        out = v[fpos]
        return ExprResult(out, val.dbtype, heap=val.heap, scale=val.scale)
    raise ValueError(fn)


def _rank(v: np.ndarray) -> np.ndarray:
    _, inv = np.unique(v, return_inverse=True)
    return inv.astype(np.int64)


def _probe_group_state(keys: list[ExprResult], idx: np.ndarray,
                       sample: int = 4096) -> int:
    """Estimated distinct group count from a strided row sample (runtime
    statistics for the spill decision).  A sample whose rows are mostly
    distinct extrapolates linearly; a clearly repetitive one is treated as
    low-cardinality."""
    if len(idx) == 0:
        return 0
    samp = idx[::max(1, len(idx) // sample)][:sample]
    codes, _ = _factorize(keys, samp)
    d = len(np.unique(codes))
    if d >= 0.5 * len(samp):
        return int(d * len(idx) / max(1, len(samp)))
    return 2 * d


def _result_chunk(r: ExprResult, sl: slice) -> np.ndarray:
    """Storage-dtype conversion + NULL filling for one slice of a result
    column — shared by the in-RAM materializer (one full-range slice) and
    the budgeted memmap streamer (morsel slices)."""
    v = np.asarray(r.values)[sl]
    t = r.dbtype
    want = STORAGE_DTYPE[t]
    if v.dtype != want:
        if is_float(t):
            v = v.astype(want)
        else:
            vv = v.astype(np.float64) if v.dtype.kind == "f" else v
            v = np.where(np.isnan(vv), NULL_SENTINEL[t], vv).astype(want) \
                if v.dtype.kind == "f" else v.astype(want)
    if r.null is not None:
        nl = np.asarray(r.null)[sl]
        if nl.any():
            if is_float(t):
                v = np.where(nl, np.nan, v)
            else:
                v = np.where(nl, NULL_SENTINEL[t], v).astype(want)
    return v.astype(want, copy=False)


# ---------------------------------------------------------------------------
# program interpreter
# ---------------------------------------------------------------------------


@dataclass
class ExecStats:
    instructions: int = 0
    index_hits: int = 0
    imprint_blocks_skipped: int = 0
    rows_scanned: int = 0
    spilled_ops: int = 0          # blocking ops routed to the spill tier
    varchar_spills: int = 0       # spilled ops whose keys include VARCHAR
    result_spills: int = 0        # final tables streamed to memmapped cols
    plan_repr: str = ""           # physical-plan EXPLAIN text of this query
    # per-query spill-pipeline deltas (the BufferManager's counters are
    # database-lifetime cumulative; these isolate this executor's programs).
    # Best-effort under concurrency: the counters are shared per database,
    # so queries spilling simultaneously cross-attribute each other's bytes.
    bytes_spilled_raw: int = 0          # pre-codec bytes this query spilled
    bytes_spilled_compressed: int = 0   # post-codec bytes actually written
    prefetch_hits: int = 0              # partitions loaded ahead of use
    repartitions: int = 0               # oversized partitions split again
    # device tier (device_cache.py / parallel.DistributedScanAgg): same
    # best-effort per-query deltas of the shared BufferStats counters
    device_tier: str = ""               # "", "resident", "streamed",
                                        # "join-resident", "join-streamed"
    device_sorted: bool = False         # ORDER BY fused onto the device
                                        # assembly (host suffix sort skipped)
    device_cache_hits: int = 0          # blocks served without a transfer
    device_prefetch_hits: int = 0       # blocks whose copy was issued ahead
    device_evictions: int = 0           # blocks evicted under budget pressure
    device_bytes_h2d: int = 0           # host→device bytes this query moved
    device_writebacks: int = 0          # dirty blocks copied back to host
    device_bytes_peak: int = 0          # manager high-water mark (lifetime)
    # serving layer (serving.py): per-query view of the concurrent path
    plan_cache_hit: bool = False        # lowering skipped via the plan cache
    admission_wait_ms: float = 0.0      # time queued at the admission gate
    reserved_bytes: int = 0             # host reservation the gate granted
    reserved_device_bytes: int = 0      # device reservation granted
    shared_scan_attaches: int = 0       # blocks served by another query's
                                        # in-flight build/upload
    observed_group_card: Optional[int] = None  # dense group count this
                                        # query's aggregate actually saw
    # imprint-driven data skipping (physplan.SkipSet): per-query deltas of
    # the shared BufferStats counters, same best-effort caveat as above
    blocks_skipped: int = 0             # imprint blocks never read/uploaded
    bytes_skipped_h2d: int = 0          # host→device bytes skipping avoided
    bytes_skipped_spill: int = 0        # column bytes kept out of the
                                        # scan→filter→partition streams
    # delta-store ingest (delta.py): per-query deltas of the shared counters
    delta_bytes_h2d: int = 0            # h2d bytes for delta-tail blocks
    delta_rows: int = 0                 # delta-tail rows this query scanned
    compactions: int = 0                # tail folds triggered while running


# Per-query deltas of the database-lifetime BufferStats counters: the field
# names are shared between BufferStats and ExecStats, so threading is one
# list instead of hand-maintained positional tuples at every call site.
SPILL_DELTA_FIELDS = ("bytes_spilled_raw", "bytes_spilled_compressed",
                      "prefetch_hits", "repartitions", "result_spills")
DEVICE_DELTA_FIELDS = ("device_cache_hits", "device_prefetch_hits",
                       "device_evictions", "device_bytes_h2d",
                       "device_writebacks", "shared_scan_attaches")
SKIP_DELTA_FIELDS = ("blocks_skipped", "bytes_skipped_h2d",
                     "bytes_skipped_spill")
INGEST_DELTA_FIELDS = ("delta_bytes_h2d", "delta_rows", "compactions")


def stats_base(buffer_stats, fields) -> tuple:
    return tuple(getattr(buffer_stats, f) for f in fields)


def stats_apply_delta(exec_stats, buffer_stats, base, fields) -> None:
    for f, b in zip(fields, base):
        setattr(exec_stats, f,
                getattr(exec_stats, f) + getattr(buffer_stats, f) - b)


class Executor:
    """Sequential host-tier interpreter.  parallel.py subclasses the
    dispatch to run parallelizable spans under shard_map.

    Tier routing is NOT decided here: every plan is lowered through
    ``physplan.plan_physical`` first, and blocking operators (join / group
    / sort / result) consult the physical plan's ``TierPolicy`` with their
    actual runtime cardinalities (paper optimization level 3: the
    plan-time annotation predicted from statistics, the instruction
    refines with real sizes — same policy, one definition of every
    threshold).  Over-budget state routes to the partitioned external
    operators in spill.py, which return bit-identical results while
    keeping tracked working memory under the budget."""

    def __init__(self, database):
        self.db = database
        self.stats = ExecStats()
        self.bufman = getattr(database, "buffer_manager", None)
        self.policy = TierPolicy.for_db(database)

    def _note_spill(self, varchar: bool) -> None:
        """Count one blocking op routed to the spill tier (per-query and
        database-lifetime); ``varchar`` marks ops whose keys include
        dictionary-encoded strings."""
        self.stats.spilled_ops += 1
        self.bufman.bump(spilled_ops=1)
        if varchar:
            self.stats.varchar_spills += 1
            self.bufman.bump(varchar_spills=1)

    # -- entry points -------------------------------------------------------
    # transfers-ownership: the ticket is released by the caller's
    # `with self._admitted(phys):` exit, not here
    def _admitted(self, phys):
        """Reserve the plan's summed per-operator budget estimates at the
        database's admission gate before running (serving.AdmissionGate);
        returns a released-on-exit ticket, or a no-op one when the
        database has no gate (suffix views, bare test harnesses)."""
        gate = getattr(self.db, "admission_gate", None)
        if gate is None:
            import contextlib
            return contextlib.nullcontext()
        host, device = phys.total_reservations()
        ticket = gate.admit(host, device)
        self.stats.admission_wait_ms = ticket.waited * 1000.0
        self.stats.reserved_bytes = ticket.host_bytes
        self.stats.reserved_device_bytes = ticket.device_bytes
        if ticket.waited and self.bufman is not None:
            self.bufman.bump(admission_waits=1)
        return ticket

    def _plan_feedback(self, plan: PlanNode, distributed: bool) -> None:
        """Report the observed group cardinality back to the plan cache so
        the next lowering of this plan shape annotates its aggregate from
        what actually happened, not the level-1 row estimate."""
        cache = getattr(self.db, "plan_cache", None)
        n = self.stats.observed_group_card
        if cache is not None and n is not None:
            from .serving import PlanCache
            cache.note_group_card(PlanCache.shape_key(plan, distributed), n)

    def execute(self, plan: PlanNode, do_optimize: bool = True):
        from .serving import lower_cached
        phys, rendered, hit = lower_cached(self.db, plan,
                                           do_optimize=do_optimize)
        self.policy = phys.policy
        self.stats.plan_repr = rendered
        self.stats.plan_cache_hit = hit
        prog = compile_plan(phys.plan, self.db.catalog)
        with self._admitted(phys):
            result = self.run_program(prog)
        self._plan_feedback(plan, False)
        return result

    def run_program(self, prog: MALProgram):
        regs: dict[str, Any] = {}
        result = None
        bm = self.bufman
        fields = (SPILL_DELTA_FIELDS + DEVICE_DELTA_FIELDS
                  + SKIP_DELTA_FIELDS + INGEST_DELTA_FIELDS)
        base = None if bm is None else stats_base(bm.stats, fields)
        for ins in prog.instrs:
            self.stats.instructions += 1
            out = self._dispatch(ins, regs)
            if ins.op == "result":
                result = out
            else:
                if len(ins.out) == 1:
                    regs[ins.out[0]] = out
                else:
                    for name, val in zip(ins.out, out):
                        regs[name] = val
        if base is not None:
            stats_apply_delta(self.stats, bm.stats, base, fields)
        return result

    # -- dispatch ------------------------------------------------------------
    def _dispatch(self, ins: Instr, regs):
        fn = getattr(self, f"_op_{ins.op}")
        return fn(ins, regs)

    def _op_load(self, ins, regs):
        table, cname = ins.payload
        t = self.db.catalog.table(table)
        col = t.column(cname)
        self.stats.rows_scanned += len(col)
        self._note_delta_scan(table, t)
        return ExprResult(col.data, col.dbtype, None, col.heap, col.scale)

    def _note_delta_scan(self, name: str, t) -> None:
        """Count a scanned table's merge-on-read tail once per program."""
        dr = t.delta_rows
        if not dr:
            return
        noted = getattr(self, "_delta_noted", None)
        if noted is None:
            noted = self._delta_noted = set()
        if name in noted:
            return
        noted.add(name)
        if self.bufman is not None:
            self.bufman.bump(delta_rows=dr)
        else:
            self.stats.delta_rows += dr

    def _ctx(self, binding: dict[str, str], regs) -> EvalContext:
        arrays, meta = {}, {}
        for cname, reg in binding.items():
            r: ExprResult = regs[reg]
            arrays[cname] = np.asarray(r.values)
            meta[cname] = (r.dbtype, r.heap, r.scale)
        ctx = EvalContext(arrays, meta, xp=np)
        return ctx

    def _op_expr(self, ins, regs):
        p = ins.payload
        return p["expr"].eval(self._ctx(p["binding"], regs))

    def _op_select(self, ins, regs):
        p = ins.payload
        expr = p["expr"]
        # Tactical: imprint-accelerated range select on base columns.
        if p.get("base_table") and self.db.index_manager is not None \
                and getattr(self.db, "data_skipping", True):
            rng = _simple_range(expr)
            if rng is not None:
                cname, lo, hi, lo_strict, hi_strict = rng
                im = self.db.index_manager.imprint_mask(
                    p["base_table"], cname, lo, hi, lo_strict, hi_strict)
                if im is not None:
                    mask, skipped = im
                    self.stats.index_hits += 1
                    self.stats.imprint_blocks_skipped += skipped
                    if skipped and self.bufman is not None:
                        # spill-side skipping is by construction: rows in
                        # non-candidate blocks never get a True mask bit,
                        # so they never reach a PartitionWriter stream.
                        # Account the filter column's bytes in those blocks
                        # (a logical estimate — they were never read).
                        from .indexes import IMPRINT_BLOCK
                        col = self.db.catalog.table(
                            p["base_table"]).column(cname)
                        rows = min(skipped * IMPRINT_BLOCK, len(col))
                        self.bufman.bump(
                            blocks_skipped=skipped,
                            bytes_skipped_spill=rows
                            * col.data.dtype.itemsize)
                    return mask
        r = expr.eval(self._ctx(p["binding"], regs))
        vals = np.asarray(r.values) != 0
        if r.null is not None:
            vals = vals & ~np.asarray(r.null)
        return vals

    def _op_mand(self, ins, regs):
        return regs[ins.args[0]] & regs[ins.args[1]]

    def _op_midx(self, ins, regs):
        return np.nonzero(regs[ins.args[0]])[0]

    def _op_take(self, ins, regs):
        r: ExprResult = regs[ins.args[0]]
        idx = regs[ins.args[1]]
        return ExprResult(np.asarray(r.values)[idx], r.dbtype,
                          None if r.null is None else np.asarray(r.null)[idx],
                          r.heap, r.scale)

    def _op_slice(self, ins, regs):
        r: ExprResult = regs[ins.args[0]]
        n = ins.payload
        return ExprResult(np.asarray(r.values)[:n], r.dbtype,
                          None if r.null is None else np.asarray(r.null)[:n],
                          r.heap, r.scale)

    def _op_fetch(self, ins, regs):
        r: ExprResult = regs[ins.args[0]]
        idx = regs[ins.args[1]]
        fill = bool(ins.payload and ins.payload.get("fill_null"))
        v = np.asarray(r.values)
        if fill:
            safe = np.maximum(idx, 0)
            out = v[safe]
            sent = NULL_SENTINEL[r.dbtype]
            out = np.where(idx < 0, sent, out)
            nl = idx < 0
            if r.null is not None:
                nl = nl | np.where(idx < 0, True, np.asarray(r.null)[safe])
            return ExprResult(out, r.dbtype, nl, r.heap, r.scale)
        return ExprResult(v[idx], r.dbtype,
                          None if r.null is None else np.asarray(r.null)[idx],
                          r.heap, r.scale)

    def _op_join(self, ins, regs):
        p = ins.payload
        nk = p["n_keys"]
        lres = [regs[a] for a in ins.args[:nk]]
        rres = [regs[a] for a in ins.args[nk:2 * nk]]
        rest = list(ins.args[2 * nk:])
        lmask = regs[rest.pop(0)] if p["lmask"] else None
        rmask = regs[rest.pop(0)] if p["rmask"] else None

        nl = len(np.asarray(lres[0].values))
        nr = len(np.asarray(rres[0].values))
        key_bytes = sum(np.asarray(r.values).dtype.itemsize for r in lres)
        if self.policy.spills(self.policy.join_state_bytes(nl, nr,
                                                           key_bytes)):
            from . import spill
            vplan = spill.plan_varchar_join(lres, rres, self.bufman)
            if vplan is not None:
                lnull = np.zeros(nl, dtype=bool)
                rnull = np.zeros(nr, dtype=bool)
                for lr, rr in zip(lres, rres):
                    lnull |= _res_nulls(lr)
                    rnull |= _res_nulls(rr)
                lsel = np.nonzero(
                    (~lnull) if lmask is None else (lmask & ~lnull))[0]
                rsel = np.nonzero(
                    (~rnull) if rmask is None else (rmask & ~rnull))[0]
                self._note_spill(any(a is not None for a in vplan))
                return spill.partitioned_hash_join(
                    lres, rres, lsel, rsel, p["how"], self.bufman,
                    vplan=vplan)

        lc, rc, lnull, rnull = _join_codes(lres, rres, nk)
        lsel = np.nonzero((~lnull) if lmask is None else (lmask & ~lnull))[0]
        rsel = np.nonzero((~rnull) if rmask is None else (rmask & ~rnull))[0]
        lc, rc = lc[lsel], rc[rsel]

        # Tactical: persisted order index on an unfiltered base build side
        # turns the build phase into a no-op (merge-join path).
        r_order = None
        if (p.get("right_base") and rmask is None and nk == 1
                and self.db.index_manager is not None):
            r_order = self.db.index_manager.auto_order_index(
                p["right_base"], p["right_keys"][0], rc)
            if r_order is not None:
                self.stats.index_hits += 1

        how = p["how"]
        lidx, ridx = _hash_join(lc, rc, how, r_order=r_order)
        if how in ("semi", "anti"):
            return (lsel[lidx],)
        glidx = lsel[lidx]
        gridx = np.where(ridx < 0, -1, rsel[np.maximum(ridx, 0)]) \
            if how == "left" else rsel[ridx]
        return glidx, gridx

    def _op_group(self, ins, regs):
        p = ins.payload
        nk = p["n_keys"]
        keys = [regs[a] for a in ins.args[:nk]]
        mask = regs[ins.args[nk]] if p["has_mask"] else None
        some = keys[0] if keys else (
            regs[ins.args[0]] if p.get("rep") else None)
        nrows = len(np.asarray(some.values)) if some is not None else (
            len(mask) if mask is not None else 0)
        idx = np.nonzero(mask)[0] if mask is not None \
            else np.arange(nrows, dtype=np.int64)
        if nk == 0:
            gid = np.zeros(len(idx), dtype=np.int64)
            return gid, 1, idx
        key_bytes = sum(np.asarray(k.values).dtype.itemsize for k in keys)
        if self.policy.group_spills(len(idx), key_bytes,
                                    lambda: _probe_group_state(keys, idx)):
            # grace-hash partition (policy: big input AND big probed
            # grouping state).  VARCHAR keys partition on their int32
            # dictionary codes: a group-by key has exactly one heap, and
            # the order-preserving code assignment makes code ranges
            # string ranges.
            from . import spill
            self._note_spill(any(k.dbtype == DBType.VARCHAR for k in keys))
            return spill.grace_hash_groupby(keys, idx, self.bufman)
        codes, _ = _factorize(keys, idx)
        gid, n, rep = _dense_gid(codes)
        # runtime statistic for the plan cache's cardinality feedback: the
        # group count this aggregate actually produced
        prev = self.stats.observed_group_card
        self.stats.observed_group_card = n if prev is None else max(prev, n)
        return gid, n, idx

    def _op_gkey(self, ins, regs):
        key: ExprResult = regs[ins.args[0]]
        gid = regs[ins.args[1]]
        n = regs[ins.args[2]]
        idx = regs[ins.args[3]]
        _, rep = np.unique(gid, return_index=True)
        pos = idx[rep]
        v = np.asarray(key.values)[pos]
        return ExprResult(v, key.dbtype,
                          None if key.null is None
                          else np.asarray(key.null)[pos],
                          key.heap, key.scale)

    def _op_agg(self, ins, regs):
        p = ins.payload
        if p["has_value"]:
            val = regs[ins.args[0]]
            gid, n, idx = (regs[a] for a in ins.args[1:4])
        else:
            val = None
            gid, n, idx = (regs[a] for a in ins.args[0:3])
        return _run_agg(p["fn"], val, gid, n, idx)

    def _op_sort(self, ins, regs):
        p = ins.payload
        keys = [regs[a] for a in ins.args]
        descs = p["descs"]
        n = len(np.asarray(keys[0].values))
        if self.policy.spills(self.policy.sort_state_bytes(n, len(keys))):
            from . import spill
            self._note_spill(any(k.dbtype == DBType.VARCHAR for k in keys))
            return spill.external_merge_sort(keys, descs, p["limit"],
                                             self.bufman)
        arrs = [
            _sort_key_float(r, d) for r, d in zip(keys, descs)
        ]
        idx = np.lexsort(tuple(reversed(arrs)))
        if p["limit"] is not None:
            idx = idx[:p["limit"]]
        return idx

    def _op_result(self, ins, regs):
        from .types import ColumnSchema, TableSchema
        names = ins.payload
        results = [regs[reg] for reg in ins.args]
        n_rows = len(np.asarray(results[0].values)) if results else 0
        total = sum(n_rows * STORAGE_DTYPE[r.dbtype].itemsize
                    for r in results)
        # budgeted result materialization: an over-budget final table
        # streams to memmapped columns instead of a second RAM copy (the
        # policy decision; string heaps stay shared in RAM — only the
        # fixed-width code/value arrays go to disk)
        spill = n_rows > 0 and self.bufman is not None \
            and self.policy.result_spills(total)
        cols = {}
        schemas = []
        for name, r in zip(names, results):
            v = self._stream_result_column(r, n_rows) if spill \
                else _result_chunk(r, slice(None))
            cols[name] = Column(r.dbtype, v, heap=r.heap, scale=r.scale)
            schemas.append(ColumnSchema(name, r.dbtype, scale=r.scale))
        if spill:
            self.bufman.bump(result_spills=1)
        from .table import Table
        return Table(TableSchema("result", tuple(schemas)), cols)

    def _stream_result_column(self, r: ExprResult, n_rows: int) -> np.ndarray:
        """Write one result column to a spill file morsel-by-morsel (the
        storage-dtype conversion runs per morsel, so no second full-size
        RAM array exists) and map it back with ``np.memmap``.  The file is
        unlinked immediately after mapping — POSIX keeps the pages
        reachable until the mapping is dropped — so no spill file outlives
        the result table and ``active_files`` returns to zero."""
        from .buffers import choose_morsel_rows
        from .storage import morsel_ranges
        want = STORAGE_DTYPE[r.dbtype]
        morsel = choose_morsel_rows(want.itemsize, self.bufman.budget)
        path = self.bufman.new_spill_file("result")
        try:
            with open(path, "wb") as f:
                for s, e in morsel_ranges(n_rows, morsel):
                    f.write(np.ascontiguousarray(
                        _result_chunk(r, slice(s, e))).tobytes())
            return np.memmap(path, dtype=want, mode="r")
        finally:
            self.bufman.release_file(path)
