"""Concurrent serving layer: admission control, plan cache, shared scans.

MonetDBLite is an *embedded* engine — it lives inside analytical host
processes that are themselves multi-threaded, so many queries contend for
ONE ``memory_budget`` and ONE ``device_budget``.  Everything below builds on
the unified physical planner (``physplan.PhysicalPlan``), which already
attaches a byte reservation to every operator:

* ``AdmissionGate`` — atomically reserves a plan's summed per-operator
  budget reservations against the host and device budgets *before*
  execution.  Queries whose reservations do not fit queue on a condition
  variable with a bounded wait instead of discovering pressure mid-flight
  (and then racing each other's eviction/spill decisions).  A reservation
  is an admission-control figure, not a pin: the ``BufferManager`` still
  enforces the real budget underneath, so the gate bounds *expected*
  pressure while pin accounting bounds actual bytes.

* ``PlanCache`` — maps ``(plan repr, entry-point flags, table versions,
  budgets, mesh)`` to a finished ``PhysicalPlan`` so hot repeated queries
  skip optimize→normalize→annotate entirely (~0.06 ms/query of pure
  planning).  Entries are invalidated by ``append`` / ``DROP TABLE`` /
  ``DELETE`` (the version component of the key makes stale hits impossible
  even without the explicit invalidation — the invalidation bounds the
  cache, the key guarantees correctness).  The cache also carries the
  feedback loop the ROADMAP asks for: observed group cardinalities from
  execution are keyed by plan shape *without* versions, so a re-plan after
  an append refines its ``TierPolicy`` estimate with what the last run
  actually saw.

* ``SingleFlight`` — the shared-morsel-scan primitive ("The End of an
  Architectural Era": concurrent queries over the same table should attach
  to one in-flight scan, not each re-read it).  ``do(key, build)`` lets the
  first caller run ``build`` while every concurrent caller of the same key
  blocks and *attaches* to that result — one host read and one
  host→device upload instead of N.  ``DeviceBufferManager.get_or_put``
  wires it under the block cache; the host tier shares base columns by
  reference already, so the device path is where the duplicated work was.
"""

from __future__ import annotations

import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Optional

from .relalg import PlanNode, ScanNode, plan_repr, walk

# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


class AdmissionTimeout(RuntimeError):
    """The bounded wait for budget reservations elapsed: the serving layer
    is saturated.  Embedders catch this and shed load instead of piling
    more queries onto an already over-committed budget."""


@dataclass
class AdmissionStats:
    admitted: int = 0            # queries that acquired their reservation
    queued: int = 0              # admissions that had to wait at least once
    timeouts: int = 0            # bounded waits that expired
    host_reserved_peak: int = 0  # high-water mark of summed host reservations
    device_reserved_peak: int = 0


class AdmissionGate:
    """Atomic budget reservations for whole queries.

    ``admit(host_bytes, device_bytes)`` blocks until both reservations fit
    their budgets (``None`` = unlimited: that side always fits) and returns
    a context-managed ticket; exiting the ticket releases the reservation
    and wakes queued queries.  Requests are capped at the budget itself —
    a plan whose per-operator reservations sum past the budget is exactly
    the plan the spill/stream tiers exist for, and it must be admissible
    when running alone."""

    def __init__(self, host_budget: Optional[int],
                 device_budget: Optional[int],
                 max_wait: float = 30.0):
        self.host_budget = host_budget
        self.device_budget = device_budget
        self.max_wait = float(max_wait)
        self._cond = threading.Condition()
        self._host_reserved = 0
        self._device_reserved = 0
        self.stats = AdmissionStats()

    # -- introspection -------------------------------------------------------
    @property
    def host_reserved(self) -> int:
        with self._cond:
            return self._host_reserved

    @property
    def device_reserved(self) -> int:
        with self._cond:
            return self._device_reserved

    def _cap(self, req: int, budget: Optional[int]) -> int:
        if budget is None:
            return 0                  # unlimited: nothing to reserve against
        return min(int(req), budget)

    def _fits(self, host_req: int, device_req: int) -> bool:  # requires-lock: _cond
        if self.host_budget is not None \
                and self._host_reserved + host_req > self.host_budget:
            return False
        if self.device_budget is not None \
                and self._device_reserved + device_req > self.device_budget:
            return False
        return True

    class _Ticket:
        def __init__(self, gate: "AdmissionGate", host: int, device: int,
                     waited: float):
            self._gate = gate
            self.host_bytes = host
            self.device_bytes = device
            self.waited = waited      # seconds spent queued (0.0 = immediate)
            self._released = False

        def release(self) -> None:
            if self._released:
                return
            self._released = True
            gate = self._gate
            with gate._cond:
                gate._host_reserved -= self.host_bytes
                gate._device_reserved -= self.device_bytes
                gate._cond.notify_all()

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            self.release()
            return False

    def admit(self, host_bytes: int, device_bytes: int = 0,
              timeout: Optional[float] = None) -> "_Ticket":
        """Reserve-or-queue.  Raises ``AdmissionTimeout`` after ``timeout``
        (default ``max_wait``) seconds of queueing."""
        host_req = self._cap(host_bytes, self.host_budget)
        device_req = self._cap(device_bytes, self.device_budget)
        limit = self.max_wait if timeout is None else float(timeout)
        start = time.monotonic()
        waited = False
        with self._cond:
            while not self._fits(host_req, device_req):
                if not waited:
                    waited = True
                    self.stats.queued += 1
                remaining = limit - (time.monotonic() - start)
                if remaining <= 0 or not self._cond.wait(remaining):
                    self.stats.timeouts += 1
                    raise AdmissionTimeout(
                        f"admission wait exceeded {limit:.1f}s "
                        f"(host {self._host_reserved}/{self.host_budget}, "
                        f"device {self._device_reserved}"
                        f"/{self.device_budget})")
            self._host_reserved += host_req
            self._device_reserved += device_req
            self.stats.admitted += 1
            self.stats.host_reserved_peak = max(
                self.stats.host_reserved_peak, self._host_reserved)
            self.stats.device_reserved_peak = max(
                self.stats.device_reserved_peak, self._device_reserved)
        return self._Ticket(self, host_req, device_req,
                            time.monotonic() - start if waited else 0.0)


# ---------------------------------------------------------------------------
# physical-plan cache
# ---------------------------------------------------------------------------


def plan_tables(plan: PlanNode) -> list[str]:
    """Every base table a plan scans (duplicates removed, order stable)."""
    seen: list[str] = []
    for node in walk(plan):
        if isinstance(node, ScanNode) and node.table not in seen:
            seen.append(node.table)
    return seen


@dataclass
class _CacheEntry:
    phys: object                     # the finished PhysicalPlan
    rendered: str                    # its EXPLAIN text (annotation, cached)
    tables: tuple[str, ...]          # for explicit invalidation


class PlanCache:
    """LRU cache of finished physical plans + the cardinality feedback map.

    Keys carry the logical plan's repr, the lowering flags, every scanned
    table's version, both budgets and the batch geometry knob — anything
    that changes the lowering changes the key, so a hit is always safe to
    reuse (modulo the per-query mutable bits, which ``get`` strips by
    handing out a shallow copy)."""

    def __init__(self, capacity: int = 256):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, _CacheEntry]" = OrderedDict()
        # plan shape (no versions/budgets) -> observed group cardinality:
        # survives invalidation on purpose — the whole point of the loop is
        # that a re-plan after an append knows what the last run saw
        self._cards: dict[tuple, int] = {}
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    # -- keys -----------------------------------------------------------------
    @staticmethod
    def key(db, plan: PlanNode, *, do_optimize: bool, distributed: bool,
            mesh_key=None) -> tuple:
        from .physplan import DEVICE_PROMOTE_HITS
        bm = getattr(db, "buffer_manager", None)
        dm = getattr(db, "device_manager", None)
        tables = plan_tables(plan)
        # delta geometry joins the version fence: an append bumps the
        # table version AND the delta epoch, and a threshold compaction
        # keeps the version but changes base_version/delta_epoch (and the
        # physical layout the plan annotated), so the key must see all
        # three — a compacted table must never be served the pre-compaction
        # plan's delta annotations
        versions = tuple(
            (t, (db.catalog.tables[t].version,
                 db.catalog.tables[t].base_version,
                 db.catalog.tables[t].delta_epoch))
            for t in tables if t in db.catalog.tables)
        # tier evidence: choose_device_tier flips a borderline table from
        # streamed to resident once its hit history crosses the promotion
        # threshold — key on the *decision input* (the crossed/not-crossed
        # boolean, which stabilizes) rather than the raw counter (which
        # would change every query and defeat the cache)
        promoted = None if (dm is None or not distributed) else tuple(
            dm.hit_history(t) >= DEVICE_PROMOTE_HITS for t in tables)
        return (plan_repr(plan), bool(do_optimize), bool(distributed),
                versions,
                None if bm is None else bm.budget,
                None if dm is None else dm.budget,
                getattr(db, "device_batch_rows", None),
                mesh_key, promoted,
                # imprint-driven skipping: cached plans carry skip-sets, so
                # the forced-off knob must never be served a skipping plan
                # (and vice versa).  Staleness is impossible without this
                # last guard too — skip-sets bind a table version and the
                # ``versions`` component already keys on it — but the knob
                # changes the *shape* of the plan's annotations.
                bool(getattr(db, "data_skipping", True)))

    @staticmethod
    def shape_key(plan: PlanNode, distributed: bool) -> tuple:
        """Version/budget-free identity used by the cardinality feedback."""
        return (plan_repr(plan), bool(distributed))

    # -- lookup / store -------------------------------------------------------
    def get(self, key: tuple):
        """Hit returns ``(physical plan copy, rendered text)``; the copy
        shields the cached entry from per-query mutation (a runtime device
        demotion must not downgrade every future hit)."""
        import copy
        with self._lock:
            entry = self._entries.get(key)
            if entry is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return copy.copy(entry.phys), entry.rendered

    def put(self, key: tuple, phys, rendered: str) -> None:
        with self._lock:
            self._entries[key] = _CacheEntry(
                phys, rendered, tuple(t for t, _ in key[3]))
            self._entries.move_to_end(key)
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)

    # -- invalidation ---------------------------------------------------------
    def invalidate_table(self, table: str) -> None:
        """Drop every cached plan that scans ``table`` (append / DROP /
        DELETE).  The version component of the key already prevents stale
        hits; this keeps dead versions from occupying cache slots."""
        with self._lock:
            for k in [k for k, e in self._entries.items()
                      if table in e.tables]:
                del self._entries[k]

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._cards.clear()

    # -- cardinality feedback -------------------------------------------------
    def note_group_card(self, shape: tuple, n_groups: int) -> None:
        with self._lock:
            self._cards[shape] = int(n_groups)

    def group_card(self, shape: tuple) -> Optional[int]:
        with self._lock:
            return self._cards.get(shape)


# ---------------------------------------------------------------------------
# shared scans (single-flight)
# ---------------------------------------------------------------------------


class SingleFlight:
    """Per-key in-flight deduplication: the first caller of ``do(key,
    build)`` runs ``build``; concurrent callers with the same key block and
    receive the same result (``attached=True``).  A failed build propagates
    to the builder only — attachers retry as builders, so one thread's
    error never poisons another's query."""

    class _Call:
        __slots__ = ("event", "result", "error")

        def __init__(self):
            self.event = threading.Event()
            self.result = None
            self.error = None

    def __init__(self):
        self._lock = threading.Lock()
        self._calls: dict = {}
        self.attaches = 0            # callers served by another's build

    def do(self, key, build: Callable[[], object]):
        """Returns ``(result, attached)``."""
        while True:
            with self._lock:
                call = self._calls.get(key)
                if call is None:
                    call = self._Call()
                    self._calls[key] = call
                    mine = True
                else:
                    mine = False
            if mine:
                try:
                    call.result = build()
                except BaseException as e:
                    call.error = e
                    raise
                finally:
                    with self._lock:
                        self._calls.pop(key, None)
                    call.event.set()
                return call.result, False
            call.event.wait()
            if call.error is None:
                with self._lock:
                    self.attaches += 1
                return call.result, True
            # builder failed: loop and try to become the builder ourselves


# ---------------------------------------------------------------------------
# cached lowering (the executor entry point)
# ---------------------------------------------------------------------------


def lower_cached(db, plan: PlanNode, *, do_optimize: bool = True,
                 distributed: bool = False, mesh=None):
    """``physplan.plan_physical`` with the serving layer's plan cache in
    front: returns ``(phys, rendered, cache_hit)``.  Databases without a
    cache (suffix views, snapshot scratch dbs) lower directly."""
    from .physplan import plan_physical
    cache: Optional[PlanCache] = getattr(db, "plan_cache", None)
    mesh_key = None if mesh is None else (
        tuple(mesh.shape.items()), tuple(d.id for d in mesh.devices.flat))
    if cache is None:
        phys = plan_physical(plan, db, do_optimize=do_optimize,
                             distributed=distributed, mesh=mesh)
        return phys, phys.render(), False
    key = PlanCache.key(db, plan, do_optimize=do_optimize,
                        distributed=distributed, mesh_key=mesh_key)
    bm = getattr(db, "buffer_manager", None)
    hit = cache.get(key)
    if hit is not None:
        if bm is not None:
            bm.bump(plan_cache_hits=1)
        phys, rendered = hit
        return phys, rendered, True
    if bm is not None:
        bm.bump(plan_cache_misses=1)
    phys = plan_physical(plan, db, do_optimize=do_optimize,
                         distributed=distributed, mesh=mesh,
                         group_card_hint=cache.group_card(
                             PlanCache.shape_key(plan, distributed)))
    rendered = phys.render()
    cache.put(key, phys, rendered)
    # the cached object is also the returned one on a miss: hand the
    # caller a copy for the same per-query-mutation reason get() does
    import copy
    return copy.copy(phys), rendered, False
