"""Relational-tree optimization passes (paper §3.1 level 1).

Passes, applied in order:
  1. constant folding inside expressions,
  2. predicate decomposition (split top-level ANDs),
  3. filter pushdown (through projections, into join inputs),
  4. inner-join-chain reordering (greedy: smallest estimated input first),
  5. projection / column pruning (scans load only referenced columns).

MAL-level CSE (level 2) lives in executor.compile_plan; tactical decisions
(level 3: join algorithm choice) are made at runtime in executor.run.
"""

from __future__ import annotations

from typing import Optional


from .expression import (BinOp, Case, Cast, Col, Expr, Func, InList, IsNull,
                         Like, Lit, Not)
from .relalg import (AggregateNode, FilterNode, JoinNode, LimitNode,
                     OrderByNode, PlanNode, ProjectNode, ScanNode)


def optimize(plan: PlanNode, catalog) -> PlanNode:
    plan = _fold_expressions(plan)
    plan = _push_filters(plan, catalog)
    plan = _reorder_joins(plan, catalog)
    plan = _push_filters(plan, catalog)     # re-push after reorder
    plan = _prune_columns(plan, catalog)
    return plan


# ---------------------------------------------------------------------------
# 1. constant folding
# ---------------------------------------------------------------------------


def fold_expr(e: Expr) -> Expr:
    if isinstance(e, BinOp):
        l, r = fold_expr(e.left), fold_expr(e.right)
        if isinstance(l, Lit) and isinstance(r, Lit) \
                and l.value is not None and r.value is not None \
                and e.op in ("+", "-", "*", "/") \
                and not isinstance(l.value, str):
            lv, rv = l.value, r.value
            try:
                out = {"+": lv + rv, "-": lv - rv, "*": lv * rv,
                       "/": lv / rv if rv != 0 else None}[e.op]
                if out is not None:
                    return Lit(out)
            except Exception:
                pass
        return BinOp(e.op, l, r)
    if isinstance(e, Not):
        return Not(fold_expr(e.child))
    if isinstance(e, IsNull):
        return IsNull(fold_expr(e.child), e.negate)
    if isinstance(e, InList):
        return InList(fold_expr(e.child), e.values)
    if isinstance(e, Like):
        return Like(fold_expr(e.child), e.pattern)
    if isinstance(e, Func):
        f = Func.__new__(Func)
        f.name, f.args = e.name, tuple(fold_expr(a) for a in e.args)
        return f
    if isinstance(e, Case):
        return Case(tuple((fold_expr(c), fold_expr(v))
                          for c, v in e.branches), fold_expr(e.default))
    if isinstance(e, Cast):
        return Cast(fold_expr(e.child), e.to)
    return e


def _map_exprs(node: PlanNode, fn) -> PlanNode:
    node = node.with_children(tuple(_map_exprs(c, fn) for c in node.children))
    if isinstance(node, FilterNode):
        return FilterNode(node.child, fn(node.predicate))
    if isinstance(node, ProjectNode):
        return ProjectNode(node.child,
                           tuple((fn(e), n) for e, n in node.exprs))
    if isinstance(node, AggregateNode):
        from .relalg import AggSpec
        return AggregateNode(node.child, node.group_by, tuple(
            AggSpec(a.fn, fn(a.expr) if a.expr is not None else None, a.name)
            for a in node.aggs))
    return node


def _fold_expressions(plan: PlanNode) -> PlanNode:
    return _map_exprs(plan, fold_expr)


# ---------------------------------------------------------------------------
# 2+3. predicate decomposition + filter pushdown
# ---------------------------------------------------------------------------


def split_conjuncts(e: Expr) -> list[Expr]:
    if isinstance(e, BinOp) and e.op == "and":
        return split_conjuncts(e.left) + split_conjuncts(e.right)
    return [e]


def _conjoin(preds: list[Expr]) -> Expr:
    out = preds[0]
    for p in preds[1:]:
        out = BinOp("and", out, p)
    return out


def _substitute(e: Expr, mapping: dict[str, Expr]) -> Optional[Expr]:
    """Rewrite column refs through a projection; None if not rewritable."""
    if isinstance(e, Col):
        return mapping.get(e.name)
    if isinstance(e, Lit):
        return e
    if isinstance(e, BinOp):
        l = _substitute(e.left, mapping)
        r = _substitute(e.right, mapping)
        return BinOp(e.op, l, r) if l is not None and r is not None else None
    if isinstance(e, Not):
        c = _substitute(e.child, mapping)
        return Not(c) if c is not None else None
    if isinstance(e, IsNull):
        c = _substitute(e.child, mapping)
        return IsNull(c, e.negate) if c is not None else None
    if isinstance(e, InList):
        c = _substitute(e.child, mapping)
        return InList(c, e.values) if c is not None else None
    if isinstance(e, Like):
        c = _substitute(e.child, mapping)
        return Like(c, e.pattern) if c is not None else None
    if isinstance(e, Func):
        args = [_substitute(a, mapping) for a in e.args]
        if any(a is None for a in args):
            return None
        f = Func.__new__(Func)
        f.name, f.args = e.name, tuple(args)
        return f
    if isinstance(e, Cast):
        c = _substitute(e.child, mapping)
        return Cast(c, e.to) if c is not None else None
    return None  # Case / DateLit handled conservatively


def _push_filters(node: PlanNode, catalog, pending: list[Expr] = None) -> PlanNode:
    pending = list(pending or [])

    if isinstance(node, FilterNode):
        pending.extend(split_conjuncts(node.predicate))
        return _push_filters(node.child, catalog, pending)

    if isinstance(node, ProjectNode):
        mapping = {n: e for e, n in node.exprs}
        stay, push = [], []
        for p in pending:
            sub = _substitute(p, mapping)
            (push if sub is not None else stay).append(
                sub if sub is not None else p)
        child = _push_filters(node.child, catalog, push)
        out: PlanNode = ProjectNode(child, node.exprs)
        return FilterNode(out, _conjoin(stay)) if stay else out

    if isinstance(node, JoinNode):
        lcols = set(node.left.output_columns(catalog))
        rcols = set(node.right.output_columns(catalog))
        lp, rp, stay = [], [], []
        for p in pending:
            refs = p.columns()
            if refs and refs <= lcols:
                lp.append(p)
            elif refs and refs <= rcols and node.how == "inner":
                rp.append(p)
            else:
                stay.append(p)
        left = _push_filters(node.left, catalog, lp)
        right = _push_filters(node.right, catalog, rp)
        out: PlanNode = JoinNode(left, right, node.left_keys,
                                 node.right_keys, node.how)
        return FilterNode(out, _conjoin(stay)) if stay else out

    if isinstance(node, AggregateNode):
        keys = set(node.group_by)
        push, stay = [], []
        for p in pending:
            (push if p.columns() and p.columns() <= keys else stay).append(p)
        child = _push_filters(node.child, catalog, push)
        out: PlanNode = AggregateNode(child, node.group_by, node.aggs)
        return FilterNode(out, _conjoin(stay)) if stay else out

    if isinstance(node, (OrderByNode, LimitNode)):
        # limits do not commute with filters; stop pushing
        child = _push_filters(node.children[0], catalog, [])
        out = node.with_children((child,))
        return FilterNode(out, _conjoin(pending)) if pending else out

    if isinstance(node, ScanNode):
        return FilterNode(node, _conjoin(pending)) if pending else node

    children = tuple(_push_filters(c, catalog, []) for c in node.children)
    out = node.with_children(children)
    return FilterNode(out, _conjoin(pending)) if pending else out


# ---------------------------------------------------------------------------
# 4. inner-join-chain reordering (greedy by estimated cardinality)
# ---------------------------------------------------------------------------


def estimate_rows(node: PlanNode, catalog) -> float:
    """Cardinality estimate (paper optimization level 1 statistics).  Used
    for join-order decisions below and by the execution tiers to predict
    whether a plan's blocking intermediates fit the memory budget."""
    if isinstance(node, ScanNode):
        return float(catalog.table(node.table).num_rows)
    if isinstance(node, FilterNode):
        return 0.25 * estimate_rows(node.child, catalog)
    if isinstance(node, JoinNode):
        l = estimate_rows(node.left, catalog)
        r = estimate_rows(node.right, catalog)
        return max(l, r)
    if isinstance(node, AggregateNode):
        return max(1.0, 0.1 * estimate_rows(node.child, catalog))
    if isinstance(node, LimitNode):
        return float(node.n)
    if node.children:
        return estimate_rows(node.children[0], catalog)
    return 1.0


def estimate_bytes(node: PlanNode, catalog) -> float:
    """Upper-ish bound on the widest intermediate a plan materializes:
    max over plan nodes of (estimated rows x output width).  The parallel
    tier uses this to keep the sharded fast path for fitting inputs and
    leave oversized plans to the host tier's spill operators."""
    try:
        width = 8.0 * max(1, len(node.output_columns(catalog)))
    except Exception:
        width = 8.0
    own = estimate_rows(node, catalog) * width
    return max([own] + [estimate_bytes(c, catalog) for c in node.children])


# Device-tier placement (choose_device_tier) moved to physplan.py: tier
# routing is the unified physical planner's job; this module keeps the
# level-1 statistics (estimate_rows / estimate_bytes) the planner costs
# plans with.


def _reorder_joins(node: PlanNode, catalog) -> PlanNode:
    """Left-deep inner-equi-join chains: put the smaller input on the build
    (right) side of each join.  Conservative: swaps a single join's sides
    when the right side is estimated larger; key lists swap with them."""
    node = node.with_children(
        tuple(_reorder_joins(c, catalog) for c in node.children))
    if isinstance(node, JoinNode) and node.how == "inner":
        l = estimate_rows(node.left, catalog)
        r = estimate_rows(node.right, catalog)
        if r > l * 1.5:
            # probe the big side, build on the small side: swap
            return JoinNode(node.right, node.left, node.right_keys,
                            node.left_keys, "inner")
    return node


# ---------------------------------------------------------------------------
# 5. projection pruning (column pruning down to scans)
# ---------------------------------------------------------------------------


def _prune_columns(node: PlanNode, catalog,
                   needed: Optional[set[str]] = None) -> PlanNode:
    if isinstance(node, ScanNode):
        all_cols = list(catalog.table(node.table).schema.names)
        if needed is None:
            cols = tuple(all_cols)
        else:
            cols = tuple(c for c in all_cols if c in needed)
            if not cols:
                cols = (all_cols[0],)          # keep one col for row count
        return ScanNode(node.table, cols)

    if isinstance(node, FilterNode):
        child_needed = None if needed is None else (
            set(needed) | node.predicate.columns())
        return FilterNode(
            _prune_columns(node.child, catalog, child_needed),
            node.predicate)

    if isinstance(node, ProjectNode):
        exprs = node.exprs if needed is None else tuple(
            (e, n) for e, n in node.exprs if n in needed) or node.exprs[:1]
        child_needed = set()
        for e, _ in exprs:
            child_needed |= e.columns()
        return ProjectNode(
            _prune_columns(node.child, catalog, child_needed or None), exprs)

    if isinstance(node, AggregateNode):
        child_needed = set(node.group_by)
        for a in node.aggs:
            if a.expr is not None:
                child_needed |= a.expr.columns()
        return AggregateNode(
            _prune_columns(node.child, catalog, child_needed or None),
            node.group_by, node.aggs)

    if isinstance(node, JoinNode):
        lcols = set(node.left.output_columns(catalog))
        rcols = set(node.right.output_columns(catalog))
        if needed is None:
            ln, rn = None, None
        else:
            ln = (set(needed) & lcols) | set(node.left_keys)
            rn = (set(needed) & rcols) | set(node.right_keys)
        return JoinNode(_prune_columns(node.left, catalog, ln),
                        _prune_columns(node.right, catalog, rn),
                        node.left_keys, node.right_keys, node.how)

    if isinstance(node, OrderByNode):
        child_needed = None if needed is None else (
            set(needed) | {k for k, _ in node.keys})
        return OrderByNode(
            _prune_columns(node.child, catalog, child_needed),
            node.keys, node.limit)

    if isinstance(node, LimitNode):
        return LimitNode(_prune_columns(node.child, catalog, needed), node.n)

    return node.with_children(
        tuple(_prune_columns(c, catalog, None) for c in node.children))
