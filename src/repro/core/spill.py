"""Spill-aware (out-of-core) blocking operators.

The paper's differentiator over in-memory analytics tools (§1, §4) is that a
real RDBMS keeps working when intermediates outgrow RAM.  This module gives
the engine that tier: each blocking operator — group/aggregate, join, sort —
has an external variant that hash/range-partitions its input into
memmap-backed run files (via buffers.BufferManager) and streams partitions
back through the existing column-at-a-time kernels.

Result-identity contract (asserted in tests/test_outofcore.py): every
operator here returns *bit-identical* output to its in-memory twin in
executor.py:

* ``grace_hash_groupby`` range-partitions on the first group key with
  sample-quantile splitters, so partitions are ordered and the concatenated
  per-partition dense gids reproduce the global lexicographic group order of
  ``_factorize``/``_dense_gid``;
* ``partitioned_hash_join`` hash-partitions both sides, joins partition
  pairs with the same ``_join_codes``/``_hash_join`` kernels, then stably
  re-sorts the output pairs by left row — recovering the probe-order output
  of the in-memory join;
* ``external_merge_sort`` sorts budget-sized runs with the same
  ``lexsort`` keys and merges with the original row index as tiebreaker,
  which is exactly stable-lexsort order.

Every partition's processing is wrapped in ``bufman.pinned`` so the tracked
high-water mark stays under the budget; run files are deleted as soon as
their partition is consumed.
"""

from __future__ import annotations

import heapq
import pickle
from typing import Iterable, Iterator, Optional

import numpy as np

from .buffers import (BufferManager, PartitionWriter, choose_morsel_rows,
                      choose_partitions)
from .expression import ExprResult
from .storage import morsel_ranges

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)


def _key_row_bytes(results: list) -> int:
    return sum(np.asarray(r.values).dtype.itemsize for r in results)


def _slice_result(r: ExprResult, sl) -> ExprResult:
    """Morsel view of an ExprResult (values + null mask, metadata shared)."""
    return ExprResult(np.asarray(r.values)[sl], r.dbtype,
                      None if r.null is None else np.asarray(r.null)[sl],
                      r.heap, r.scale)


def _gather_result(r: ExprResult, arr: np.ndarray) -> ExprResult:
    """Rebuild an ExprResult around values read back from a spill file."""
    return ExprResult(arr, r.dbtype, None, r.heap, r.scale)


# ---------------------------------------------------------------------------
# grace-hash aggregation (range-partitioned, group-order preserving)
# ---------------------------------------------------------------------------


def _lex_float(arr: np.ndarray) -> np.ndarray:
    """Partitioning representation of raw key values: float64 with NaN
    normalized to +inf (np.unique sorts NaN after inf, and co-locating the
    two costs only balance, never correctness)."""
    f = np.asarray(arr, dtype=np.float64)
    return np.where(np.isnan(f), np.inf, f)


def _composite_splitters(key_arrays: list, idx: np.ndarray,
                         n_parts: int) -> np.ndarray:
    """Sample-quantile splitter *tuples* over the full group key.

    Partitioning on the composite key (not just the first column) keeps
    partitions balanced when the leading key is low-cardinality — e.g.
    GROUP BY city, fare with three cities.  Quantiles (not min/max linspace)
    also stay balanced when the domain holds extreme values such as the
    in-domain NULL sentinel ``-2**63``.  Returns an (n_splitters, n_keys)
    matrix of lexicographically ascending, deduplicated boundary tuples."""
    if n_parts <= 1:
        return np.empty((0, len(key_arrays)), dtype=np.float64)
    stride = max(1, len(idx) // 65536)
    samp = idx[::stride]
    cols = [_lex_float(a[samp]) for a in key_arrays]
    order = np.lexsort(tuple(reversed(cols)))
    mat = np.stack([c[order] for c in cols], axis=1)
    picks = (np.arange(1, n_parts) * len(samp)) // n_parts
    splitters = mat[np.clip(picks, 0, len(samp) - 1)]
    return np.unique(splitters, axis=0)


def _composite_partition(key_cols: list, splitters: np.ndarray) -> np.ndarray:
    """Partition id per row: the count of splitter tuples lexicographically
    below the row's key tuple.  Monotone in group-sort order and constant on
    equal keys — the two properties order-preserving grace hashing needs."""
    n = len(key_cols[0])
    part = np.zeros(n, dtype=np.int64)
    for s in splitters:
        gt = np.zeros(n, dtype=bool)
        eq = np.ones(n, dtype=bool)
        for j, v in enumerate(key_cols):
            gt |= eq & (v > s[j])
            eq &= v == s[j]
        part += gt
    return part


def grace_hash_groupby(keys: list, idx: np.ndarray, bufman: BufferManager):
    """External GROUP BY: returns the same ``(gid, n_groups, idx)`` triple as
    the in-memory ``_op_group``, with identical group numbering.

    Rows are range-partitioned on the composite key tuple so partition p's
    groups all sort before partition p+1's; within a partition the normal
    factorize path runs, and per-partition gids are shifted by a running
    offset.  Equal key tuples always share a partition, and NaN keys land
    after finite values — matching ``np.unique``'s NaN-last order.
    """
    from .executor import _dense_gid, _factorize

    n = len(idx)
    row_bytes = _key_row_bytes(keys) + 8
    n_parts = choose_partitions(n * row_bytes, bufman.budget)
    morsel = choose_morsel_rows(row_bytes, bufman.budget)
    key_arrays = [np.asarray(k.values) for k in keys]
    splitters = _composite_splitters(key_arrays, idx, n_parts)

    streams = {"idx": np.dtype(np.int64)}
    for i, k in enumerate(keys):
        streams[f"k{i}"] = np.asarray(k.values).dtype
    writer = PartitionWriter(bufman, n_parts, streams, hint="grp")
    for s, e in morsel_ranges(n, morsel):
        sub = idx[s:e]
        part = _composite_partition([_lex_float(ka[sub])
                                     for ka in key_arrays], splitters)
        chunks = {"idx": sub}
        for i, ka in enumerate(key_arrays):
            chunks[f"k{i}"] = ka[sub]
        with bufman.pinned(sub.nbytes + sum(
                ka[sub].nbytes for ka in key_arrays)):
            writer.append(part, chunks)

    out_gid, out_idx = [], []
    offset = 0
    for partn in writer.finalize():
        if partn.rows == 0:
            partn.release()
            continue
        with bufman.pinned(partn.nbytes):
            arrs = partn.load()
            sub_results = [_gather_result(k, arrs[f"k{i}"])
                           for i, k in enumerate(keys)]
            codes, _ = _factorize(sub_results)
            gid, n_local, _ = _dense_gid(codes)
            out_gid.append(gid + offset)
            out_idx.append(arrs["idx"])
            offset += n_local
        partn.release()
    if not out_gid:
        return np.zeros(0, dtype=np.int64), 0, np.zeros(0, dtype=np.int64)
    return (np.concatenate(out_gid).astype(np.int64), int(offset),
            np.concatenate(out_idx).astype(np.int64))


# ---------------------------------------------------------------------------
# partitioned (grace) hash join
# ---------------------------------------------------------------------------


def _hash_partition(values: np.ndarray, n_parts: int,
                    as_float: bool) -> np.ndarray:
    """Deterministic bucket per raw key value, identical across both sides.

    Floats are normalized (-0.0 -> +0.0) then bit-hashed; integer families
    widen to int64 so INT32 and INT64 keys bucket together."""
    if as_float:
        bits = (np.asarray(values, dtype=np.float64) + 0.0).view(np.uint64)
    else:
        bits = np.asarray(values).astype(np.int64).view(np.uint64)
    h = bits * _GOLDEN
    h = h ^ (h >> np.uint64(29))
    return (h % np.uint64(n_parts)).astype(np.int64)


def spillable_join_keys(lres: list, rres: list) -> bool:
    """VARCHAR keys are only partitionable when both sides share one heap
    (dictionary codes then compare directly); otherwise the in-memory path
    must decode, so the spill tier declines."""
    from .types import DBType
    for lr, rr in zip(lres, rres):
        if (lr.dbtype == DBType.VARCHAR or rr.dbtype == DBType.VARCHAR) \
                and lr.heap is not rr.heap:
            return False
    return True


def _spool_side(results: list, sel: np.ndarray, bufman: BufferManager,
                n_parts: int, as_float: bool, hint: str):
    row_bytes = _key_row_bytes(results) + 8
    morsel = choose_morsel_rows(row_bytes, bufman.budget)
    streams = {"idx": np.dtype(np.int64)}
    for i, r in enumerate(results):
        streams[f"k{i}"] = np.asarray(r.values).dtype
    writer = PartitionWriter(bufman, n_parts, streams, hint=hint)
    arrays = [np.asarray(r.values) for r in results]
    first = arrays[0]
    for s, e in morsel_ranges(len(sel), morsel):
        sub = sel[s:e]
        part = _hash_partition(first[sub], n_parts, as_float)
        chunks = {"idx": sub}
        for i, a in enumerate(arrays):
            chunks[f"k{i}"] = a[sub]
        with bufman.pinned(sub.nbytes + sum(a[sub].nbytes for a in arrays)):
            writer.append(part, chunks)
    return writer.finalize()


def partitioned_hash_join(lres: list, rres: list, lsel: np.ndarray,
                          rsel: np.ndarray, how: str,
                          bufman: BufferManager):
    """External equi-join.  Inputs are the *pre-null-filtered* selected row
    positions of each side; output is the same global (lidx, ridx) pairs —
    in the same order — as the in-memory ``_op_join``."""
    from .executor import _hash_join, _join_codes
    from .types import is_float

    nk = len(lres)
    as_float = any(is_float(r.dbtype) for r in (lres + rres))
    row_bytes = _key_row_bytes(lres) + 8
    est = (len(lsel) + len(rsel)) * row_bytes
    n_parts = choose_partitions(est, bufman.budget)

    lparts = _spool_side(lres, lsel, bufman, n_parts, as_float, "jl")
    rparts = _spool_side(rres, rsel, bufman, n_parts, as_float, "jr")

    out_l, out_r = [], []
    for lp, rp in zip(lparts, rparts):
        if lp.rows == 0:
            lp.release(), rp.release()
            continue
        with bufman.pinned(lp.nbytes + rp.nbytes):
            larr = lp.load()
            rarr = rp.load()
            lidx_g = larr["idx"]
            ridx_g = rarr["idx"]
            if rp.rows == 0:
                if how == "anti":
                    out_l.append(lidx_g)
                elif how == "left":
                    out_l.append(lidx_g)
                    out_r.append(np.full(len(lidx_g), -1, dtype=np.int64))
                # inner / semi: no matches in this partition
            else:
                lsub = [_gather_result(r, larr[f"k{i}"])
                        for i, r in enumerate(lres)]
                rsub = [_gather_result(r, rarr[f"k{i}"])
                        for i, r in enumerate(rres)]
                lc, rc, _, _ = _join_codes(lsub, rsub, nk)
                lidx, ridx = _hash_join(lc, rc, how)
                if how in ("semi", "anti"):
                    out_l.append(lidx_g[lidx])
                else:
                    out_l.append(lidx_g[lidx])
                    out_r.append(np.where(
                        ridx < 0, -1, ridx_g[np.maximum(ridx, 0)]))
        lp.release(), rp.release()

    gl = np.concatenate(out_l).astype(np.int64) if out_l \
        else np.zeros(0, dtype=np.int64)
    # Recover probe order: in-memory output is sorted by global left row
    # (ties = one left row's matches, already in right-row order within the
    # single partition that owns the key) -> a stable sort by gl suffices.
    order = np.argsort(gl, kind="stable")
    if how in ("semi", "anti"):
        return (gl[order],)
    gr = np.concatenate(out_r).astype(np.int64) if out_r \
        else np.zeros(0, dtype=np.int64)
    return gl[order], gr[order]


# ---------------------------------------------------------------------------
# external merge sort
# ---------------------------------------------------------------------------


SORT_MERGE_FAN_IN = 64      # max run files open per merge pass (fd bound)


def _write_sort_run(bufman: BufferManager, run: np.ndarray) -> str:
    """Raw float64 row-major run file: appendable during cascade merges."""
    path = bufman.new_spill_file("sortrun")
    with open(path, "wb") as f:
        f.write(np.ascontiguousarray(run).tobytes())
    bufman.note_spilled(int(run.nbytes))
    return path


def _stream_sort_run(path: str, n_cols: int) -> Iterator[tuple]:
    mm = np.memmap(path, dtype=np.float64,
                   mode="r").reshape(-1, n_cols)   # OS-paged, not pinned
    for i in range(mm.shape[0]):
        row = mm[i]
        yield tuple(float(v) for v in row[:-1]) + (int(row[-1]),)


def external_merge_sort(keys: list, descs, limit: Optional[int],
                        bufman: BufferManager) -> np.ndarray:
    """External ORDER BY: returns the identical index vector np.lexsort
    would.  Budget-sized runs are lexsorted with the same float sort keys,
    spilled as ``(rows, n_keys+1)`` row-major float64 run files (last
    column = original row index), then merged with the row index as
    tiebreaker — which reproduces stable-lexsort order exactly.  When the
    run count exceeds ``SORT_MERGE_FAN_IN``, cascade passes merge groups of
    runs into longer runs first, bounding open file descriptors."""
    from .executor import _sort_key_float

    n = len(np.asarray(keys[0].values))
    n_cols = len(keys) + 1
    row_bytes = 8 * n_cols
    if bufman.budget is not None:
        run_rows = max(64, (bufman.budget // 2) // row_bytes)
    else:
        run_rows = n
    paths = []
    try:
        for s, e in morsel_ranges(n, run_rows):
            arrs = [_sort_key_float(_slice_result(r, slice(s, e)), d)
                    for r, d in zip(keys, descs)]
            with bufman.pinned((e - s) * row_bytes):
                local = np.lexsort(tuple(reversed(arrs)))
                run = np.empty((e - s, n_cols), dtype=np.float64)
                for j, a in enumerate(arrs):
                    run[:, j] = a[local]
                run[:, -1] = (s + local).astype(np.float64)
                paths.append(_write_sort_run(bufman, run))

        # cascade: collapse groups of runs until one merge pass suffices
        while len(paths) > SORT_MERGE_FAN_IN:
            next_paths = []
            for i in range(0, len(paths), SORT_MERGE_FAN_IN):
                group = paths[i:i + SORT_MERGE_FAN_IN]
                if len(group) == 1:
                    next_paths.append(group[0])
                    continue
                out_path = bufman.new_spill_file("sortmerge")
                written = 0
                with open(out_path, "wb") as f:
                    buf = []
                    for item in heapq.merge(
                            *(_stream_sort_run(p, n_cols) for p in group)):
                        buf.append(item)
                        if len(buf) >= 4096:
                            b = np.asarray(buf, dtype=np.float64)
                            f.write(b.tobytes())
                            written += b.nbytes
                            buf = []
                    if buf:
                        b = np.asarray(buf, dtype=np.float64)
                        f.write(b.tobytes())
                        written += b.nbytes
                bufman.note_spilled(written)
                for p in group:
                    bufman.release_file(p)
                next_paths.append(out_path)
            paths = next_paths

        if len(paths) == 1:
            mm = np.memmap(paths[0], dtype=np.float64,
                           mode="r").reshape(-1, n_cols)
            idx = np.asarray(mm[:, -1], dtype=np.int64)
            return idx[:limit] if limit is not None else idx

        out = []
        want = n if limit is None else min(limit, n)
        for item in heapq.merge(*(_stream_sort_run(p, n_cols)
                                  for p in paths)):
            out.append(item[-1])
            if len(out) >= want:
                break
        return np.asarray(out, dtype=np.int64)
    finally:
        for p in paths:
            bufman.release_file(p)


# ---------------------------------------------------------------------------
# row spooler (volcano tier)
# ---------------------------------------------------------------------------


def spooled_row_groups(rows: Iterable[dict], key_fn, bufman: BufferManager,
                       n_parts: int = 16) -> Iterator[tuple]:
    """Out-of-core grouping for the row-at-a-time volcano engine: spool rows
    to hash partitions (pickled batches), then yield ``(key, rows)`` one
    partition at a time.  A group lives entirely in one partition, so the
    caller can aggregate and discard each group's rows immediately."""
    paths = [bufman.new_spill_file(f"volrows{p}") for p in range(n_parts)]
    handles = [open(p, "wb") for p in paths]
    try:
        batches: list[list] = [[] for _ in range(n_parts)]
        for row in rows:
            p = hash(key_fn(row)) % n_parts
            batches[p].append(row)
            if len(batches[p]) >= 1024:
                pickle.dump(batches[p], handles[p])
                batches[p] = []
        for p in range(n_parts):
            if batches[p]:
                pickle.dump(batches[p], handles[p])
    finally:
        for p, h in enumerate(handles):
            bufman.note_spilled(h.tell())
            h.close()
    for p in range(n_parts):
        groups: dict = {}
        with open(paths[p], "rb") as f:
            while True:
                try:
                    batch = pickle.load(f)
                except EOFError:
                    break
                for row in batch:
                    groups.setdefault(key_fn(row), []).append(row)
        bufman.release_file(paths[p])
        yield from groups.items()
