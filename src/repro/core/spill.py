"""Spill-aware (out-of-core) blocking operators.

The paper's differentiator over in-memory analytics tools (§1, §4) is that a
real RDBMS keeps working when intermediates outgrow RAM.  This module gives
the engine that tier: each blocking operator — group/aggregate, join, sort —
has an external variant that hash/range-partitions its input into run files
(via buffers.BufferManager) and streams partitions back through the existing
column-at-a-time kernels.

Pipeline v2 adds three coordinated mechanisms on top of the PR-1 operators:

* **codec'd run files** — every stream goes through the block codec in
  buffers.py (frame-of-reference + byte-shuffle for integer key/index
  streams, raw passthrough for floats), cutting spill I/O several-fold on
  sorted/clustered keys;
* **async partition prefetch** — ``PartitionPrefetcher`` double-buffers:
  a background thread loads partition N+1's streams while partition N is
  processed.  Prefetched bytes are pinned in the BufferManager *before* the
  load starts, and a prefetch is skipped entirely when pinning it would
  exceed the budget — so the tracked ``peak <= budget`` contract survives
  overlap;
* **recursive repartitioning** — a group-by partition still larger than the
  budget is re-partitioned with fresh composite-key splitters sampled from
  its own rows (streamed block-by-block, never fully resident), to a
  bounded depth; at the depth bound, or when every sampled key tuple is
  equal (one giant group — unsplittable by key), it falls back to
  whole-partition processing.

Result-identity contract (asserted in tests/test_outofcore.py): every
operator here returns *bit-identical* output to its in-memory twin in
executor.py:

* ``grace_hash_groupby`` range-partitions on the composite group key with
  sample-quantile splitters, so partitions are ordered and the concatenated
  per-partition dense gids reproduce the global lexicographic group order of
  ``_factorize``/``_dense_gid`` — recursion refines ranges *within* a
  parent partition, preserving that order;
* ``partitioned_hash_join`` hash-partitions both sides, joins partition
  pairs with the same ``_join_codes``/``_hash_join`` kernels, then stably
  re-sorts the output pairs by left row — recovering the probe-order output
  of the in-memory join.  VARCHAR keys stay partitionable even when the two
  sides' dictionary heaps differ (``plan_varchar_join``): content-equal
  heaps spill plain codes, small distinct heaps merge into one shared
  dictionary both sides recode to while spooling, and oversized heaps fall
  back to spilling decoded string bytes and hashing on those — in every
  case equal strings land in the same partition and NULL (code 0) rows are
  pre-filtered by the caller exactly as in memory;
* ``external_merge_sort`` sorts budget-sized runs with the same
  ``lexsort`` keys and merges with the original row index as tiebreaker,
  which is exactly stable-lexsort order.  Run files keep the row index as a
  native int64 stream (not float64), so indexes past 2^53 survive
  bit-exactly.

Every partition's processing happens under pinned accounting so the tracked
high-water mark stays under the budget; run files are deleted as soon as
their partition is consumed — and on *any* error, every still-registered
run file of the operator is released immediately (not parked until db
cleanup()).
"""

from __future__ import annotations

import heapq
import pickle
import queue
import threading
import zlib
from typing import Iterable, Iterator, Optional

import numpy as np

from .buffers import (BufferManager, CODEC_RAW, PartitionWriter,
                      SpillPartition, choose_morsel_rows, choose_partitions,
                      logical_nbytes, read_stream_block, write_stream_block)
from .expression import ExprResult
from .storage import morsel_ranges

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)

MAX_REPARTITION_DEPTH = 3    # recursion bound; then whole-partition fallback


def _key_row_bytes(results: list) -> int:
    return sum(np.asarray(r.values).dtype.itemsize for r in results)


def _slice_result(r: ExprResult, sl) -> ExprResult:
    """Morsel view of an ExprResult (values + null mask, metadata shared)."""
    return ExprResult(np.asarray(r.values)[sl], r.dbtype,
                      None if r.null is None else np.asarray(r.null)[sl],
                      r.heap, r.scale)


def _gather_result(r: ExprResult, arr: np.ndarray) -> ExprResult:
    """Rebuild an ExprResult around values read back from a spill file."""
    return ExprResult(arr, r.dbtype, None, r.heap, r.scale)


# ---------------------------------------------------------------------------
# double-buffered async partition prefetch
# ---------------------------------------------------------------------------


class PartitionPrefetcher:
    """Iterate partition groups with one-group-ahead background loading.

    ``groups`` is a list of tuples of SpillPartition (a group is everything
    one processing step needs at once: a single partition for group-by, a
    build/probe pair for join).  Iteration yields ``(group, arrs)`` where
    ``arrs`` is the tuple of decoded stream dicts, loaded either by the
    prefetch thread (counted in ``stats.prefetch_hits``) or synchronously.

    Budget contract: a group's decoded bytes are pinned *before* its load
    begins — by the main thread at queue time for prefetches — and a
    prefetch is skipped when pinning the next group alongside the current
    one would exceed the budget, so double-buffering never breaks
    ``peak <= budget``.

    File-lifecycle contract (spill-leak fix): the prefetcher owns release.
    Each group's run files are released once the consumer finishes with it,
    and if the consumer raises (or abandons the iterator), every remaining
    group's files are released on generator close instead of lingering
    until db cleanup().

    Groups larger than ``max_load_bytes`` are yielded with ``arrs=None``
    (not loaded, nothing pinned): the consumer streams or re-partitions
    them instead of materializing an over-budget load.
    """

    def __init__(self, bufman: BufferManager, groups: list[tuple],
                 max_load_bytes: Optional[int] = None):
        self.bufman = bufman
        self.groups = groups
        self.max_load_bytes = max_load_bytes
        self._consumed = 0
        self._jobs: Optional[queue.Queue] = None
        self._worker: Optional[threading.Thread] = None

    def _oversized(self, nbytes: int) -> bool:
        return self.max_load_bytes is not None and nbytes > self.max_load_bytes

    # one persistent daemon worker per prefetcher (started lazily, stopped
    # on generator close): at most one job is ever outstanding, and reusing
    # the thread keeps per-partition overhead to an event handoff
    def _submit(self, group: tuple) -> tuple[dict, threading.Event]:
        if self._worker is None:
            self._jobs = queue.Queue()
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()
        box: dict = {}
        done = threading.Event()
        self._jobs.put((group, box, done))
        return box, done

    def _run(self) -> None:
        while True:
            job = self._jobs.get()
            if job is None:
                return
            group, box, done = job
            # I/O only: raw stream bytes.  File reads release the GIL, so
            # this genuinely overlaps the consumer; decoding (GIL-bound
            # numpy) would contend, so it happens at consumption instead.
            try:
                box["raw"] = tuple(p.read_streams() for p in group)
            except BaseException as e:           # surfaced on the main thread
                box["err"] = e
            done.set()

    def __iter__(self):
        bm = self.bufman
        pend = None                  # (pinned_bytes, result box, done event)
        try:
            for i, group in enumerate(self.groups):
                self._consumed = i
                if pend is not None:
                    pnb, box, done = pend
                    pend = None
                    done.wait()
                    if "err" in box:
                        bm.unpin(pnb)
                        raise box["err"]
                    try:
                        arrs = tuple(p.decode_streams(rb) for p, rb
                                     in zip(group, box["raw"]))
                    except BaseException:
                        bm.unpin(pnb)
                        raise
                    bm.bump(prefetch_hits=1)
                else:
                    nb = sum(p.nbytes for p in group)
                    if self._oversized(nb):
                        pnb, arrs = 0, None
                    else:
                        pnb = bm.pin(nb)
                        try:
                            arrs = tuple(p.load() for p in group)
                        except BaseException:
                            bm.unpin(pnb)
                            raise
                # never queue ahead of an oversized group: its consumer
                # needs the remaining budget headroom to repartition
                if bm.prefetch and arrs is not None \
                        and i + 1 < len(self.groups):
                    nnb = sum(p.nbytes for p in self.groups[i + 1])
                    # try_pin is the atomic reserve-or-fail: the old
                    # would_exceed()+pin() pair was check-then-act — two
                    # concurrent queries could both pass the check and
                    # jointly blow the budget
                    if not self._oversized(nnb) and bm.try_pin(nnb):
                        try:
                            box, done = self._submit(self.groups[i + 1])
                        except BaseException:
                            # worker-thread start can fail: the reserve
                            # must not outlive the submission it was for
                            bm.unpin(nnb)
                            raise
                        pend = (nnb, box, done)
                try:
                    yield group, arrs
                finally:
                    bm.unpin(pnb)
                    for p in group:
                        p.release()
                self._consumed = i + 1
        finally:
            if pend is not None:
                pnb, box, done = pend
                done.wait()
                bm.unpin(pnb)
            if self._worker is not None:
                self._jobs.put(None)         # stop the worker thread
            for group in self.groups[self._consumed:]:
                for p in group:
                    p.release()              # release_file is idempotent


# ---------------------------------------------------------------------------
# grace-hash aggregation (range-partitioned, group-order preserving)
# ---------------------------------------------------------------------------


def _lex_float(arr: np.ndarray) -> np.ndarray:
    """Partitioning representation of raw key values: float64 with NaN
    normalized to +inf (np.unique sorts NaN after inf, and co-locating the
    two costs only balance, never correctness)."""
    f = np.asarray(arr, dtype=np.float64)
    return np.where(np.isnan(f), np.inf, f)


def _splitters_from_sample(cols: list[np.ndarray], n_parts: int) -> np.ndarray:
    """Sample-quantile splitter *tuples* over already-normalized key columns.

    Partitioning on the composite key (not just the first column) keeps
    partitions balanced when the leading key is low-cardinality — e.g.
    GROUP BY city, fare with three cities.  Quantiles (not min/max linspace)
    also stay balanced when the domain holds extreme values such as the
    in-domain NULL sentinel ``-2**63``.  Returns an (n_splitters, n_keys)
    matrix of lexicographically ascending, deduplicated boundary tuples."""
    if n_parts <= 1 or len(cols[0]) == 0:
        return np.empty((0, len(cols)), dtype=np.float64)
    order = np.lexsort(tuple(reversed(cols)))
    mat = np.stack([c[order] for c in cols], axis=1)
    n_samp = len(cols[0])
    picks = (np.arange(1, n_parts) * n_samp) // n_parts
    splitters = mat[np.clip(picks, 0, n_samp - 1)]
    return np.unique(splitters, axis=0)


def _composite_splitters(key_arrays: list, idx: np.ndarray,
                         n_parts: int) -> np.ndarray:
    """Splitters from a strided sample of the selected rows (spool pass)."""
    if n_parts <= 1:
        return np.empty((0, len(key_arrays)), dtype=np.float64)
    stride = max(1, len(idx) // 65536)
    samp = idx[::stride]
    return _splitters_from_sample([_lex_float(a[samp]) for a in key_arrays],
                                  n_parts)


def _composite_partition(key_cols: list, splitters: np.ndarray) -> np.ndarray:
    """Partition id per row: the count of splitter tuples lexicographically
    below the row's key tuple.  Monotone in group-sort order and constant on
    equal keys — the two properties order-preserving grace hashing needs."""
    n = len(key_cols[0])
    part = np.zeros(n, dtype=np.int64)
    for s in splitters:
        gt = np.zeros(n, dtype=bool)
        eq = np.ones(n, dtype=bool)
        for j, v in enumerate(key_cols):
            gt |= eq & (v > s[j])
            eq &= v == s[j]
        part += gt
    return part


def _groupby_arrays(keys: list, arrs: dict) -> tuple:
    """Factorize one loaded partition; returns (gid, n_groups, idx_rows)."""
    from .executor import _dense_gid, _factorize

    sub_results = [_gather_result(k, arrs[f"k{i}"])
                   for i, k in enumerate(keys)]
    codes, _ = _factorize(sub_results)
    gid, n_local, _ = _dense_gid(codes)
    return gid, n_local, arrs["idx"]


def _repartition_groupby(keys: list, partn: SpillPartition,
                         bufman: BufferManager, depth: int) -> tuple:
    """Recursively split one over-budget partition (skew-proofing).

    Fresh splitters come from a strided sample of the partition's *own*
    rows — far finer resolution than the global spool pass, so anything
    with more than one distinct key tuple splits.  The partition is read
    block-by-block (never fully resident); sub-partitions recurse through
    the same prefetching consumer.  Whole-partition fallback at the depth
    bound or when the sample is a single key tuple (one giant group)."""
    nk = len(keys)
    budget = bufman.budget
    if depth >= MAX_REPARTITION_DEPTH:        # before the sampling scan:
        with bufman.pinned(partn.nbytes):     # at the bound the sample
            return _groupby_arrays(keys, partn.load())   # would be unused
    n_parts = choose_partitions(partn.nbytes, budget)

    stride = max(1, partn.rows // 65536)
    samples: list[list[np.ndarray]] = [[] for _ in range(nk)]
    pos = 0
    for blk in partn.iter_blocks():
        bn = len(blk["idx"])
        take = np.arange((-pos) % stride, bn, stride)
        if len(take):
            for i in range(nk):
                samples[i].append(_lex_float(blk[f"k{i}"][take]))
        pos += bn
    cols = [np.concatenate(s) if s else np.empty(0) for s in samples]
    if len(cols[0]) == 0 \
            or len(np.unique(np.stack(cols, axis=1), axis=0)) <= 1:
        # one distinct key tuple = one giant group: unsplittable by key,
        # so re-scattering would be a no-op rewrite — process whole
        with bufman.pinned(partn.nbytes):
            return _groupby_arrays(keys, partn.load())
    splitters = _splitters_from_sample(cols, n_parts)

    bufman.bump(repartitions=1)
    writer = PartitionWriter(bufman, n_parts, dict(partn.streams),
                             hint=f"grp{depth}")
    # coalesce the parent's (possibly tiny) blocks up to one morsel before
    # scattering, so sub-partition files get real blocks, not confetti
    row_bytes = sum(dt.itemsize for dt in partn.streams.values())
    morsel = choose_morsel_rows(row_bytes, budget)

    def _scatter(buf: list) -> None:
        blk = {s: (buf[0][s] if len(buf) == 1 else
                   np.concatenate([b[s] for b in buf]))
               for s in partn.streams}
        part = _composite_partition(
            [_lex_float(blk[f"k{i}"]) for i in range(nk)], splitters)
        with bufman.pinned(sum(a.nbytes for a in blk.values())):
            writer.append(part, blk)

    try:
        buf, brows = [], 0
        for blk in partn.iter_blocks():
            buf.append(blk)
            brows += len(blk["idx"])
            if brows >= morsel:
                _scatter(buf)
                buf, brows = [], 0
        if buf:
            _scatter(buf)
    except BaseException:
        writer.abort()
        raise
    subs = writer.finalize()
    partn.release()                  # parent file no longer needed

    out_gid, out_idx = [], []
    offset = 0
    for (sp,), arrs in PartitionPrefetcher(bufman, [(p,) for p in subs],
                                           max_load_bytes=budget):
        if sp.rows == 0:
            continue
        if arrs is None:
            gid, n_local, pidx = _repartition_groupby(keys, sp, bufman,
                                                      depth + 1)
        else:
            gid, n_local, pidx = _groupby_arrays(keys, arrs[0])
        out_gid.append(gid + offset)
        out_idx.append(pidx)
        offset += n_local
    if not out_gid:
        return np.zeros(0, dtype=np.int64), 0, np.zeros(0, dtype=np.int64)
    return (np.concatenate(out_gid), int(offset), np.concatenate(out_idx))


def grace_hash_groupby(keys: list, idx: np.ndarray, bufman: BufferManager):
    """External GROUP BY: returns the same ``(gid, n_groups, idx)`` triple as
    the in-memory ``_op_group``, with identical group numbering.

    Rows are range-partitioned on the composite key tuple so partition p's
    groups all sort before partition p+1's; within a partition the normal
    factorize path runs, and per-partition gids are shifted by a running
    offset.  Equal key tuples always share a partition, and NaN keys land
    after finite values — matching ``np.unique``'s NaN-last order.
    """
    n = len(idx)
    row_bytes = _key_row_bytes(keys) + 8
    n_parts = choose_partitions(n * row_bytes, bufman.budget)
    morsel = choose_morsel_rows(row_bytes, bufman.budget)
    key_arrays = [np.asarray(k.values) for k in keys]
    splitters = _composite_splitters(key_arrays, idx, n_parts)

    streams = {"idx": np.dtype(np.int64)}
    for i, k in enumerate(keys):
        streams[f"k{i}"] = np.asarray(k.values).dtype
    writer = PartitionWriter(bufman, n_parts, streams, hint="grp")
    try:
        for s, e in morsel_ranges(n, morsel):
            sub = idx[s:e]
            part = _composite_partition([_lex_float(ka[sub])
                                         for ka in key_arrays], splitters)
            chunks = {"idx": sub}
            for i, ka in enumerate(key_arrays):
                chunks[f"k{i}"] = ka[sub]
            with bufman.pinned(sub.nbytes + sum(
                    ka[sub].nbytes for ka in key_arrays)):
                writer.append(part, chunks)
    except BaseException:
        writer.abort()
        raise

    out_gid, out_idx = [], []
    offset = 0
    groups = [(p,) for p in writer.finalize()]
    for (partn,), arrs in PartitionPrefetcher(bufman, groups,
                                              max_load_bytes=bufman.budget):
        if partn.rows == 0:
            continue
        if arrs is None:             # still over budget: recursive split
            gid, n_local, pidx = _repartition_groupby(keys, partn, bufman,
                                                      depth=1)
        else:
            gid, n_local, pidx = _groupby_arrays(keys, arrs[0])
        out_gid.append(gid + offset)
        out_idx.append(pidx)
        offset += n_local
    if not out_gid:
        return np.zeros(0, dtype=np.int64), 0, np.zeros(0, dtype=np.int64)
    return (np.concatenate(out_gid).astype(np.int64), int(offset),
            np.concatenate(out_idx).astype(np.int64))


# ---------------------------------------------------------------------------
# partitioned (grace) hash join
# ---------------------------------------------------------------------------


_RESALT = np.uint64(0x632BE59BD9B4E019)     # odd: per-depth hash reseeding


def _hash_partition(values: np.ndarray, n_parts: int, as_float: bool,
                    salt: int = 0) -> np.ndarray:
    """Deterministic bucket per raw key value, identical across both sides.

    Floats are normalized (-0.0 -> +0.0) then bit-hashed; integer families
    widen to int64 so INT32 and INT64 keys bucket together.  ``salt``
    decorrelates the recursive-repartition passes from the parent split
    (without it every parent-partition row would land in one sub-bucket)."""
    if as_float:
        bits = (np.asarray(values, dtype=np.float64) + 0.0).view(np.uint64)
    else:
        bits = np.asarray(values).astype(np.int64).view(np.uint64)
    h = (bits ^ (np.uint64(salt) * _RESALT)) * _GOLDEN
    h = h ^ (h >> np.uint64(29))
    return (h % np.uint64(n_parts)).astype(np.int64)


def _hash_partition_str(values: np.ndarray, n_parts: int,
                        salt: int = 0) -> np.ndarray:
    """Deterministic bucket per decoded string (``str`` or pre-encoded
    utf-8 ``bytes``).  Python's built-in ``str`` hash is salted per process,
    so both join sides (and a future resumed process) hash the utf-8 bytes
    with crc32 instead, mixed through the same golden-ratio finalizer as
    the numeric hash.  ``salt`` reseeds the crc for recursive-repartition
    passes."""
    from .buffers import _utf8
    start = int(salt) & 0xFFFFFFFF
    h = np.fromiter((zlib.crc32(_utf8(s), start) for s in values),
                    dtype=np.uint64, count=len(values))
    h = h * _GOLDEN
    h = h ^ (h >> np.uint64(29))
    return (h % np.uint64(n_parts)).astype(np.int64)


def plan_varchar_join(lres: list, rres: list,
                      bufman: BufferManager) -> Optional[list]:
    """Per-key spill strategy for (possibly VARCHAR) join keys.

    The paper's duplicate-eliminated string heaps mean VARCHAR columns
    execute as int32 codes — but codes from *different* heaps are not
    comparable.  Per key pair this returns:

    * ``None`` entry — numeric key, spill raw values (unchanged path);
    * ``("codes",)`` — heaps are content-equal (same object, or equal
      fingerprints — e.g. two separately-loaded copies of one table), so
      dictionary codes compare directly and spill as plain int32 streams;
    * ``("recode", merged, lmap, rmap)`` — distinct heaps whose union fits
      comfortably in the budget: one shared heap is built incrementally via
      ``StringHeap.merge`` (its recode map re-keys the left side, the
      returned new-value codes re-key the right), and both sides spool
      already-recoded codes of that single dictionary;
    * ``("decode",)`` — heaps too large to merge under the budget: rows
      spill their decoded string bytes (offsets+bytes block codec) and
      partitions hash on those bytes.

    Returns ``None`` (not a list) when the pairing cannot be partitioned at
    all: one side VARCHAR and the other numeric has no common key domain,
    and the in-memory path must resolve it."""
    from .column import heaps_equal
    from .types import DBType
    # pairability first: a VARCHAR-vs-numeric key pair has no common key
    # domain, and finding it late would waste the heap merges done for
    # earlier key pairs (merge is O(heap), this pass is O(keys))
    for lr, rr in zip(lres, rres):
        if (lr.dbtype == DBType.VARCHAR) != (rr.dbtype == DBType.VARCHAR):
            return None
    actions: list = []
    for lr, rr in zip(lres, rres):
        if lr.dbtype != DBType.VARCHAR:
            actions.append(None)
            continue
        if heaps_equal(lr.heap, rr.heap):
            actions.append(("codes",))
            continue
        heap_bytes = lr.heap.nbytes() + rr.heap.nbytes()
        if bufman.budget is None or heap_bytes <= bufman.budget // 4:
            # the merge's working set (both heaps + the union) is pinned so
            # peak accounting still reflects the dictionary build
            with bufman.pinned(heap_bytes):
                merged, lmap, rcodes = lr.heap.merge(
                    [str(v) for v in rr.heap.values[1:]])
            rmap = np.zeros(len(rr.heap.values), dtype=np.int32)
            rmap[1:] = rcodes
            actions.append(("recode", merged, lmap, rmap))
        else:
            actions.append(("decode",))
    return actions


def _plan_row_bytes(results: list, actions: Optional[list]) -> int:
    """Estimated spooled bytes per row under a varchar plan: decoded string
    keys count their average heap string width, everything else its dtype
    itemsize; +8 for the row-index stream."""
    rb = 8
    for i, r in enumerate(results):
        act = None if actions is None else actions[i]
        if act is not None and act[0] == "decode":
            h = r.heap
            rb += max(8, h.nbytes() // max(1, len(h)))
        else:
            rb += np.asarray(r.values).dtype.itemsize
    return rb


def _spool_side(results: list, sel: np.ndarray, bufman: BufferManager,
                n_parts: int, as_float: bool, hint: str,
                actions: Optional[list] = None, side: int = 0):
    """Hash-scatter one join side to partition files.  ``actions`` is the
    varchar plan (see ``plan_varchar_join``); ``side`` selects which recode
    map applies (0 = left/lmap, 1 = right/rmap).  Key conversion happens per
    morsel — recode maps index per chunk, decode materializes only one
    morsel of strings — so full-column converted copies never exist."""
    row_bytes = _plan_row_bytes(results, actions)
    morsel = choose_morsel_rows(row_bytes, bufman.budget)
    streams = {"idx": np.dtype(np.int64)}
    converts: list = []
    for i, r in enumerate(results):
        act = None if actions is None else actions[i]
        if act is not None and act[0] == "decode":
            streams[f"k{i}"] = np.dtype(object)
            # decode AND utf-8 encode once per value here: the partition
            # hash, the pin accounting, and the block writer all consume
            # the same bytes objects instead of re-encoding the str 3x
            def _decode_utf8(a, h=r.heap):
                from .buffers import _utf8
                out = h.decode(a)
                return np.fromiter((_utf8(s) for s in out), dtype=object,
                                   count=len(out))
            converts.append(_decode_utf8)
        elif act is not None and act[0] == "recode":
            streams[f"k{i}"] = np.dtype(np.int32)
            converts.append(lambda a, m=act[2 + side]: m[a])
        else:
            streams[f"k{i}"] = np.asarray(r.values).dtype
            converts.append(None)
    writer = PartitionWriter(bufman, n_parts, streams, hint=hint)
    arrays = [np.asarray(r.values) for r in results]
    str_first = streams["k0"] == np.dtype(object)
    try:
        for s, e in morsel_ranges(len(sel), morsel):
            sub = sel[s:e]
            chunks = {"idx": sub}
            for i, a in enumerate(arrays):
                c = a[sub]
                if converts[i] is not None:
                    c = converts[i](c)
                chunks[f"k{i}"] = c
            part = (_hash_partition_str(chunks["k0"], n_parts) if str_first
                    else _hash_partition(chunks["k0"], n_parts, as_float))
            with bufman.pinned(sum(logical_nbytes(c)
                                   for c in chunks.values())):
                writer.append(part, chunks)
    except BaseException:
        writer.abort()
        raise
    return writer.finalize()


def _gather_planned(r: ExprResult, arr: np.ndarray, act) -> ExprResult:
    """Per-partition ExprResult honoring the varchar plan: decoded-string
    streams carry no heap (``_join_codes`` then compares the strings
    themselves), recoded streams carry the *merged* heap on both sides (so
    codes compare directly), everything else keeps its original metadata."""
    if act is not None and act[0] == "decode":
        return ExprResult(arr, r.dbtype, None, None, r.scale)
    if act is not None and act[0] == "recode":
        return ExprResult(arr, r.dbtype, None, act[1], r.scale)
    return _gather_result(r, arr)


def _join_partition_pair(lres: list, rres: list, larr: dict, rarr: dict,
                         how: str, vplan: Optional[list]) -> tuple:
    """Join one loaded partition pair with the in-memory kernels; returns
    (left global rows, right global rows or None) for this pair."""
    from .executor import _hash_join, _join_codes

    nk = len(lres)
    lidx_g, ridx_g = larr["idx"], rarr["idx"]
    if len(ridx_g) == 0:
        # empty build side: no matches; left keeps every probe row (and the
        # general path would index the empty ridx_g eagerly via np.where)
        if how in ("anti", "left"):
            rpad = None if how == "anti" \
                else np.full(len(lidx_g), -1, dtype=np.int64)
            return lidx_g, rpad
        return (lidx_g[:0],
                None if how == "semi" else np.zeros(0, dtype=np.int64))
    lsub = [_gather_planned(r, larr[f"k{i}"],
                            None if vplan is None else vplan[i])
            for i, r in enumerate(lres)]
    rsub = [_gather_planned(r, rarr[f"k{i}"],
                            None if vplan is None else vplan[i])
            for i, r in enumerate(rres)]
    lc, rc, _, _ = _join_codes(lsub, rsub, nk)
    lidx, ridx = _hash_join(lc, rc, how)
    if how in ("semi", "anti"):
        return lidx_g[lidx], None
    return (lidx_g[lidx],
            np.where(ridx < 0, -1, ridx_g[np.maximum(ridx, 0)]))


def _scatter_partition(partn: SpillPartition, writer: PartitionWriter,
                       bufman: BufferManager, n_sub: int, as_float: bool,
                       salt: int, morsel: int) -> None:
    """Re-scatter one spilled join side block-by-block (never fully
    resident) into ``n_sub`` sub-partitions with a re-salted hash on k0."""
    str_first = partn.streams["k0"] == np.dtype(object)

    def _flush(buf: list) -> None:
        blk = {s: (buf[0][s] if len(buf) == 1 else
                   np.concatenate([b[s] for b in buf]))
               for s in partn.streams}
        part = (_hash_partition_str(blk["k0"], n_sub, salt) if str_first
                else _hash_partition(blk["k0"], n_sub, as_float, salt))
        with bufman.pinned(sum(logical_nbytes(a) for a in blk.values())):
            writer.append(part, blk)

    buf, brows = [], 0
    for blk in partn.iter_blocks():
        buf.append(blk)
        brows += len(blk["idx"])
        if brows >= morsel:
            _flush(buf)
            buf, brows = [], 0
    if buf:
        _flush(buf)


def _repartition_join(lp: SpillPartition, rp: SpillPartition, lres: list,
                      rres: list, how: str, bufman: BufferManager,
                      vplan: Optional[list], as_float: bool,
                      depth: int) -> tuple:
    """Recursively split an over-budget join partition pair (skew/cap
    proofing): both sides re-scatter with a re-salted hash — equal keys
    still meet in the same sub-pair — and sub-pairs stream through the same
    prefetching consumer.  Probe order needs no care here: the caller's
    final stable sort by global left row restores it whatever the partition
    structure.  At the depth bound (a single hot key cannot be split by
    hashing) the pair is processed whole."""
    if depth >= MAX_REPARTITION_DEPTH:
        with bufman.pinned(lp.nbytes + rp.nbytes):
            return _join_partition_pair(lres, rres, lp.load(), rp.load(),
                                        how, vplan)
    nbytes = lp.nbytes + rp.nbytes
    n_sub = choose_partitions(nbytes, bufman.budget)
    rows = lp.rows + rp.rows
    row_bytes = max(1, nbytes // max(1, rows))
    morsel = choose_morsel_rows(row_bytes, bufman.budget)
    bufman.bump(repartitions=1)

    lw = PartitionWriter(bufman, n_sub, dict(lp.streams),
                         hint=f"jl{depth}")
    try:
        _scatter_partition(lp, lw, bufman, n_sub, as_float, depth, morsel)
        rw = PartitionWriter(bufman, n_sub, dict(rp.streams),
                             hint=f"jr{depth}")
        try:
            _scatter_partition(rp, rw, bufman, n_sub, as_float, depth,
                               morsel)
        except BaseException:
            rw.abort()
            raise
    except BaseException:
        lw.abort()
        raise
    lp.release()
    rp.release()

    out_l, out_r = [], []
    groups = list(zip(lw.finalize(), rw.finalize()))
    for (slp, srp), arrs in PartitionPrefetcher(
            bufman, groups, max_load_bytes=bufman.budget):
        if slp.rows == 0:
            continue
        if arrs is None:
            pl, pr = _repartition_join(slp, srp, lres, rres, how, bufman,
                                       vplan, as_float, depth + 1)
        else:
            pl, pr = _join_partition_pair(lres, rres, arrs[0], arrs[1],
                                          how, vplan)
        out_l.append(pl)
        if pr is not None:
            out_r.append(pr)
    empty = np.zeros(0, dtype=np.int64)
    return (np.concatenate(out_l) if out_l else empty,
            None if how in ("semi", "anti")
            else (np.concatenate(out_r) if out_r else empty))


def partitioned_hash_join(lres: list, rres: list, lsel: np.ndarray,
                          rsel: np.ndarray, how: str,
                          bufman: BufferManager,
                          vplan: Optional[list] = None):
    """External equi-join.  Inputs are the *pre-null-filtered* selected row
    positions of each side; output is the same global (lidx, ridx) pairs —
    in the same order — as the in-memory ``_op_join``.  ``vplan`` (from
    ``plan_varchar_join``) makes VARCHAR keys with distinct heaps
    partitionable: both sides either recode to one merged dictionary or
    spill decoded string bytes, so equal strings always meet in the same
    partition regardless of which heap coded them.  Pairs that still exceed
    the budget after the spool's maximum fan-out re-split recursively
    (``_repartition_join``), so ``peak <= budget`` holds for joins too."""
    from .types import is_float

    as_float = any(is_float(r.dbtype) for r in (lres + rres))
    # size each side with its own heap widths: under the decode strategy
    # the two sides' average string lengths can differ arbitrarily
    est = (len(lsel) * _plan_row_bytes(lres, vplan)
           + len(rsel) * _plan_row_bytes(rres, vplan))
    n_parts = choose_partitions(est, bufman.budget)

    lparts = _spool_side(lres, lsel, bufman, n_parts, as_float, "jl",
                         vplan, 0)
    try:
        rparts = _spool_side(rres, rsel, bufman, n_parts, as_float, "jr",
                             vplan, 1)
    except BaseException:
        for lp in lparts:
            lp.release()
        raise

    # an empty probe side yields nothing for any join flavor: drop those
    # pairs up front so the prefetcher never loads (or pins) their build side
    groups = []
    for lp, rp in zip(lparts, rparts):
        if lp.rows == 0:
            lp.release()
            rp.release()
        else:
            groups.append((lp, rp))

    out_l, out_r = [], []
    for (lp, rp), arrs in PartitionPrefetcher(
            bufman, groups, max_load_bytes=bufman.budget):
        if arrs is None:             # pair over budget: recursive re-split
            pl, pr = _repartition_join(lp, rp, lres, rres, how, bufman,
                                       vplan, as_float, depth=1)
            out_l.append(pl)
            if pr is not None:
                out_r.append(pr)
        elif rp.rows == 0:
            lidx_g = arrs[0]["idx"]
            if how == "anti":
                out_l.append(lidx_g)
            elif how == "left":
                out_l.append(lidx_g)
                out_r.append(np.full(len(lidx_g), -1, dtype=np.int64))
            # inner / semi: no matches in this partition
        else:
            pl, pr = _join_partition_pair(lres, rres, arrs[0], arrs[1],
                                          how, vplan)
            out_l.append(pl)
            if pr is not None:
                out_r.append(pr)

    gl = np.concatenate(out_l).astype(np.int64) if out_l \
        else np.zeros(0, dtype=np.int64)
    # Recover probe order: in-memory output is sorted by global left row
    # (ties = one left row's matches, already in right-row order within the
    # single partition that owns the key) -> a stable sort by gl suffices.
    order = np.argsort(gl, kind="stable")
    if how in ("semi", "anti"):
        return (gl[order],)
    gr = np.concatenate(out_r).astype(np.int64) if out_r \
        else np.zeros(0, dtype=np.int64)
    return gl[order], gr[order]


# ---------------------------------------------------------------------------
# external merge sort
# ---------------------------------------------------------------------------


SORT_MERGE_FAN_IN = 64      # max run files open per merge pass (fd bound)
SORT_BLOCK_ROWS = 1024      # rows per codec block inside a run file


def _append_sort_blocks(f, bufman: BufferManager, key_cols: list,
                        idx: np.ndarray) -> None:
    """Write sorted rows as row-aligned codec blocks: each key column spills
    raw float64, the row-index column spills as FOR-shuffled *int64* —
    end-to-end integer, so indexes past 2^53 round-trip bit-exactly (the
    old float64 row matrix silently lost precision there)."""
    for s, e in morsel_ranges(len(idx), SORT_BLOCK_ROWS):
        for a in key_cols:
            write_stream_block(f, a[s:e], CODEC_RAW, bufman)
        write_stream_block(f, idx[s:e], bufman.codec, bufman)


# transfers-ownership: the returned run path is released by the merge
# (external_merge_sort) once the run is consumed
def _write_sort_run(bufman: BufferManager, key_cols: list,
                    idx: np.ndarray) -> str:
    path = bufman.new_spill_file("sortrun")
    with open(path, "wb") as f:
        _append_sort_blocks(f, bufman, key_cols, idx)
    return path


def _iter_sort_run(path: str, n_keys: int) -> Iterator[tuple]:
    """Stream one run as (key..., idx) tuples, decoding one bounded block at
    a time (the merge keeps FAN_IN blocks resident, not FAN_IN runs)."""
    with open(path, "rb") as f:
        while True:
            cols = []
            for _ in range(n_keys):
                a = read_stream_block(f, np.float64)
                if a is None:
                    return
                cols.append(a)
            idx = read_stream_block(f, np.int64)
            for i in range(len(idx)):
                yield tuple(float(c[i]) for c in cols) + (int(idx[i]),)


def _run_index_column(path: str, n_keys: int) -> np.ndarray:
    """Read only the int64 index stream of a run (single-run fast path)."""
    out = []
    with open(path, "rb") as f:
        while True:
            for _ in range(n_keys):
                if read_stream_block(f, np.float64) is None:
                    if not out:
                        return np.zeros(0, dtype=np.int64)
                    return out[0] if len(out) == 1 else np.concatenate(out)
            out.append(read_stream_block(f, np.int64))


def _flush_merge_rows(f, bufman: BufferManager, buf: list,
                      n_keys: int) -> None:
    mat = np.asarray([t[:-1] for t in buf],
                     dtype=np.float64).reshape(len(buf), n_keys)
    idx = np.asarray([t[-1] for t in buf], dtype=np.int64)
    _append_sort_blocks(
        f, bufman, [np.ascontiguousarray(mat[:, j]) for j in range(n_keys)],
        idx)


def external_merge_sort(keys: list, descs, limit: Optional[int],
                        bufman: BufferManager) -> np.ndarray:
    """External ORDER BY: returns the identical index vector np.lexsort
    would.  Budget-sized runs are lexsorted with the same float sort keys,
    spilled as block-encoded column streams (keys + int64 row index), then
    merged with the row index as tiebreaker — which reproduces
    stable-lexsort order exactly.  When the run count exceeds
    ``SORT_MERGE_FAN_IN``, cascade passes merge groups of runs into longer
    runs first, bounding open file descriptors.  Every file created here —
    including half-written cascade outputs — is released on any exit."""
    from .executor import _sort_key_float

    n = len(np.asarray(keys[0].values))
    n_keys = len(keys)
    row_bytes = 8 * (n_keys + 1)
    if bufman.budget is not None:
        run_rows = max(64, (bufman.budget // 2) // row_bytes)
    else:
        run_rows = max(n, 1)
    live: list[str] = []
    try:
        paths = []
        for s, e in morsel_ranges(n, run_rows):
            arrs = [_sort_key_float(_slice_result(r, slice(s, e)), d)
                    for r, d in zip(keys, descs)]
            with bufman.pinned((e - s) * row_bytes):
                local = np.lexsort(tuple(reversed(arrs)))
                key_cols = [a[local] for a in arrs]
                idx = (s + local).astype(np.int64)
                path = _write_sort_run(bufman, key_cols, idx)
                live.append(path)
                paths.append(path)

        # cascade: collapse groups of runs until one merge pass suffices
        while len(paths) > SORT_MERGE_FAN_IN:
            next_paths = []
            for i in range(0, len(paths), SORT_MERGE_FAN_IN):
                group = paths[i:i + SORT_MERGE_FAN_IN]
                if len(group) == 1:
                    next_paths.append(group[0])
                    continue
                out_path = bufman.new_spill_file("sortmerge")
                live.append(out_path)
                with open(out_path, "wb") as f:
                    buf = []
                    for item in heapq.merge(
                            *(_iter_sort_run(p, n_keys) for p in group)):
                        buf.append(item)
                        if len(buf) >= 4096:
                            _flush_merge_rows(f, bufman, buf, n_keys)
                            buf = []
                    if buf:
                        _flush_merge_rows(f, bufman, buf, n_keys)
                for p in group:
                    bufman.release_file(p)
                next_paths.append(out_path)
            paths = next_paths

        if len(paths) == 1:
            idx = _run_index_column(paths[0], n_keys)
            return idx[:limit] if limit is not None else idx

        out = []
        want = n if limit is None else min(limit, n)
        for item in heapq.merge(*(_iter_sort_run(p, n_keys)
                                  for p in paths)):
            out.append(item[-1])
            if len(out) >= want:
                break
        return np.asarray(out, dtype=np.int64)
    finally:
        for p in live:
            bufman.release_file(p)


# ---------------------------------------------------------------------------
# row spooler (volcano tier)
# ---------------------------------------------------------------------------


def spooled_row_groups(rows: Iterable[dict], key_fn, bufman: BufferManager,
                       n_parts: Optional[int] = None,
                       est_bytes: int = 0) -> Iterator[tuple]:
    """Out-of-core grouping for the row-at-a-time volcano engine: spool rows
    to hash partitions (pickled batches), then yield ``(key, rows)`` one
    partition at a time.  A group lives entirely in one partition, so the
    caller can aggregate and discard each group's rows immediately.

    The partition count derives from the caller's input estimate and the
    budget (``choose_partitions``) unless given explicitly; every partition
    file is released even when the input iterator or the consumer raises."""
    if n_parts is None:
        n_parts = choose_partitions(int(est_bytes), bufman.budget)
    paths = [bufman.new_spill_file(f"volrows{p}") for p in range(n_parts)]
    try:
        handles = [open(p, "wb") for p in paths]
        try:
            batches: list[list] = [[] for _ in range(n_parts)]
            # sniff the key type for the varchar_spills stat only until a
            # verdict is possible: a str anywhere counts, and a fully
            # non-None key settles a numeric shape — so the scan is O(1)
            # rows for dense keys instead of running over the whole input
            sniffing = True
            for row in rows:
                key = key_fn(row)
                if sniffing:
                    ks = key if isinstance(key, tuple) else (key,)
                    if any(isinstance(v, str) for v in ks):
                        bufman.bump(varchar_spills=1)
                        sniffing = False
                    elif all(v is not None for v in ks):
                        sniffing = False
                p = hash(key) % n_parts
                batches[p].append(row)
                if len(batches[p]) >= 1024:
                    pickle.dump(batches[p], handles[p])
                    batches[p] = []
            for p in range(n_parts):
                if batches[p]:
                    pickle.dump(batches[p], handles[p])
        finally:
            for h in handles:
                bufman.note_spilled(h.tell())
                h.close()
        for p in range(n_parts):
            groups: dict = {}
            with open(paths[p], "rb") as f:
                while True:
                    try:
                        batch = pickle.load(f)
                    except EOFError:
                        break
                    for row in batch:
                        groups.setdefault(key_fn(row), []).append(row)
            bufman.release_file(paths[p])
            yield from groups.items()
    finally:
        # mid-spool error, consumer error, or abandoned generator: reclaim
        # every remaining partition file now, not at db cleanup()
        for p in paths:
            bufman.release_file(p)
