"""Logical relational algebra + fluent query builder.

SQL (sqlparser.py) and the builder API below both produce this tree; the
optimizer (optimizer.py) rewrites it; the executor (executor.py) compiles it
into a MAL-style column-at-a-time program (mal.py).  Matches the paper's
§3.1 "Query Plan Execution" pipeline: SQL -> relational tree -> MAL.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

from .expression import Col, Expr, Lit

# ---------------------------------------------------------------------------
# aggregate spec
# ---------------------------------------------------------------------------

AGG_FNS = ("sum", "count", "avg", "min", "max", "median",
           "count_distinct", "first", "var", "std")


@dataclass(frozen=True)
class AggSpec:
    fn: str                      # one of AGG_FNS; count with expr=None = COUNT(*)
    expr: Optional[Expr]
    name: str

    def __post_init__(self):
        if self.fn not in AGG_FNS:
            raise ValueError(f"unknown aggregate {self.fn}")


# ---------------------------------------------------------------------------
# plan nodes
# ---------------------------------------------------------------------------


class PlanNode:
    children: tuple

    def output_columns(self, catalog) -> list[str]:  # pragma: no cover
        raise NotImplementedError

    def with_children(self, children) -> "PlanNode":
        raise NotImplementedError


@dataclass
class ScanNode(PlanNode):
    table: str
    columns: Optional[tuple[str, ...]] = None   # None = all (pruned later)
    children: tuple = ()

    def output_columns(self, catalog):
        if self.columns is not None:
            return list(self.columns)
        return list(catalog.table(self.table).schema.names)

    def with_children(self, children):
        return self


@dataclass
class FilterNode(PlanNode):
    child: PlanNode
    predicate: Expr

    @property
    def children(self):
        return (self.child,)

    def output_columns(self, catalog):
        return self.child.output_columns(catalog)

    def with_children(self, children):
        return FilterNode(children[0], self.predicate)


@dataclass
class ProjectNode(PlanNode):
    child: PlanNode
    exprs: tuple[tuple[Expr, str], ...]      # (expression, output name)

    @property
    def children(self):
        return (self.child,)

    def output_columns(self, catalog):
        return [n for _, n in self.exprs]

    def with_children(self, children):
        return ProjectNode(children[0], self.exprs)


@dataclass
class AggregateNode(PlanNode):
    child: PlanNode
    group_by: tuple[str, ...]                # grouping key column names
    aggs: tuple[AggSpec, ...]

    @property
    def children(self):
        return (self.child,)

    def output_columns(self, catalog):
        return list(self.group_by) + [a.name for a in self.aggs]

    def with_children(self, children):
        return AggregateNode(children[0], self.group_by, self.aggs)


@dataclass
class JoinNode(PlanNode):
    left: PlanNode
    right: PlanNode
    left_keys: tuple[str, ...]
    right_keys: tuple[str, ...]
    how: str = "inner"                       # inner | left | semi | anti

    @property
    def children(self):
        return (self.left, self.right)

    def output_columns(self, catalog):
        lcols = self.left.output_columns(catalog)
        if self.how in ("semi", "anti"):
            return lcols
        rcols = self.right.output_columns(catalog)
        return lcols + [c for c in rcols if c not in lcols]

    def with_children(self, children):
        return JoinNode(children[0], children[1], self.left_keys,
                        self.right_keys, self.how)


@dataclass
class OrderByNode(PlanNode):
    child: PlanNode
    keys: tuple[tuple[str, bool], ...]       # (column, descending)
    limit: Optional[int] = None              # fused top-N

    @property
    def children(self):
        return (self.child,)

    def output_columns(self, catalog):
        return self.child.output_columns(catalog)

    def with_children(self, children):
        return OrderByNode(children[0], self.keys, self.limit)


@dataclass
class LimitNode(PlanNode):
    child: PlanNode
    n: int

    @property
    def children(self):
        return (self.child,)

    def output_columns(self, catalog):
        return self.child.output_columns(catalog)

    def with_children(self, children):
        return LimitNode(children[0], self.n)


def walk(node: PlanNode):
    yield node
    for c in node.children:
        yield from walk(c)


def node_line(node: PlanNode) -> str:
    """One operator's display line (shared by the logical ``plan_repr`` and
    the physical planner's EXPLAIN output)."""
    if isinstance(node, ScanNode):
        return f"Scan({node.table}, cols={list(node.columns) if node.columns else '*'})"
    if isinstance(node, FilterNode):
        return f"Filter({node.predicate!r})"
    if isinstance(node, ProjectNode):
        return f"Project({[n for _, n in node.exprs]})"
    if isinstance(node, AggregateNode):
        return f"Aggregate(by={list(node.group_by)}, aggs={[a.fn + ':' + a.name for a in node.aggs]})"
    if isinstance(node, JoinNode):
        return f"Join({node.how}, {list(node.left_keys)}={list(node.right_keys)})"
    if isinstance(node, OrderByNode):
        return f"OrderBy({list(node.keys)}, limit={node.limit})"
    if isinstance(node, LimitNode):
        return f"Limit({node.n})"
    return repr(node)


def plan_repr(node: PlanNode, indent: int = 0) -> str:
    line = "  " * indent + node_line(node)
    return "\n".join([line] + [plan_repr(c, indent + 1) for c in node.children])


# ---------------------------------------------------------------------------
# fluent builder
# ---------------------------------------------------------------------------


class Query:
    """DataFrame-style builder over the relational algebra.

    ``db.scan("lineitem").filter(...).group_by(...).agg(...)`` etc.  Executed
    via ``.execute()`` (returns a result Table) through the session's
    executor with optimization enabled.
    """

    def __init__(self, plan: PlanNode, database):
        self.plan = plan
        self.database = database

    def _wrap(self, plan) -> "Query":
        return Query(plan, self.database)

    def filter(self, predicate: Expr) -> "Query":
        return self._wrap(FilterNode(self.plan, predicate))

    def project(self, **exprs) -> "Query":
        items = tuple((e if isinstance(e, Expr) else Lit(e), n)
                      for n, e in exprs.items())
        return self._wrap(ProjectNode(self.plan, items))

    def select(self, *names: str) -> "Query":
        items = tuple((Col(n), n) for n in names)
        return self._wrap(ProjectNode(self.plan, items))

    def group_by(self, *keys: str) -> "GroupedQuery":
        return GroupedQuery(self, keys)

    def agg(self, **aggs) -> "Query":
        return GroupedQuery(self, ()).agg(**aggs)

    def join(self, other: "Query", on=None, left_on=None, right_on=None,
             how: str = "inner") -> "Query":
        if on is not None:
            lk = rk = tuple([on] if isinstance(on, str) else on)
        else:
            lk = tuple([left_on] if isinstance(left_on, str) else left_on)
            rk = tuple([right_on] if isinstance(right_on, str) else right_on)
        return self._wrap(JoinNode(self.plan, other.plan, lk, rk, how))

    def order_by(self, *keys, limit: Optional[int] = None) -> "Query":
        norm = tuple((k, False) if isinstance(k, str) else (k[0], bool(k[1]))
                     for k in keys)
        return self._wrap(OrderByNode(self.plan, norm, limit))

    def limit(self, n: int) -> "Query":
        return self._wrap(LimitNode(self.plan, n))

    def having(self, predicate: Expr) -> "Query":
        return self._wrap(FilterNode(self.plan, predicate))

    def explain(self, optimized: bool = True, physical: bool = False,
                distributed: bool = False, mesh=None) -> str:
        """Logical plan text, or — with ``physical=True`` — the unified
        physical planner's lowering: the normalized plan with per-operator
        tier decisions (device-resident / device-streamed / parallel-host /
        spill / in-memory) and budget reservations.  ``distributed=True``
        mirrors ``execute(distributed=True)`` and enables the device-tier
        annotations (deriving the default mesh from the local devices)."""
        if physical:
            from .physplan import plan_physical
            phys = plan_physical(self.plan, self.database,
                                 do_optimize=optimized,
                                 distributed=distributed, mesh=mesh)
            return phys.render()
        plan = self.plan
        if optimized:
            from .optimizer import optimize
            plan = optimize(plan, self.database.catalog)
        return plan_repr(plan)

    def execute(self, **kw):
        return self.database.execute_plan(self.plan, **kw)

    def to_dict(self, **kw):
        return self.execute(**kw).to_pydict()


class GroupedQuery:
    def __init__(self, query: Query, keys: Sequence[str]):
        self.query = query
        self.keys = tuple(keys)

    def agg(self, **aggs) -> Query:
        """agg(total=("sum", expr), n=("count", None), ...)"""
        specs = []
        for name, spec in aggs.items():
            fn, expr = spec if isinstance(spec, tuple) else (spec, None)
            if isinstance(expr, str):
                expr = Col(expr)
            specs.append(AggSpec(fn, expr, name))
        return self.query._wrap(
            AggregateNode(self.query.plan, self.keys, tuple(specs)))
