"""Columns: tightly packed arrays + duplicate-eliminated string heaps.

Storage tiers (paper §3.1 "Memory Management", adapted for TPU — DESIGN.md §3):

* **host tier**: a numpy array (possibly an ``np.memmap`` view onto the
  persistent column file).  This plays the role of MonetDB's memory-mapped
  column: the OS keeps it paged in/out on the host.
* **device tier**: a ``jax.Array`` produced on first touch by a query
  (`.device()`), the explicit analogue of a page fault pulling a column into
  HBM.  Hot columns stay pinned; `evict()` drops the device copy.

Columns are **immutable versions**: appends/updates produce a new ``Column``
(functional copy-on-write — the strong form of the paper's mprotect-CoW).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .types import (DBType, NULL_SENTINEL, STORAGE_DTYPE, is_float,
                    null_mask)


class StringHeap:
    """Order-preserving dictionary heap for VARCHAR columns.

    The paper's variable-sized heap performs duplicate elimination; we make
    that total: every distinct value appears exactly once and codes are
    assigned in *sorted order* (code 1 = smallest string), so range
    predicates and sorts operate directly on int32 codes.  Code 0 is NULL.
    """

    __slots__ = ("values", "_fp")

    def __init__(self, values: Optional[np.ndarray] = None):
        # values[0] is the NULL placeholder; values[1:] sorted ascending.
        if values is None:
            values = np.array([""], dtype=object)
        self.values = values
        self._fp: Optional[bytes] = None

    def fingerprint(self) -> bytes:
        """Content hash of the heap (cached; heaps are immutable once built).

        Two heaps with equal fingerprints assign equal codes to equal
        strings, so their columns' int32 codes are directly comparable —
        the cheap content-equality that lets operators treat separately
        loaded copies of the same dictionary as one."""
        if self._fp is None:
            import hashlib
            h = hashlib.blake2b(digest_size=16)
            h.update(len(self.values).to_bytes(8, "little"))
            for v in self.values:
                b = str(v).encode("utf-8")
                h.update(len(b).to_bytes(4, "little"))
                h.update(b)
            self._fp = h.digest()
        return self._fp

    def content_equal(self, other: Optional["StringHeap"]) -> bool:
        """True iff both heaps hold the same values in the same order."""
        if self is other:
            return True
        if other is None:
            return False
        return (len(self.values) == len(other.values)
                and self.fingerprint() == other.fingerprint())

    @classmethod
    def encode(cls, strings) -> tuple["StringHeap", np.ndarray]:
        """Encode an iterable of (str | None) into (heap, codes)."""
        arr = np.asarray(
            [("\0NULL" if s is None else s) for s in strings], dtype=object)
        isnull = np.array([s is None for s in strings], dtype=bool)
        present = arr[~isnull]
        uniq = np.unique(present.astype(str)) if present.size else np.array([], dtype=str)
        heap_vals = np.empty(len(uniq) + 1, dtype=object)
        heap_vals[0] = ""
        heap_vals[1:] = uniq
        codes = np.zeros(len(arr), dtype=np.int32)
        if present.size:
            codes[~isnull] = (
                np.searchsorted(uniq, present.astype(str)).astype(np.int32) + 1)
        return cls(heap_vals), codes

    def decode(self, codes: np.ndarray) -> np.ndarray:
        out = self.values[np.asarray(codes, dtype=np.int64)]
        return out

    def code_of(self, s: Optional[str]) -> int:
        """Exact-match code; -1 if the value is absent from the heap."""
        if s is None:
            return 0
        i = np.searchsorted(self.values[1:].astype(str), s) + 1
        if i < len(self.values) and self.values[i] == s:
            return int(i)
        return -1

    def lower_bound(self, s: str) -> int:
        """Smallest code whose value >= s (for range predicates on codes)."""
        return int(np.searchsorted(self.values[1:].astype(str), s, "left")) + 1

    def upper_bound(self, s: str) -> int:
        return int(np.searchsorted(self.values[1:].astype(str), s, "right")) + 1

    def merge(self, strings) -> tuple["StringHeap", np.ndarray, np.ndarray]:
        """Merge new values in; returns (new_heap, recode_map, new_codes).

        ``recode_map`` maps old codes -> new codes so existing columns can be
        re-encoded (order preservation requires global re-sort on novel
        values; appends of already-present values are O(1) in heap size:
        the heap object is returned unchanged with an identity recode map,
        never re-sorted — ``new is self`` on that path).
        """
        new_heap, new_codes = StringHeap.encode(strings)
        old_strs = self.values[1:].astype(str)
        if len(old_strs) == 0:
            recode = np.zeros(1, dtype=np.int32)
            return new_heap, recode, new_codes
        nvals = new_heap.values[1:].astype(str)
        if len(nvals) == 0:
            # all-NULL input: nothing to add, heap identity preserved
            recode = np.arange(len(self.values), dtype=np.int32)
            return self, recode, new_codes
        pos = np.searchsorted(old_strs, nvals)
        safe = np.minimum(pos, len(old_strs) - 1)
        if bool((old_strs[safe] == nvals).all()):
            # every incoming value is already present: O(1) in heap size —
            # no global re-sort, identity recode, same heap object
            recode = np.arange(len(self.values), dtype=np.int32)
            nc = np.zeros_like(new_codes)
            mask = new_codes > 0
            nc[mask] = (pos[new_codes[mask] - 1] + 1).astype(np.int32)
            return self, recode, nc
        merged = np.unique(np.concatenate(
            [old_strs, new_heap.values[1:].astype(str)]))
        heap_vals = np.empty(len(merged) + 1, dtype=object)
        heap_vals[0] = ""
        heap_vals[1:] = merged
        out = StringHeap(heap_vals)
        recode = np.zeros(len(self.values), dtype=np.int32)
        recode[1:] = np.searchsorted(merged, old_strs).astype(np.int32) + 1
        nc = np.zeros_like(new_codes)
        mask = new_codes > 0
        nc[mask] = (np.searchsorted(
            merged, new_heap.values[new_codes[mask]].astype(str)
        ).astype(np.int32) + 1)
        return out, recode, nc

    def __len__(self) -> int:
        return len(self.values)

    def nbytes(self) -> int:
        return int(sum(len(str(v)) for v in self.values)) + 8 * len(self.values)


def heaps_equal(a: Optional[StringHeap], b: Optional[StringHeap]) -> bool:
    """Content equality for possibly-absent heaps: identical objects (or
    both absent) short-circuit; otherwise compare cached fingerprints."""
    if a is b:
        return True
    if a is None or b is None:
        return False
    return a.content_equal(b)


@dataclass
class Column:
    """One column version: packed data + optional heap + cached device copy."""

    dbtype: DBType
    data: np.ndarray                       # host tier (may be np.memmap)
    heap: Optional[StringHeap] = None      # VARCHAR only
    scale: int = 0                         # DECIMAL only
    _device: object = field(default=None, repr=False, compare=False)
    _has_nulls: object = field(default=None, repr=False, compare=False)

    def __post_init__(self):
        want = STORAGE_DTYPE[self.dbtype]
        if self.data.dtype != want:
            self.data = self.data.astype(want)   # dtype mismatch: convert
        if self.dbtype == DBType.VARCHAR and self.heap is None:
            raise ValueError("VARCHAR column requires a heap")

    # ---- constructors ----------------------------------------------------
    @classmethod
    def from_values(cls, values, dbtype: DBType, scale: int = 0) -> "Column":
        from .types import date_from_string, decimal_encode
        if dbtype == DBType.VARCHAR:
            heap, codes = StringHeap.encode(values)
            return cls(dbtype, codes, heap=heap)
        vals = list(values) if not isinstance(values, np.ndarray) else values
        if isinstance(vals, list):
            isnull = np.array([v is None for v in vals], dtype=bool)
            filled = [0 if v is None else v for v in vals]
            if dbtype == DBType.DATE and filled and isinstance(
                    next((v for v in vals if v is not None), 0), str):
                arr = np.zeros(len(vals), dtype=np.int32)
                nn = [v for v in vals if v is not None]
                if nn:
                    arr[~isnull] = date_from_string(nn)
            elif dbtype == DBType.DECIMAL:
                arr = decimal_encode(np.asarray(filled), scale)
            elif dbtype == DBType.BOOL:
                arr = np.asarray(filled).astype(np.int8)
            else:
                arr = np.asarray(filled).astype(STORAGE_DTYPE[dbtype])
            if isnull.any():
                arr = arr.copy()
                arr[isnull] = NULL_SENTINEL[dbtype]
        else:
            if dbtype == DBType.DECIMAL and np.issubdtype(vals.dtype, np.floating):
                arr = decimal_encode(vals, scale)
            else:
                # zero-copy adoption when the dtype already matches
                arr = vals.astype(STORAGE_DTYPE[dbtype], copy=False)
        return cls(dbtype, arr, scale=scale)

    # ---- tiers -----------------------------------------------------------
    def device(self):
        """HBM-resident view (explicit 'page-in'; cached)."""
        if self._device is None:
            import jax
            object.__setattr__(self, "_device", jax.device_put(
                np.ascontiguousarray(self.data)))
        return self._device

    def evict(self) -> None:
        object.__setattr__(self, "_device", None)

    # ---- basic accessors ---------------------------------------------------
    def __len__(self) -> int:
        return int(self.data.shape[0])

    @property
    def nbytes(self) -> int:
        n = int(self.data.nbytes)
        if self.heap is not None:
            n += self.heap.nbytes()
        return n

    def nulls(self) -> np.ndarray:
        return null_mask(self.data, self.dbtype)

    def has_nulls(self) -> bool:
        """Cached null presence (columns are immutable versions, so the
        answer never changes) — keeps zero-copy eligibility O(1)."""
        if self._has_nulls is None:
            object.__setattr__(self, "_has_nulls", bool(self.nulls().any()))
        return self._has_nulls

    def to_numpy(self, decode: bool = True) -> np.ndarray:
        """Decode to a user-facing numpy array (NULLs -> None/NaN)."""
        from .types import decimal_decode
        if not decode:
            return self.data
        if self.dbtype == DBType.VARCHAR:
            out = self.heap.decode(self.data)
            out = out.copy()
            out[self.data == 0] = None
            return out
        if self.dbtype == DBType.DECIMAL:
            out = decimal_decode(self.data, self.scale)
            out[self.nulls()] = np.nan
            return out
        if self.dbtype == DBType.BOOL:
            out = self.data.astype(object)
            m = self.nulls()
            out = (self.data != 0).astype(object)
            out[m] = None
            return out
        if is_float(self.dbtype):
            return self.data
        out = self.data.astype(object)
        out[self.nulls()] = None
        return out

    def take(self, idx: np.ndarray) -> "Column":
        return Column(self.dbtype, np.asarray(self.data)[idx],
                      heap=self.heap, scale=self.scale)

    def append(self, other: "Column") -> "Column":
        """Functional append -> new column version (bulk append path)."""
        if other.dbtype != self.dbtype:
            raise TypeError(f"append type mismatch {self.dbtype} vs {other.dbtype}")
        if self.dbtype == DBType.VARCHAR:
            heap, recode, new_codes = self.heap.merge(
                [None if c == 0 else str(other.heap.values[c])
                 for c in other.data])
            data = np.concatenate([recode[self.data], new_codes])
            return Column(self.dbtype, data, heap=heap)
        return Column(self.dbtype,
                      np.concatenate([self.data, other.data]),
                      scale=self.scale)
