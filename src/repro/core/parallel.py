"""Chunked parallel / distributed query execution (paper Fig. 2 -> SPMD).

MonetDB parallelizes by splitting the largest table into chunks, running
"parallelizable" MAL operators per chunk, and merging before "blocking"
operators.  The TPU-native restatement (DESIGN.md §3): row-shard the base
columns over the mesh's ``data`` axis with ``shard_map``; the mappable span
(select masks, scalar expressions, partial aggregates) runs per shard; the
merge is a collective (psum / pmin / pmax) — exactly the chunk-merge tree of
Fig. 2 with the merge node lowered to an all-reduce.

Two execution tiers:

* ``DistributedScanAgg`` — the device tier for the hot OLAP pattern
  Aggregate(Filter*(Scan)) with dense group domains: it streams
  morsel-sized column batches through the HBM-budgeted block cache
  (``device_cache.DeviceBufferManager``) and merges per-batch raw partials
  with an order-fixed carry, so the query runs on devices whose memory is
  smaller than the table.  The batch decomposition is *independent of the
  device budget* — unbudgeted, generous and tight budgets all execute the
  identical sequence of jitted batch steps, so results are bit-identical
  across budgets and only the transfer/caching behaviour differs
  (resident: blocks stay cached across queries; streamed: LRU eviction
  recycles them, double-buffered prefetch overlaps the next batch's
  host→device copy with the current batch's compute).
* ``ParallelExecutor`` — Executor subclass that consumes the unified
  physical plan (``physplan.plan_physical``): a scan-agg core annotated
  device-resident/device-streamed runs through ``DistributedScanAgg``, a
  host-side suffix (ORDER BY / LIMIT / projection / HAVING) executes over
  the assembled aggregate, and everything else goes to the (host)
  sequential program.  ``physplan.choose_device_tier`` decides
  streamed-device vs resident-device vs host-spill from the byte
  estimates, biased by the device cache's hit history.

``build_query_step``/``make_fragment`` (the single-shot whole-table
fragment) remain for the multi-pod dry-run, which lowers the engine on the
production mesh.

Chunking heuristics follow the paper: the shard count comes from the mesh
("cores"), and small tables are not split at all (`MIN_ROWS_TO_SHARD`).
"""

from __future__ import annotations

import threading
from typing import Optional

import numpy as np

import jax

# Analytical correctness needs 64-bit aggregation (the paper's engine sums
# DECIMALs exactly).  Enabling x64 only widens the *available* dtypes; all
# model-side code in this repo is dtype-explicit, so LM HLO is unaffected.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from .device_cache import (DeviceBlockKeys, DeviceBudgetError,
                           DeviceBufferManager)
from .executor import Executor, _res_nulls, compile_plan
from .expression import EvalContext, Expr, ExprResult
from .physplan import (AGG_RESULT_NAME, PhysicalPlan, ScanAggSpec,
                       TIER_DEVICE_RESIDENT, choose_device_tier,
                       match_scan_agg,  # noqa: F401  (re-exported for tests)
                       mesh_shards, partial_layout, scan_agg_geometry)
from .relalg import PlanNode
from .types import DBType

# The scan-agg pattern matcher, the partial-matrix layout, the batch
# geometry and the tier-placement policy all live in physplan.py (the
# unified physical planner); this module executes what the planner
# decided.  ``match_scan_agg`` / ``ScanAggSpec`` / ``partial_layout`` are
# re-exported above for existing importers.


# ---------------------------------------------------------------------------
# the shard_map fragment
# ---------------------------------------------------------------------------


def _eval_jnp(expr: Expr, arrays: dict, meta: dict) -> ExprResult:
    ctx = EvalContext(arrays, meta, xp=jnp)
    return expr.eval(ctx)


def _fragment_mask_gid(spec: ScanAggSpec, meta: dict, valid, arrays):
    """Shared SPMD prologue: the filter mask and the dense mixed-radix gid.
    One definition serves both the single-shot fragment and the batched
    raw-partial fragment — any fix to NULL masking or domain decoding
    lands in both, preserving their bit-identity."""
    mask = valid
    for conj in spec.conjuncts:
        r = _eval_jnp(conj, arrays, meta)
        m = r.values != 0
        if r.null is not None:
            m = m & ~r.null
        mask = mask & m
    if spec.group_keys:
        gid = jnp.zeros(valid.shape, dtype=jnp.int32)
        for k, (off, card) in zip(spec.group_keys, spec.key_domains):
            t, heap, scale = meta[k]
            kv = arrays[k]
            code = (kv.astype(jnp.float64) - off).astype(jnp.int32) \
                if t not in (DBType.VARCHAR,) else kv.astype(jnp.int32)
            code = jnp.clip(code, 0, card - 1)
            gid = gid * card + code
    else:
        gid = jnp.zeros(valid.shape, dtype=jnp.int32)
    return mask, gid


def _fragment_partials(spec: ScanAggSpec, meta: dict, mask, gid, arrays,
                       data_axis):
    """Shared SPMD core: evaluate every aggregate expression once, stack
    the sum-like columns in ``partial_layout`` order into ONE segment_sum
    + ONE psum (paper Fig. 2 per-chunk work, MAL-fused), and merge each
    min/max via its own segment+collective.  Returns (seg, extras) —
    mergeable raw partials, not yet finalized."""
    layout = partial_layout(spec)
    sum_cols = [mask.astype(jnp.float64)]            # cnt_star
    evals = {}
    for i, a in enumerate(spec.aggs):
        if a.expr is None:
            continue
        r = _eval_jnp(a.expr, arrays, meta)
        ok = mask if r.null is None else (mask & ~r.null)
        f = r.as_float(jnp)
        evals[i] = (ok, f)
        sum_cols.append(ok.astype(jnp.float64))      # per-agg count
        if a.fn in ("sum", "avg"):
            sum_cols.append(jnp.where(ok, f, 0.0))
    stacked = jnp.stack(sum_cols, axis=1)            # (rows, n_sum)
    seg = jax.ops.segment_sum(stacked, gid, num_segments=spec.n_groups)
    seg = jax.lax.psum(seg, data_axis)               # one collective
    big = jnp.float64(np.inf)
    extras = {}
    for i, fn, _cnt, out_col in layout.minmax:
        ok, f = evals[i]
        if fn == "min":
            v = jnp.where(ok, f, big)
            s = jax.lax.pmin(jax.ops.segment_min(
                v, gid, num_segments=spec.n_groups), data_axis)
        else:
            v = jnp.where(ok, f, -big)
            s = jax.lax.pmax(jax.ops.segment_max(
                v, gid, num_segments=spec.n_groups), data_axis)
        extras[out_col] = s
    return seg, extras


def make_fragment(spec: ScanAggSpec, meta: dict, data_axis: str = "data"):
    """Build the per-shard SPMD function (traced under shard_map).

    arrays: {col: (rows_local,)} storage-repr jnp arrays; ``valid``:
    (rows_local,) bool marking real (non-padding) rows.  Returns
    (n_groups, n_aggs+1) merged + finalized results: per agg, the ratio /
    NULL masking already applied (single-shot whole-input execution).
    """
    layout = partial_layout(spec)

    def fragment(valid, **arrays):
        mask, gid = _fragment_mask_gid(spec, meta, valid, arrays)
        seg, extras = _fragment_partials(spec, meta, mask, gid, arrays,
                                         data_axis)
        cnt_star = seg[:, 0]
        outs = {}
        for i, kind, cnt_idx, val_idx in layout.plans:
            if kind == "count_star":
                outs[i] = cnt_star
            elif kind == "count":
                outs[i] = seg[:, cnt_idx]
            else:
                cnt = seg[:, cnt_idx]
                v = seg[:, val_idx]
                outs[i] = jnp.where(
                    cnt > 0,
                    v if kind == "sum" else v / jnp.maximum(cnt, 1.0),
                    jnp.nan)
        for i, _fn, cnt_idx, out_col in layout.minmax:
            outs[i] = jnp.where(seg[:, cnt_idx] > 0, extras[out_col],
                                jnp.nan)
        cols = [outs[i] for i in range(len(spec.aggs))] + [cnt_star]
        return jnp.stack(cols, axis=1)          # (n_groups, n_aggs+1)

    return fragment


def _shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax versions: top-level ``jax.shard_map`` with
    ``check_vma`` on newer releases, ``jax.experimental.shard_map`` with
    ``check_rep`` on older ones."""
    try:
        from jax import shard_map as sm              # newer jax
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def build_query_step(spec: ScanAggSpec, meta: dict, mesh: Mesh,
                     data_axis: str = "data"):
    """jit(shard_map(fragment)) with row-sharded inputs; also used by the
    multi-pod dry-run to lower the engine on the production mesh."""
    axes = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
    rowspec = P(axes if len(axes) > 1 else axes[0])

    def merged_axis_fragment(valid, **arrays):
        frag = make_fragment(spec, meta, data_axis=axes)
        return frag(valid, **arrays)

    in_specs = (rowspec,) + tuple(rowspec for _ in spec.columns)
    f = _shard_map_compat(
        lambda valid, *cols: merged_axis_fragment(
            valid, **dict(zip(spec.columns, cols))),
        mesh=mesh, in_specs=in_specs, out_specs=P())
    return jax.jit(f)


_STEP_CACHE: dict = {}
# concurrent queries may race to build the same jitted step; the lock makes
# the check-then-build atomic so one trace is built and shared (tracing the
# same fragment twice is wasted work, and a dict insert during another
# thread's insert is not guaranteed safe across interpreters)
_STEP_CACHE_LOCK = threading.Lock()

# XLA's cross-device collectives rendezvous by (run_id, device set): two
# threads dispatching collective programs at once interleave their
# participants into each other's rendezvous and deadlock (observed on the
# forced-multi-device CPU backend; real accelerators serialize launches on
# a stream anyway).  ONE in-process device dispatch at a time — host-tier
# queries are unaffected and still run concurrently.
_DEVICE_DISPATCH_LOCK = threading.Lock()


def _meta_key(spec: ScanAggSpec, meta: dict) -> tuple:
    """The trace-relevant identity of each referenced column: dtype, scale
    and — for VARCHAR — the heap content fingerprint.  String literal
    codes and heap bounds are baked into jitted traces at Python time
    (expression.py), and an append that introduces a novel string
    re-sorts/renumbers the whole heap, so a step compiled against the old
    heap must not be reused."""
    out = []
    for c in spec.columns:
        t, heap, scale = meta[c]
        out.append((c, t, scale,
                    heap.fingerprint() if heap is not None else None))
    return tuple(out)


def _cached_query_step(spec: ScanAggSpec, meta: dict, mesh: Mesh, pad: int):
    """Compiled-fragment cache: repeated queries (the hot-run benchmark
    protocol, dashboards) reuse the jitted shard_map step instead of
    re-tracing per call."""
    key = (spec.table, repr(spec.conjuncts), tuple(spec.group_keys),
           tuple(spec.key_domains),     # baked into the trace as constants
           tuple((a.fn, repr(a.expr)) for a in spec.aggs),
           _meta_key(spec, meta), spec.n_groups, pad,
           id(mesh.devices.flat[0]),
           tuple(mesh.shape.items()))
    with _STEP_CACHE_LOCK:
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = build_query_step(spec, meta, mesh)
        return _STEP_CACHE[key]


# ---------------------------------------------------------------------------
# batched device-tier execution: raw partials + order-fixed carry
# (PartialLayout / partial_layout live in physplan.py — the layout of the
# partial matrix is physical-plan metadata the geometry estimates need)
# ---------------------------------------------------------------------------


def make_partial_fragment(spec: ScanAggSpec, meta: dict,
                          data_axis="data"):
    """Per-shard SPMD function returning *mergeable* raw partials
    (n_groups, K) in ``partial_layout`` order — the streaming analogue of
    ``make_fragment``: the identical shared prologue/core, minus the
    finalization (which ``finalize_partials`` applies once after the
    carry has merged every batch)."""
    layout = partial_layout(spec)

    def fragment(valid, **arrays):
        mask, gid = _fragment_mask_gid(spec, meta, valid, arrays)
        seg, extras = _fragment_partials(spec, meta, mask, gid, arrays,
                                         data_axis)
        if not extras:
            return seg
        cols = [extras[c][:, None] for c in sorted(extras)]
        return jnp.concatenate([seg] + cols, axis=1)

    return fragment


def finalize_partials(spec: ScanAggSpec, partial: np.ndarray) -> np.ndarray:
    """Merged raw partials -> the (n_groups, n_aggs + 1) matrix
    ``_assemble`` consumes (same formulas the single-shot fragment applies
    on device: avg ratios, NULL where a group saw no valid rows)."""
    layout = partial_layout(spec)
    cnt_star = partial[:, 0]
    outs = {}
    for i, kind, cnt_col, val_col in layout.plans:
        if kind == "count_star":
            outs[i] = cnt_star
        elif kind == "count":
            outs[i] = partial[:, cnt_col]
        else:
            cnt = partial[:, cnt_col]
            v = partial[:, val_col]
            outs[i] = np.where(
                cnt > 0,
                v if kind == "sum" else v / np.maximum(cnt, 1.0),
                np.nan)
    for i, _fn, cnt_col, out_col in layout.minmax:
        outs[i] = np.where(partial[:, cnt_col] > 0, partial[:, out_col],
                           np.nan)
    cols = [outs[i] for i in range(len(spec.aggs))] + [cnt_star]
    return np.stack(cols, axis=1)


def _mesh_axes(mesh: Mesh):
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def build_batch_step(spec: ScanAggSpec, meta: dict, mesh: Mesh):
    """(init_fn, step_fn): ``step(carry, valid, *cols) -> carry'`` — one
    jitted fused unit per batch: the shard_map partial fragment plus the
    carry combine (add / min / max per column).  The carry is replicated
    over the mesh; ``init_fn`` materializes the combine identity on device
    (no host→device transfer beyond the compiled constant)."""
    axes = _mesh_axes(mesh)
    rowspec = P(axes if len(axes) > 1 else axes[0])
    layout = partial_layout(spec)
    frag = make_partial_fragment(spec, meta, data_axis=axes)
    sm = _shard_map_compat(
        lambda valid, *cols: frag(valid, **dict(zip(spec.columns, cols))),
        mesh=mesh, in_specs=(rowspec,) * (1 + len(spec.columns)),
        out_specs=P())
    kinds = layout.kinds

    def step(carry, valid, *cols):
        part = sm(valid, *cols)
        return jnp.where(kinds == 0, carry + part,
                         jnp.where(kinds == 1, jnp.minimum(carry, part),
                                   jnp.maximum(carry, part)))

    rep_sh = NamedSharding(mesh, P())
    g, k = spec.n_groups, len(kinds)
    init = jax.jit(lambda: jnp.broadcast_to(
        jnp.asarray(layout.init), (g, k)) + jnp.float64(0.0),
        out_shardings=rep_sh)
    return init, jax.jit(step, out_shardings=rep_sh)


def _cached_batch_step(spec: ScanAggSpec, meta: dict, mesh: Mesh,
                       batch_rows: int):
    key = ("batch", spec.table, repr(spec.conjuncts),
           tuple(spec.group_keys),
           tuple(spec.key_domains),     # baked into the trace as constants:
                                        # a shifted key domain (delete/append
                                        # moving min/max at equal cardinality)
                                        # must not reuse the stale step
           tuple((a.fn, repr(a.expr)) for a in spec.aggs),
           _meta_key(spec, meta),
           spec.n_groups, batch_rows, id(mesh.devices.flat[0]),
           tuple(mesh.shape.items()))
    with _STEP_CACHE_LOCK:
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = build_batch_step(spec, meta, mesh)
        return _STEP_CACHE[key]


class DistributedScanAgg:
    """Streamed device-tier execution of one Aggregate(Filter*(Scan)).

    The table's rows are cut into fixed-size batches (``batch_rows``,
    rounded up to a multiple of the shard count; NOT derived from the
    budget — identical batching across budgets is what makes the budget
    matrix bit-identical).  Each (column, batch) block flows through the
    ``DeviceBufferManager``:

    * resident tier: every block fits the budget at once; after the first
      query all blocks are cache hits and no host→device bytes move;
    * streamed tier: only batches fit; blocks of consumed batches are
      LRU-evicted to make room, and batch N+1's transfers are issued
      (non-blocking ``jax.device_put``) before batch N's compute so copy
      and compute overlap — ``jax`` orders them by data dependency, and
      the final host fetch of the carry is the ``block_until_ready``
      fence.

    The merge carry (a dirty intermediate block) may itself be evicted
    under a tight budget: it is copied back to host and transparently
    re-uploaded — the only writeback case, since base-column blocks are
    clean by definition."""

    def __init__(self, db, spec: ScanAggSpec, mesh: Mesh,
                 batch_rows: Optional[int] = None, skip_set=None):
        self.db = db
        self.spec = spec
        self.mesh = mesh
        self.devman: DeviceBufferManager = getattr(
            db, "device_manager", None) or DeviceBufferManager(
                stats=getattr(db, "buffer_manager", None).stats
                if getattr(db, "buffer_manager", None) else None)
        self.table = db.catalog.table(spec.table)
        self.n_rows = self.table.num_rows
        # transaction snapshots run under a unique key namespace: their
        # tables reuse the version number the next committed write gets,
        # so bare versions would let rolled-back rows alias committed ones
        self._key_ns = getattr(db, "device_key_namespace", 0)
        # delta geometry (base tables report delta_rows == 0): batches that
        # lie fully inside the immutable base are keyed by base_version only
        # and so survive appends; tail-overlapping batches carry the delta
        # epoch and are the only entries an append invalidates
        self.base_rows = self.table.base_rows
        self.delta_rows = self.table.delta_rows
        self.base_version_key = (self._key_ns, "b", self.table.base_version)
        self.delta_version_key = (self._key_ns, "d", self.table.base_version,
                                  self.table.delta_epoch)
        # mesh identity (device ids + axis layout) joins the shard key:
        # blocks are sharded FOR a mesh, and serving a 4-device block to a
        # 2-device step raises inside jit — which the executor would
        # swallow as a host fallback, silently losing the device tier
        self.mesh_key = (tuple(mesh.shape.items()),
                         tuple(d.id for d in mesh.devices.flat))
        # batch decomposition + byte footprint come from the physical
        # planner's shared geometry model — identical numbers whether the
        # tier was chosen through plan_physical or a direct construction
        geom = scan_agg_geometry(spec, self.table, mesh_shards(mesh),
                                 batch_rows)
        self.batch_rows = geom.batch_rows
        self.n_batches = geom.n_batches
        self.row_bytes = geom.row_bytes
        self.carry_nbytes = geom.carry_nbytes
        self.batch_bytes = geom.batch_bytes
        self.resident_bytes = geom.resident_bytes
        # imprint-derived skip-set (physplan.SkipSet): intersected with the
        # batch geometry so non-qualifying batches are never built, never
        # prefetched and never device_put.  Execution-time re-validation:
        # a skip-set derived against another table version (an append or
        # DELETE raced the lowering) is discarded, not half-trusted.
        if skip_set is not None and not skip_set.valid_for(self.table):
            skip_set = None
        self.skip_set = skip_set
        m = self.batch_rows
        self.live_batches = [
            b for b in range(self.n_batches)
            if skip_set is None or skip_set.batch_qualifies(
                b * m, min(self.n_rows, b * m + m))]
        self.meta = {}
        for c in spec.columns:
            col = self.table.column(c)
            self.meta[c] = (col.dbtype, col.heap, col.scale)

    # -- placement decision ---------------------------------------------------
    def choose_tier(self) -> str:
        return choose_device_tier(
            self.resident_bytes, self.batch_bytes, self.devman.budget,
            host_budget=getattr(self.db, "memory_budget", None),
            host_bytes=self.resident_bytes,
            hit_history=self.devman.hit_history(self.spec.table))

    # -- block builders -------------------------------------------------------
    def _builders(self, b: int):
        """Yield (cache key, host-build thunk) for batch ``b``'s blocks:
        the valid mask first, then every referenced column, each padded to
        exactly ``batch_rows`` rows (one trace serves all batches).  The
        shard component of the key is ``(mesh, batch_rows, b)``: a block
        is only reusable by a query slicing the same geometry onto the
        same devices — a different ``device_batch_rows`` cuts different
        row ranges (a bare batch index would serve the wrong rows as a
        cache hit), and a different mesh needs differently-sharded
        placements."""
        spec, table = self.spec, self.table
        m = self.batch_rows
        s = b * m
        e = min(self.n_rows, s + m)
        shard = (self.mesh_key, m, b)
        vkey = self._batch_version_key(b)

        def bvalid():
            a = np.zeros(m, dtype=bool)
            a[:e - s] = True
            return a

        yield DeviceBlockKeys.valid(spec.table, vkey, shard), bvalid
        for c in spec.columns:
            col = table.column(c)

            def bcol(col=col):
                a = np.zeros(m, dtype=col.data.dtype)
                a[:e - s] = col.data[s:e]       # memmap: pages one morsel
                return a

            yield (DeviceBlockKeys.column(spec.table, c, vkey, shard),
                   bcol)

    def _batch_version_key(self, b: int):
        """Epoch-keyed caching (delta store): the version component of batch
        ``b``'s block keys.  A batch whose rows lie entirely within the
        immutable base is keyed ``(ns, "b", base_version)`` — stable across
        appends, so a repeat scan after an append re-uploads only the tail.
        A batch overlapping the delta tail is keyed
        ``(ns, "d", base_version, delta_epoch)``; the next append bumps the
        epoch, orphaning exactly those entries (reaped by
        ``DeviceBufferManager.invalidate_delta`` / LRU).  Soundness: a batch
        that ends at the base boundary *before* an append keeps the same
        rows after it (the base is immutable), so serving its "b" entry as a
        hit is correct; a batch that gains rows by an append necessarily
        overlaps the tail and flips to a fresh "d" key — never a stale hit."""
        if self.delta_rows == 0:
            return self.base_version_key
        e = min(self.n_rows, (b + 1) * self.batch_rows)
        if e <= self.base_rows:
            return self.base_version_key
        return self.delta_version_key

    # requires-lock: _DEVICE_DISPATCH_LOCK
    def _issue_prefetch(self, b: int, prefetched: set, query_keys: set,
                        sh) -> None:
        """Start batch ``b``'s host→device copies (non-blocking) so they
        overlap the current batch's compute.  ``put`` recycles the budget
        by evicting *unpinned* (already-consumed) blocks, and the loop
        stops issuing the moment room would require touching a pinned one
        — double-buffering never breaks ``device_bytes_peak <= budget``."""
        for key, build in self._builders(b):
            if key in self.devman or key in prefetched:
                continue       # cached: will be a cache hit at consumption
            try:
                # single-flight even here: two streamed queries walking the
                # same table prefetch the same next batch — one upload,
                # the other attaches (and still takes its own pin)
                self.devman.get_or_put(key, build, sharding=sh, pin=True)
            except DeviceBudgetError:
                return
            prefetched.add(key)
            query_keys.add(key)

    # -- execution ------------------------------------------------------------
    def run(self, tier: Optional[str] = None) -> np.ndarray:
        tier = tier or self.choose_tier()
        if tier == "host":
            raise DeviceBudgetError("input does not fit the device tier")
        # serialize the whole batch loop: every step() carries a psum, and
        # concurrent collective dispatch deadlocks the XLA rendezvous (see
        # _DEVICE_DISPATCH_LOCK).  Cross-query sharing still happens — a
        # later query attaches to this one's cached blocks via get_or_put
        with _DEVICE_DISPATCH_LOCK:
            return self._run_locked(tier)

    def _run_locked(self, tier: str) -> np.ndarray:  # requires-lock: _DEVICE_DISPATCH_LOCK
        devman = self.devman
        spec = self.spec
        init_fn, step = _cached_batch_step(spec, self.meta, self.mesh,
                                           self.batch_rows)
        axes = _mesh_axes(self.mesh)
        sh = NamedSharding(self.mesh, P(axes if len(axes) > 1 else axes[0]))
        rep_sh = NamedSharding(self.mesh, P())
        carry_key = DeviceBlockKeys.carry()
        query_keys: set = {carry_key}
        pinned: set = set()
        prefetched: set = set()
        try:
            carry = devman.adopt(carry_key, init_fn(),
                                 nbytes=self.carry_nbytes, dirty=True)
            live = self.live_batches
            if len(live) < self.n_batches:
                # a skipped batch contributes exactly the carry-combine
                # identity (+0 / +inf / -inf): not running its step leaves
                # the carry bit-identical to running it.  Account what the
                # zone maps saved: every block of every skipped batch would
                # have been padded to batch_rows and uploaded.
                blk = self.skip_set.block
                live_set = set(live)
                skipped_blocks = 0
                for b in range(self.n_batches):
                    if b in live_set:
                        continue
                    s = b * self.batch_rows
                    e = min(self.n_rows, s + self.batch_rows)
                    skipped_blocks += -(-(e - s) // blk)
                devman.bump(
                    blocks_skipped=skipped_blocks,
                    bytes_skipped_h2d=(self.n_batches - len(live))
                    * self.batch_rows * self.row_bytes)
            for i, b in enumerate(live):
                arrs = []
                batch_keys = []
                for key, build in self._builders(b):
                    if key in prefetched:
                        prefetched.discard(key)         # pinned at issue
                        arr = devman.peek(key)
                        devman.bump(device_prefetch_hits=1)
                    else:
                        # single-flight: a concurrent query needing the
                        # same block attaches to one in-flight upload
                        # instead of issuing its own (shared morsel scans)
                        arr = devman.get_or_put(key, build, sharding=sh,
                                                pin=True)
                    pinned.add(key)
                    query_keys.add(key)
                    batch_keys.append(key)
                    arrs.append(arr)
                # the carry is unpinned between batches so a tight budget
                # may have evicted it (writeback); re-upload before use
                if carry_key not in devman:
                    host = devman.take_host(carry_key)
                    carry = devman.put(carry_key, host, sharding=rep_sh,
                                       pin=False, dirty=True)
                devman.pin(carry_key)
                if i + 1 < len(live):
                    self._issue_prefetch(live[i + 1], prefetched,
                                         query_keys, sh)
                carry = step(carry, *arrs)              # async dispatch
                devman.unpin(carry_key)
                devman.adopt(carry_key, carry, nbytes=self.carry_nbytes,
                             dirty=True)
                for key in batch_keys:
                    devman.unpin(key)
                    pinned.discard(key)
            out = devman.take_host(carry_key)   # blocks: the final fence
            return finalize_partials(spec, out)
        finally:
            for key in pinned | prefetched:
                devman.unpin(key)
            devman.drop(carry_key)
            if devman.budget is None:
                # zero-config: no silent device-memory growth across
                # queries — cross-query caching is a budgeted feature
                for key in query_keys:
                    devman.drop(key)


# ---------------------------------------------------------------------------
# executor integration
# ---------------------------------------------------------------------------


class _SuffixDatabase:
    """Minimal database view for suffix execution: one catalog entry — the
    assembled scan-agg core under ``AGG_RESULT_NAME`` — sharing the parent
    database's buffer manager (one budget accounting)."""

    class _Catalog:
        def __init__(self, table):
            self._table = table

        def table(self, name):
            if name != AGG_RESULT_NAME:
                raise KeyError(name)
            return self._table

    def __init__(self, table, buffer_manager):
        self.catalog = self._Catalog(table)
        self.buffer_manager = buffer_manager
        self.index_manager = None


class ParallelExecutor(Executor):
    """Routes qualifying plans to the shard_map tier (paper Fig. 2)."""

    def __init__(self, database, mesh: Optional[Mesh] = None,
                 use_pallas: bool = False):
        super().__init__(database)
        self.mesh = mesh
        self.use_pallas = use_pallas
        self.distributed_hits = 0

    def _default_mesh(self) -> Mesh:
        if self.mesh is None:
            dev = np.array(jax.devices())
            self.mesh = Mesh(dev.reshape(-1), ("data",))
        return self.mesh

    def execute(self, plan: PlanNode, do_optimize: bool = True):
        from .serving import lower_cached
        mesh = self._default_mesh()
        phys, rendered, hit = lower_cached(self.db, plan,
                                           do_optimize=do_optimize,
                                           distributed=True, mesh=mesh)
        self.policy = phys.policy
        self.stats.plan_repr = rendered
        self.stats.plan_cache_hit = hit
        with self._admitted(phys):
            if phys.device_tier():
                result = self._try_distributed(phys)
                if result is not None:
                    return result
                # the planner chose the device tier but runtime lowering
                # failed; the host program is the fallback — re-render so
                # EXPLAIN/stats reflect what actually ran
                phys.demote_device()
                self.stats.plan_repr = phys.render()
            prog = compile_plan(phys.plan, self.db.catalog)
            result = self.run_program(prog)
        self._plan_feedback(plan, True)
        return result

    # -- distributed scan-agg -------------------------------------------------
    def _try_distributed(self, phys: PhysicalPlan):
        """Run the physical plan's scan-agg core through the device tier
        (the tier the planner annotated), then the host-side suffix
        (ORDER BY / LIMIT / projection / HAVING) over the assembled
        aggregate; None means a runtime lowering gap — the caller falls
        back to the host program."""
        spec = phys.scan_agg
        table = self.db.catalog.table(spec.table)
        try:
            agg = DistributedScanAgg(
                self.db, spec, self._default_mesh(),
                batch_rows=getattr(self.db, "device_batch_rows", None),
                skip_set=phys.core_skip_set())
        except Exception:
            return None
        tier = "resident" if phys.agg_tier == TIER_DEVICE_RESIDENT \
            else "streamed"
        from .executor import (DEVICE_DELTA_FIELDS, INGEST_DELTA_FIELDS,
                               SKIP_DELTA_FIELDS, stats_base)
        fields = DEVICE_DELTA_FIELDS + SKIP_DELTA_FIELDS + INGEST_DELTA_FIELDS
        dm = agg.devman.stats
        base = stats_base(dm, fields)
        try:
            out = agg.run(tier)
        except Exception:
            return None      # fall back to the host tier on any lowering gap
        if agg.delta_rows:
            # merge-on-read visibility: the scan consumed a delta tail
            agg.devman.bump(delta_rows=agg.delta_rows)
        result = self._assemble(spec, out, table)
        # close the device-counter window BEFORE the suffix runs (its host
        # program threads the same delta fields through run_program)...
        end = stats_base(dm, fields)
        if phys.suffix_plan is not None:
            try:
                result = self._run_suffix(phys.suffix_plan, result)
            except Exception:
                return None  # suffix gap: host program recomputes everything
        # ...but claim the device tier only once the WHOLE query succeeded:
        # a suffix failure falls back to a full host recompute, and
        # device_tier / distributed_hits must describe the result returned
        self.distributed_hits += 1
        self.stats.device_tier = tier
        for f, b, e in zip(fields, base, end):
            setattr(self.stats, f, getattr(self.stats, f) + e - b)
        # lifetime gauge, reported only by queries that ran on the device
        # tier (host-tier queries keep 0 alongside device_tier == "")
        self.stats.device_bytes_peak = dm.device_bytes_peak
        return result

    def _run_suffix(self, suffix_plan: PlanNode, table):
        """Execute the suffix operators over the assembled aggregate: a
        host program against a one-table catalog holding the (tiny) core
        result.  Stats and policy are shared, so suffix sorts/limits that
        spill are counted against this query."""
        sdb = _SuffixDatabase(table, self.bufman)
        sub = Executor(sdb)
        sub.stats = self.stats
        sub.policy = self.policy
        prog = compile_plan(suffix_plan, sdb.catalog)
        return sub.run_program(prog)

    def _assemble(self, spec: ScanAggSpec, out: np.ndarray, table):
        from .column import Column
        from .table import Table
        from .types import ColumnSchema, TableSchema
        cnt_star = out[:, -1]
        present = cnt_star > 0 if spec.group_keys else np.ones(1, bool)
        gids = np.nonzero(present)[0]
        cols = {}
        schemas = []
        # reconstruct key values from the mixed-radix gid
        rem = gids.copy()
        radices = [card for _, card in spec.key_domains]
        digits = []
        for off, card in reversed(spec.key_domains):
            digits.append(rem % card)
            rem = rem // card
        digits.reverse()
        for k, (off, card), d in zip(spec.group_keys, spec.key_domains,
                                     digits):
            col = table.column(k)
            if col.dbtype == DBType.VARCHAR:
                vals = d.astype(np.int32)
                cols[k] = Column(DBType.VARCHAR, vals, heap=col.heap)
            else:
                vals = (d + off).astype(col.data.dtype)
                cols[k] = Column(col.dbtype, vals, scale=col.scale)
            schemas.append(ColumnSchema(k, col.dbtype, scale=col.scale))
        for i, a in enumerate(spec.aggs):
            v = out[gids, i]
            if a.fn == "count":
                cols[a.name] = Column(DBType.INT64, v.astype(np.int64))
                schemas.append(ColumnSchema(a.name, DBType.INT64))
            else:
                cols[a.name] = Column(DBType.FLOAT64, v.astype(np.float64))
                schemas.append(ColumnSchema(a.name, DBType.FLOAT64))
        return Table(TableSchema("result", tuple(schemas)), cols)

    # -- host-chunked fallback (Fig. 2 semantics without devices) -------------
    def run_chunked_host(self, spec: ScanAggSpec, n_chunks: int):
        """Reference chunked execution used by tests to validate that
        per-chunk partials + merge == sequential results."""
        db = self.db
        table = db.catalog.table(spec.table)
        n = table.num_rows
        bounds = np.linspace(0, n, n_chunks + 1).astype(int)
        partial_sums = None
        for ci in range(n_chunks):
            s, e = bounds[ci], bounds[ci + 1]
            arrays = {}
            meta = {}
            for c in spec.columns:
                col = table.column(c)
                arrays[c] = np.asarray(col.data)[s:e]
                meta[c] = (col.dbtype, col.heap, col.scale)
            ctx_mask = np.ones(e - s, dtype=bool)
            for conj in spec.conjuncts:
                r = conj.eval(EvalContext(arrays, meta, xp=np))
                m = np.asarray(r.values) != 0
                if r.null is not None:
                    m &= ~np.asarray(r.null)
                ctx_mask &= m
            gid = np.zeros(e - s, dtype=np.int64)
            for k, (off, card) in zip(spec.group_keys, spec.key_domains):
                t, heap, scale = meta[k]
                kv = arrays[k]
                code = kv.astype(np.int64) if t == DBType.VARCHAR \
                    else (kv.astype(np.float64) - off).astype(np.int64)
                code = np.clip(code, 0, card - 1)
                gid = gid * card + code
            chunk = np.zeros((spec.n_groups, 2 * len(spec.aggs) + 1))
            chunk[:, -1] = np.bincount(gid[ctx_mask],
                                       minlength=spec.n_groups)
            for i, a in enumerate(spec.aggs):
                if a.expr is None:
                    chunk[:, 2 * i] = chunk[:, -1]
                    chunk[:, 2 * i + 1] = chunk[:, -1]
                    continue
                r = a.expr.eval(EvalContext(arrays, meta, xp=np))
                ok = ctx_mask & ~_res_nulls(r)
                f = r.as_float(np)
                chunk[:, 2 * i] = np.bincount(
                    gid[ok], weights=f[ok], minlength=spec.n_groups)
                chunk[:, 2 * i + 1] = np.bincount(
                    gid[ok], minlength=spec.n_groups)
            partial_sums = chunk if partial_sums is None \
                else partial_sums + chunk
        return partial_sums
