"""Chunked parallel / distributed query execution (paper Fig. 2 -> SPMD).

MonetDB parallelizes by splitting the largest table into chunks, running
"parallelizable" MAL operators per chunk, and merging before "blocking"
operators.  The TPU-native restatement (DESIGN.md §3): row-shard the base
columns over the mesh's ``data`` axis with ``shard_map``; the mappable span
(select masks, scalar expressions, partial aggregates) runs per shard; the
merge is a collective (psum / pmin / pmax) — exactly the chunk-merge tree of
Fig. 2 with the merge node lowered to an all-reduce.

Two execution tiers:

* ``DistributedScanAgg`` — the jit'd shard_map pipeline for the hot OLAP
  pattern Aggregate(Filter*(Scan)) with dense group domains.  This is the
  fragment the multi-pod dry-run lowers on the production mesh, and it uses
  the Pallas kernels per shard when enabled.
* ``ParallelExecutor`` — Executor subclass that routes qualifying plans to
  the distributed tier and everything else to the (host) sequential tier,
  optionally with host-level chunking to exercise merge semantics.

Chunking heuristics follow the paper: the shard count comes from the mesh
("cores"), and small tables are not split at all (`MIN_ROWS_TO_SHARD`).
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import numpy as np

import jax

# Analytical correctness needs 64-bit aggregation (the paper's engine sums
# DECIMALs exactly).  Enabling x64 only widens the *available* dtypes; all
# model-side code in this repo is dtype-explicit, so LM HLO is unaffected.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from .executor import Executor, _res_nulls
from .expression import EvalContext, Expr, ExprResult
from .optimizer import optimize, split_conjuncts
from .relalg import (AggregateNode, AggSpec, FilterNode, PlanNode,
                     ProjectNode, ScanNode)
from .types import DBType, NULL_SENTINEL, is_float

MAX_DENSE_GROUPS = 4096
MIN_ROWS_TO_SHARD = 4096      # paper: don't split small columns
_SUPPORTED_AGGS = {"count", "sum", "avg", "min", "max"}


# ---------------------------------------------------------------------------
# pattern extraction
# ---------------------------------------------------------------------------


@dataclass
class ScanAggSpec:
    table: str
    conjuncts: list[Expr]
    group_keys: list[str]
    key_domains: list[tuple[float, int]]     # (offset, cardinality) per key
    aggs: list[AggSpec]
    n_groups: int
    columns: list[str]                       # all referenced base columns


def match_scan_agg(plan: PlanNode, catalog) -> Optional[ScanAggSpec]:
    """Aggregate( Filter* ( Scan ) ) with dense-domain group keys."""
    if not isinstance(plan, AggregateNode):
        return None
    if any(a.fn not in _SUPPORTED_AGGS for a in plan.aggs):
        return None
    node = plan.child
    conjuncts: list[Expr] = []
    while isinstance(node, FilterNode):
        conjuncts = split_conjuncts(node.predicate) + conjuncts
        node = node.child
    if not isinstance(node, ScanNode):
        return None
    table = catalog.table(node.table)
    # dense domains for the keys
    domains = []
    n_groups = 1
    for k in plan.group_by:
        col = table.column(k)
        if col.dbtype == DBType.VARCHAR:
            offset, card = 0.0, len(col.heap)
        elif col.dbtype == DBType.BOOL:
            offset, card = 0.0, 2
        elif col.dbtype in (DBType.INT32, DBType.INT64, DBType.DATE):
            v = np.asarray(col.data)
            nn = v[v != NULL_SENTINEL[col.dbtype]]
            if nn.size == 0:
                return None
            mn, mx = int(nn.min()), int(nn.max())
            offset, card = float(mn), mx - mn + 1
        else:
            return None
        if card > MAX_DENSE_GROUPS:
            return None
        domains.append((offset, card))
        n_groups *= card
    if n_groups > MAX_DENSE_GROUPS:
        return None
    cols: set[str] = set(plan.group_by)
    for c in conjuncts:
        cols |= c.columns()
    for a in plan.aggs:
        if a.expr is not None:
            cols |= a.expr.columns()
    if not cols:
        cols = {table.schema.names[0]}
    return ScanAggSpec(node.table, conjuncts, list(plan.group_by),
                       domains, list(plan.aggs), n_groups, sorted(cols))


# ---------------------------------------------------------------------------
# the shard_map fragment
# ---------------------------------------------------------------------------


def _eval_jnp(expr: Expr, arrays: dict, meta: dict) -> ExprResult:
    ctx = EvalContext(arrays, meta, xp=jnp)
    return expr.eval(ctx)


def make_fragment(spec: ScanAggSpec, meta: dict, data_axis: str = "data"):
    """Build the per-shard SPMD function (traced under shard_map).

    arrays: {col: (rows_local,)} storage-repr jnp arrays; ``valid``:
    (rows_local,) bool marking real (non-padding) rows.  Returns
    (n_groups, n_out) float32 merged partials: per agg, sum & count & min &
    max slots as needed.
    """
    aggs = spec.aggs
    n_groups = spec.n_groups

    def fragment(valid, **arrays):
        mask = valid
        for conj in spec.conjuncts:
            r = _eval_jnp(conj, arrays, meta)
            m = r.values != 0
            if r.null is not None:
                m = m & ~r.null
            mask = mask & m
        # dense gid (mixed radix over key domains)
        if spec.group_keys:
            gid = jnp.zeros(valid.shape, dtype=jnp.int32)
            for k, (off, card) in zip(spec.group_keys, spec.key_domains):
                t, heap, scale = meta[k]
                kv = arrays[k]
                code = (kv.astype(jnp.float64) - off).astype(jnp.int32) \
                    if t not in (DBType.VARCHAR,) else kv.astype(jnp.int32)
                code = jnp.clip(code, 0, card - 1)
                gid = gid * card + code
        else:
            gid = jnp.zeros(valid.shape, dtype=jnp.int32)
        # One fused pass (paper Fig. 2 per-chunk work, MAL-fused): every
        # sum-like aggregate stacks into a single (rows, k) segment_sum and
        # ONE psum, instead of 2 segment_sums + 2 psums per aggregate
        # (EXPERIMENTS.md §Perf, engine cell).
        sum_cols = [mask.astype(jnp.float64)]            # cnt_star
        plans = []                                       # per-agg decode plan
        minmax = []
        evals = {}
        for i, a in enumerate(aggs):
            if a.expr is None:
                plans.append((i, "count_star", 0, 0))
                continue
            r = _eval_jnp(a.expr, arrays, meta)
            ok = mask if r.null is None else (mask & ~r.null)
            f = r.as_float(jnp)
            evals[i] = (ok, f)
            sum_cols.append(ok.astype(jnp.float64))      # per-agg count
            cnt_idx = len(sum_cols) - 1
            if a.fn in ("sum", "avg"):
                sum_cols.append(jnp.where(ok, f, 0.0))
                plans.append((i, a.fn, cnt_idx, len(sum_cols) - 1))
            elif a.fn == "count":
                plans.append((i, "count", cnt_idx, 0))
            else:
                minmax.append((i, a.fn, cnt_idx))
        stacked = jnp.stack(sum_cols, axis=1)            # (rows, k)
        seg = jax.ops.segment_sum(stacked, gid, num_segments=n_groups)
        seg = jax.lax.psum(seg, data_axis)               # one collective
        cnt_star = seg[:, 0]
        outs = {}
        for i, kind, cnt_idx, val_idx in plans:
            if kind == "count_star":
                outs[i] = cnt_star
            elif kind == "count":
                outs[i] = seg[:, cnt_idx]
            else:
                cnt = seg[:, cnt_idx]
                v = seg[:, val_idx]
                outs[i] = jnp.where(
                    cnt > 0,
                    v if kind == "sum" else v / jnp.maximum(cnt, 1.0),
                    jnp.nan)
        big = jnp.float64(np.inf)
        for i, fn, cnt_idx in minmax:
            ok, f = evals[i]
            if fn == "min":
                v = jnp.where(ok, f, big)
                s = jax.lax.pmin(jax.ops.segment_min(
                    v, gid, num_segments=n_groups), data_axis)
            else:
                v = jnp.where(ok, f, -big)
                s = jax.lax.pmax(jax.ops.segment_max(
                    v, gid, num_segments=n_groups), data_axis)
            outs[i] = jnp.where(seg[:, cnt_idx] > 0, s, jnp.nan)
        cols = [outs[i] for i in range(len(aggs))] + [cnt_star]
        return jnp.stack(cols, axis=1)          # (n_groups, n_aggs+1)

    return fragment


def _shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax versions: top-level ``jax.shard_map`` with
    ``check_vma`` on newer releases, ``jax.experimental.shard_map`` with
    ``check_rep`` on older ones."""
    try:
        from jax import shard_map as sm              # newer jax
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def build_query_step(spec: ScanAggSpec, meta: dict, mesh: Mesh,
                     data_axis: str = "data"):
    """jit(shard_map(fragment)) with row-sharded inputs; also used by the
    multi-pod dry-run to lower the engine on the production mesh."""
    axes = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
    rowspec = P(axes if len(axes) > 1 else axes[0])

    def merged_axis_fragment(valid, **arrays):
        frag = make_fragment(spec, meta, data_axis=axes)
        return frag(valid, **arrays)

    in_specs = (rowspec,) + tuple(rowspec for _ in spec.columns)
    f = _shard_map_compat(
        lambda valid, *cols: merged_axis_fragment(
            valid, **dict(zip(spec.columns, cols))),
        mesh=mesh, in_specs=in_specs, out_specs=P())
    return jax.jit(f)


_STEP_CACHE: dict = {}


def _cached_query_step(spec: ScanAggSpec, meta: dict, mesh: Mesh, pad: int):
    """Compiled-fragment cache: repeated queries (the hot-run benchmark
    protocol, dashboards) reuse the jitted shard_map step instead of
    re-tracing per call."""
    key = (spec.table, repr(spec.conjuncts), tuple(spec.group_keys),
           tuple((a.fn, repr(a.expr)) for a in spec.aggs),
           tuple(spec.columns), spec.n_groups, pad, id(mesh.devices.flat[0]),
           tuple(mesh.shape.items()))
    if key not in _STEP_CACHE:
        _STEP_CACHE[key] = build_query_step(spec, meta, mesh)
    return _STEP_CACHE[key]


# ---------------------------------------------------------------------------
# executor integration
# ---------------------------------------------------------------------------


class ParallelExecutor(Executor):
    """Routes qualifying plans to the shard_map tier (paper Fig. 2)."""

    def __init__(self, database, mesh: Optional[Mesh] = None,
                 use_pallas: bool = False):
        super().__init__(database)
        self.mesh = mesh
        self.use_pallas = use_pallas
        self.distributed_hits = 0

    def _fits_budget(self, plan: PlanNode, catalog) -> bool:
        """The sharded tier is the fast path for inputs that fit in memory;
        over-budget plans stay on the host tier, whose blocking operators
        spill (spill.py) instead of materializing device-resident copies."""
        budget = getattr(self.db, "memory_budget", None)
        if budget is None:
            return True
        from .optimizer import estimate_bytes
        return estimate_bytes(plan, catalog) <= budget

    def _default_mesh(self) -> Mesh:
        if self.mesh is not None:
            return self.mesh
        dev = np.array(jax.devices())
        return Mesh(dev.reshape(-1), ("data",))

    def execute(self, plan: PlanNode, do_optimize: bool = True):
        catalog = self.db.catalog
        if do_optimize:
            plan = optimize(plan, catalog)
        spec = match_scan_agg(plan, catalog)
        if spec is not None and self._fits_budget(plan, catalog):
            table = catalog.table(spec.table)
            if table.num_rows >= MIN_ROWS_TO_SHARD:
                try:
                    return self._run_distributed(spec, plan)
                except Exception:
                    pass     # fall back to the host tier on any lowering gap
        from .executor import compile_plan
        prog = compile_plan(plan, catalog)
        return self.run_program(prog)

    # -- distributed scan-agg -------------------------------------------------
    def _run_distributed(self, spec: ScanAggSpec, plan: AggregateNode):
        mesh = self._default_mesh()
        db = self.db
        table = db.catalog.table(spec.table)
        n = table.num_rows
        shards = 1
        for ax in ("pod", "data"):
            if ax in mesh.shape:
                shards *= mesh.shape[ax]
        pad = -(-n // shards) * shards

        meta = {}
        arrays = {}
        for c in spec.columns:
            col = table.column(c)
            meta[c] = (col.dbtype, col.heap, col.scale)
            a = np.zeros(pad, dtype=col.data.dtype)
            a[:n] = col.data
            arrays[c] = a
        valid = np.zeros(pad, dtype=bool)
        valid[:n] = True

        step = _cached_query_step(spec, meta, mesh, pad)
        axes = tuple(nm for nm in mesh.axis_names if nm in ("pod", "data"))
        sh = NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))
        dev_valid = jax.device_put(valid, sh)
        dev_cols = [jax.device_put(arrays[c], sh) for c in spec.columns]
        out = np.asarray(step(dev_valid, *dev_cols))   # (G, n_aggs+1)
        self.distributed_hits += 1
        return self._assemble(spec, plan, out, table)

    def _assemble(self, spec: ScanAggSpec, plan: AggregateNode,
                  out: np.ndarray, table):
        from .column import Column
        from .table import Table
        from .types import ColumnSchema, TableSchema
        cnt_star = out[:, -1]
        present = cnt_star > 0 if spec.group_keys else np.ones(1, bool)
        gids = np.nonzero(present)[0]
        cols = {}
        schemas = []
        # reconstruct key values from the mixed-radix gid
        rem = gids.copy()
        radices = [card for _, card in spec.key_domains]
        digits = []
        for off, card in reversed(spec.key_domains):
            digits.append(rem % card)
            rem = rem // card
        digits.reverse()
        for k, (off, card), d in zip(spec.group_keys, spec.key_domains,
                                     digits):
            col = table.column(k)
            if col.dbtype == DBType.VARCHAR:
                vals = d.astype(np.int32)
                cols[k] = Column(DBType.VARCHAR, vals, heap=col.heap)
            else:
                vals = (d + off).astype(col.data.dtype)
                cols[k] = Column(col.dbtype, vals, scale=col.scale)
            schemas.append(ColumnSchema(k, col.dbtype, scale=col.scale))
        for i, a in enumerate(spec.aggs):
            v = out[gids, i]
            if a.fn == "count":
                cols[a.name] = Column(DBType.INT64, v.astype(np.int64))
                schemas.append(ColumnSchema(a.name, DBType.INT64))
            else:
                cols[a.name] = Column(DBType.FLOAT64, v.astype(np.float64))
                schemas.append(ColumnSchema(a.name, DBType.FLOAT64))
        return Table(TableSchema("result", tuple(schemas)), cols)

    # -- host-chunked fallback (Fig. 2 semantics without devices) -------------
    def run_chunked_host(self, spec: ScanAggSpec, n_chunks: int):
        """Reference chunked execution used by tests to validate that
        per-chunk partials + merge == sequential results."""
        db = self.db
        table = db.catalog.table(spec.table)
        n = table.num_rows
        bounds = np.linspace(0, n, n_chunks + 1).astype(int)
        partial_sums = None
        for ci in range(n_chunks):
            s, e = bounds[ci], bounds[ci + 1]
            arrays = {}
            meta = {}
            for c in spec.columns:
                col = table.column(c)
                arrays[c] = np.asarray(col.data)[s:e]
                meta[c] = (col.dbtype, col.heap, col.scale)
            ctx_mask = np.ones(e - s, dtype=bool)
            for conj in spec.conjuncts:
                r = conj.eval(EvalContext(arrays, meta, xp=np))
                m = np.asarray(r.values) != 0
                if r.null is not None:
                    m &= ~np.asarray(r.null)
                ctx_mask &= m
            gid = np.zeros(e - s, dtype=np.int64)
            for k, (off, card) in zip(spec.group_keys, spec.key_domains):
                t, heap, scale = meta[k]
                kv = arrays[k]
                code = kv.astype(np.int64) if t == DBType.VARCHAR \
                    else (kv.astype(np.float64) - off).astype(np.int64)
                code = np.clip(code, 0, card - 1)
                gid = gid * card + code
            chunk = np.zeros((spec.n_groups, 2 * len(spec.aggs) + 1))
            chunk[:, -1] = np.bincount(gid[ctx_mask],
                                       minlength=spec.n_groups)
            for i, a in enumerate(spec.aggs):
                if a.expr is None:
                    chunk[:, 2 * i] = chunk[:, -1]
                    chunk[:, 2 * i + 1] = chunk[:, -1]
                    continue
                r = a.expr.eval(EvalContext(arrays, meta, xp=np))
                ok = ctx_mask & ~_res_nulls(r)
                f = r.as_float(np)
                chunk[:, 2 * i] = np.bincount(
                    gid[ok], weights=f[ok], minlength=spec.n_groups)
                chunk[:, 2 * i + 1] = np.bincount(
                    gid[ok], minlength=spec.n_groups)
            partial_sums = chunk if partial_sums is None \
                else partial_sums + chunk
        return partial_sums
