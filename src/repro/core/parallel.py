"""Chunked parallel / distributed query execution (paper Fig. 2 -> SPMD).

MonetDB parallelizes by splitting the largest table into chunks, running
"parallelizable" MAL operators per chunk, and merging before "blocking"
operators.  The TPU-native restatement (DESIGN.md §3): row-shard the base
columns over the mesh's ``data`` axis with ``shard_map``; the mappable span
(select masks, scalar expressions, partial aggregates) runs per shard; the
merge is a collective (psum / pmin / pmax) — exactly the chunk-merge tree of
Fig. 2 with the merge node lowered to an all-reduce.

Two execution tiers:

* ``DistributedScanAgg`` — the device tier for the hot OLAP pattern
  Aggregate(Filter*(Scan)) with dense group domains: it streams
  morsel-sized column batches through the HBM-budgeted block cache
  (``device_cache.DeviceBufferManager``) and merges per-batch raw partials
  with an order-fixed carry, so the query runs on devices whose memory is
  smaller than the table.  The batch decomposition is *independent of the
  device budget* — unbudgeted, generous and tight budgets all execute the
  identical sequence of jitted batch steps, so results are bit-identical
  across budgets and only the transfer/caching behaviour differs
  (resident: blocks stay cached across queries; streamed: LRU eviction
  recycles them, double-buffered prefetch overlaps the next batch's
  host→device copy with the current batch's compute).
* ``ParallelExecutor`` — Executor subclass that consumes the unified
  physical plan (``physplan.plan_physical``): a scan-agg core annotated
  device-resident/device-streamed runs through ``DistributedScanAgg``, a
  host-side suffix (ORDER BY / LIMIT / projection / HAVING) executes over
  the assembled aggregate, and everything else goes to the (host)
  sequential program.  ``physplan.choose_device_tier`` decides
  streamed-device vs resident-device vs host-spill from the byte
  estimates, biased by the device cache's hit history.

``build_query_step``/``make_fragment`` (the single-shot whole-table
fragment) remain for the multi-pod dry-run, which lowers the engine on the
production mesh.

Chunking heuristics follow the paper: the shard count comes from the mesh
("cores"), and small tables are not split at all (`MIN_ROWS_TO_SHARD`).
"""

from __future__ import annotations

import threading
from typing import Optional

import numpy as np

import jax

# Analytical correctness needs 64-bit aggregation (the paper's engine sums
# DECIMALs exactly).  Enabling x64 only widens the *available* dtypes; all
# model-side code in this repo is dtype-explicit, so LM HLO is unaffected.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

import math

from .device_cache import (DeviceBlockKeys, DeviceBudgetError,
                           DeviceBufferManager)
from .executor import Executor, _res_nulls, compile_plan
from .expression import EvalContext, Expr, ExprResult
from .physplan import (AGG_RESULT_NAME, DeviceBuild, JoinAggSpec,
                       PhysicalPlan, ScanAggSpec,
                       TIER_DEVICE_RESIDENT, choose_device_join_tier,
                       choose_device_tier, join_agg_geometry,
                       match_scan_agg,  # noqa: F401  (re-exported for tests)
                       mesh_shards, partial_layout, scan_agg_geometry)
from .relalg import PlanNode
from .types import DBType, NULL_SENTINEL

# The scan-agg pattern matcher, the partial-matrix layout, the batch
# geometry and the tier-placement policy all live in physplan.py (the
# unified physical planner); this module executes what the planner
# decided.  ``match_scan_agg`` / ``ScanAggSpec`` / ``partial_layout`` are
# re-exported above for existing importers.


# ---------------------------------------------------------------------------
# the shard_map fragment
# ---------------------------------------------------------------------------


def _eval_jnp(expr: Expr, arrays: dict, meta: dict) -> ExprResult:
    ctx = EvalContext(arrays, meta, xp=jnp)
    return expr.eval(ctx)


def _fragment_mask_gid(spec: ScanAggSpec, meta: dict, valid, arrays):
    """Shared SPMD prologue: the filter mask and the dense mixed-radix gid.
    One definition serves both the single-shot fragment and the batched
    raw-partial fragment — any fix to NULL masking or domain decoding
    lands in both, preserving their bit-identity."""
    mask = valid
    for conj in spec.conjuncts:
        r = _eval_jnp(conj, arrays, meta)
        m = r.values != 0
        if r.null is not None:
            m = m & ~r.null
        mask = mask & m
    if spec.group_keys:
        gid = jnp.zeros(valid.shape, dtype=jnp.int32)
        for k, (off, card) in zip(spec.group_keys, spec.key_domains):
            t, heap, scale = meta[k]
            kv = arrays[k]
            code = (kv.astype(jnp.float64) - off).astype(jnp.int32) \
                if t not in (DBType.VARCHAR,) else kv.astype(jnp.int32)
            code = jnp.clip(code, 0, card - 1)
            gid = gid * card + code
    else:
        gid = jnp.zeros(valid.shape, dtype=jnp.int32)
    return mask, gid


def _fragment_partials(spec: ScanAggSpec, meta: dict, mask, gid, arrays,
                       data_axis):
    """Shared SPMD core: evaluate every aggregate expression once, stack
    the sum-like columns in ``partial_layout`` order into ONE segment_sum
    + ONE psum (paper Fig. 2 per-chunk work, MAL-fused), and merge each
    min/max via its own segment+collective.  Returns (seg, extras) —
    mergeable raw partials, not yet finalized."""
    layout = partial_layout(spec)
    sum_cols = [mask.astype(jnp.float64)]            # cnt_star
    evals = {}
    for i, a in enumerate(spec.aggs):
        if a.expr is None:
            continue
        r = _eval_jnp(a.expr, arrays, meta)
        ok = mask if r.null is None else (mask & ~r.null)
        f = r.as_float(jnp)
        evals[i] = (ok, f)
        sum_cols.append(ok.astype(jnp.float64))      # per-agg count
        if a.fn in ("sum", "avg"):
            sum_cols.append(jnp.where(ok, f, 0.0))
    stacked = jnp.stack(sum_cols, axis=1)            # (rows, n_sum)
    seg = jax.ops.segment_sum(stacked, gid, num_segments=spec.n_groups)
    seg = jax.lax.psum(seg, data_axis)               # one collective
    big = jnp.float64(np.inf)
    extras = {}
    for i, fn, _cnt, out_col in layout.minmax:
        ok, f = evals[i]
        if fn == "min":
            v = jnp.where(ok, f, big)
            s = jax.lax.pmin(jax.ops.segment_min(
                v, gid, num_segments=spec.n_groups), data_axis)
        else:
            v = jnp.where(ok, f, -big)
            s = jax.lax.pmax(jax.ops.segment_max(
                v, gid, num_segments=spec.n_groups), data_axis)
        extras[out_col] = s
    return seg, extras


def _join_edge_mask(arrays, meta: dict, mask, edge_cols, domains, btabs):
    """Shared probe-side join gating: for each equi-join edge, exclude rows
    whose local key is NULL, outside the build's dense domain, or absent
    from the build table (presence lane 0 == 0).  The domain comparison
    runs in float64 *before* the int32 narrowing — an out-of-domain key
    must never alias a clipped in-domain code."""
    for cname, (off, card), btab in zip(edge_cols, domains, btabs):
        kv = arrays[cname]
        sent = NULL_SENTINEL[meta[cname][0]]
        codef = kv.astype(jnp.float64) - off
        ok = (kv != sent) & (codef >= 0) & (codef < card)
        code = jnp.clip(codef, 0, card - 1).astype(jnp.int32)
        mask = mask & ok & (btab[code, 0] > 0)
    return mask


def make_fragment(spec: ScanAggSpec, meta: dict, data_axis: str = "data"):
    """Build the per-shard SPMD function (traced under shard_map).

    arrays: {col: (rows_local,)} storage-repr jnp arrays; ``valid``:
    (rows_local,) bool marking real (non-padding) rows.  Returns
    (n_groups, n_aggs+1) merged + finalized results: per agg, the ratio /
    NULL masking already applied (single-shot whole-input execution).
    """
    layout = partial_layout(spec)

    def fragment(valid, **arrays):
        mask, gid = _fragment_mask_gid(spec, meta, valid, arrays)
        seg, extras = _fragment_partials(spec, meta, mask, gid, arrays,
                                         data_axis)
        cnt_star = seg[:, 0]
        outs = {}
        for i, kind, cnt_idx, val_idx in layout.plans:
            if kind == "count_star":
                outs[i] = cnt_star
            elif kind == "count":
                outs[i] = seg[:, cnt_idx]
            else:
                cnt = seg[:, cnt_idx]
                v = seg[:, val_idx]
                outs[i] = jnp.where(
                    cnt > 0,
                    v if kind == "sum" else v / jnp.maximum(cnt, 1.0),
                    jnp.nan)
        for i, _fn, cnt_idx, out_col in layout.minmax:
            outs[i] = jnp.where(seg[:, cnt_idx] > 0, extras[out_col],
                                jnp.nan)
        cols = [outs[i] for i in range(len(spec.aggs))] + [cnt_star]
        return jnp.stack(cols, axis=1)          # (n_groups, n_aggs+1)

    return fragment


def _shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map across jax versions: top-level ``jax.shard_map`` with
    ``check_vma`` on newer releases, ``jax.experimental.shard_map`` with
    ``check_rep`` on older ones."""
    try:
        from jax import shard_map as sm              # newer jax
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    except TypeError:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def build_query_step(spec: ScanAggSpec, meta: dict, mesh: Mesh,
                     data_axis: str = "data"):
    """jit(shard_map(fragment)) with row-sharded inputs; also used by the
    multi-pod dry-run to lower the engine on the production mesh."""
    axes = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
    rowspec = P(axes if len(axes) > 1 else axes[0])

    def merged_axis_fragment(valid, **arrays):
        frag = make_fragment(spec, meta, data_axis=axes)
        return frag(valid, **arrays)

    in_specs = (rowspec,) + tuple(rowspec for _ in spec.columns)
    f = _shard_map_compat(
        lambda valid, *cols: merged_axis_fragment(
            valid, **dict(zip(spec.columns, cols))),
        mesh=mesh, in_specs=in_specs, out_specs=P())
    return jax.jit(f)


_STEP_CACHE: dict = {}
# concurrent queries may race to build the same jitted step; the lock makes
# the check-then-build atomic so one trace is built and shared (tracing the
# same fragment twice is wasted work, and a dict insert during another
# thread's insert is not guaranteed safe across interpreters)
_STEP_CACHE_LOCK = threading.Lock()

# XLA's cross-device collectives rendezvous by (run_id, device set): two
# threads dispatching collective programs at once interleave their
# participants into each other's rendezvous and deadlock (observed on the
# forced-multi-device CPU backend; real accelerators serialize launches on
# a stream anyway).  ONE in-process device dispatch at a time — host-tier
# queries are unaffected and still run concurrently.
_DEVICE_DISPATCH_LOCK = threading.Lock()


def _meta_key(columns, meta: dict) -> tuple:
    """The trace-relevant identity of each referenced column: dtype, scale
    and — for VARCHAR — the heap content fingerprint.  String literal
    codes and heap bounds are baked into jitted traces at Python time
    (expression.py), and an append that introduces a novel string
    re-sorts/renumbers the whole heap, so a step compiled against the old
    heap must not be reused."""
    out = []
    for c in columns:
        t, heap, scale = meta[c]
        out.append((c, t, scale,
                    heap.fingerprint() if heap is not None else None))
    return tuple(out)


def _cached_query_step(spec: ScanAggSpec, meta: dict, mesh: Mesh, pad: int):
    """Compiled-fragment cache: repeated queries (the hot-run benchmark
    protocol, dashboards) reuse the jitted shard_map step instead of
    re-tracing per call."""
    key = (spec.table, repr(spec.conjuncts), tuple(spec.group_keys),
           tuple(spec.key_domains),     # baked into the trace as constants
           tuple((a.fn, repr(a.expr)) for a in spec.aggs),
           _meta_key(spec.columns, meta), spec.n_groups, pad,
           id(mesh.devices.flat[0]),
           tuple(mesh.shape.items()))
    with _STEP_CACHE_LOCK:
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = build_query_step(spec, meta, mesh)
        return _STEP_CACHE[key]


# ---------------------------------------------------------------------------
# batched device-tier execution: raw partials + order-fixed carry
# (PartialLayout / partial_layout live in physplan.py — the layout of the
# partial matrix is physical-plan metadata the geometry estimates need)
# ---------------------------------------------------------------------------


def make_partial_fragment(spec: ScanAggSpec, meta: dict,
                          data_axis="data"):
    """Per-shard SPMD function returning *mergeable* raw partials
    (n_groups, K) in ``partial_layout`` order — the streaming analogue of
    ``make_fragment``: the identical shared prologue/core, minus the
    finalization (which ``finalize_partials`` applies once after the
    carry has merged every batch)."""
    layout = partial_layout(spec)

    def fragment(valid, **arrays):
        mask, gid = _fragment_mask_gid(spec, meta, valid, arrays)
        seg, extras = _fragment_partials(spec, meta, mask, gid, arrays,
                                         data_axis)
        if not extras:
            return seg
        cols = [extras[c][:, None] for c in sorted(extras)]
        return jnp.concatenate([seg] + cols, axis=1)

    return fragment


def finalize_partials(spec: ScanAggSpec, partial: np.ndarray) -> np.ndarray:
    """Merged raw partials -> the (n_groups, n_aggs + 1) matrix
    ``_assemble`` consumes (same formulas the single-shot fragment applies
    on device: avg ratios, NULL where a group saw no valid rows)."""
    layout = partial_layout(spec)
    cnt_star = partial[:, 0]
    outs = {}
    for i, kind, cnt_col, val_col in layout.plans:
        if kind == "count_star":
            outs[i] = cnt_star
        elif kind == "count":
            outs[i] = partial[:, cnt_col]
        else:
            cnt = partial[:, cnt_col]
            v = partial[:, val_col]
            outs[i] = np.where(
                cnt > 0,
                v if kind == "sum" else v / np.maximum(cnt, 1.0),
                np.nan)
    for i, _fn, cnt_col, out_col in layout.minmax:
        outs[i] = np.where(partial[:, cnt_col] > 0, partial[:, out_col],
                           np.nan)
    cols = [outs[i] for i in range(len(spec.aggs))] + [cnt_star]
    return np.stack(cols, axis=1)


def _mesh_axes(mesh: Mesh):
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def _gather_expand(gather, inv, valid, cols):
    """Reconstruct a shard's full batch rows from its gathered (compact)
    blocks.  ``inv`` maps each of the shard's ``L`` skip-slots to its
    position among the ``q`` uploaded candidate slots (-1 = not uploaded).
    Filler rows get ``valid = False``, which is exactly the state the full
    upload's rows would reach after masking: zone-map soundness guarantees
    a non-candidate slot's rows all fail some conjunct, and a masked row
    contributes the combine identity (+0.0 / +inf / -inf) no matter what
    its column values are — so the gathered and full paths produce
    bit-identical partials."""
    q, ublock, n_slots = gather

    def expand(comp, fill):
        cb = comp.reshape(q, ublock)
        idx = jnp.clip(inv, 0, q - 1)
        rows = jnp.where((inv >= 0)[:, None], cb[idx],
                         jnp.asarray(fill, dtype=comp.dtype))
        return rows.reshape(n_slots * ublock)

    return expand(valid, False), [expand(c, 0) for c in cols]


def build_batch_step(spec: ScanAggSpec, meta: dict, mesh: Mesh,
                     gather=None):
    """(init_fn, step_fn): ``step(carry, valid, *cols) -> carry'`` — one
    jitted fused unit per batch: the shard_map partial fragment plus the
    carry combine (add / min / max per column).  The carry is replicated
    over the mesh; ``init_fn`` materializes the combine identity on device
    (no host→device transfer beyond the compiled constant).  With
    ``gather`` (intra-batch skipping) the step instead takes
    ``step(carry, inv, valid_compact, *cols_compact)`` and reconstructs
    the full batch rows on device before the fragment runs."""
    axes = _mesh_axes(mesh)
    rowspec = P(axes if len(axes) > 1 else axes[0])
    layout = partial_layout(spec)
    frag = make_partial_fragment(spec, meta, data_axis=axes)
    if gather is None:
        def shard_fn(valid, *cols):
            return frag(valid, **dict(zip(spec.columns, cols)))
        n_in = 1 + len(spec.columns)
    else:
        def shard_fn(inv, valid, *cols):
            v, full = _gather_expand(gather, inv, valid, cols)
            return frag(v, **dict(zip(spec.columns, full)))
        n_in = 2 + len(spec.columns)
    sm = _shard_map_compat(shard_fn, mesh=mesh,
                           in_specs=(rowspec,) * n_in, out_specs=P())
    kinds = layout.kinds

    def step(carry, *args):
        part = sm(*args)
        return jnp.where(kinds == 0, carry + part,
                         jnp.where(kinds == 1, jnp.minimum(carry, part),
                                   jnp.maximum(carry, part)))

    rep_sh = NamedSharding(mesh, P())
    g, k = spec.n_groups, len(kinds)
    init = jax.jit(lambda: jnp.broadcast_to(
        jnp.asarray(layout.init), (g, k)) + jnp.float64(0.0),
        out_shardings=rep_sh)
    return init, jax.jit(step, out_shardings=rep_sh)


def _cached_batch_step(spec: ScanAggSpec, meta: dict, mesh: Mesh,
                       batch_rows: int, gather=None):
    key = ("batch", spec.table, repr(spec.conjuncts),
           tuple(spec.group_keys),
           tuple(spec.key_domains),     # baked into the trace as constants:
                                        # a shifted key domain (delete/append
                                        # moving min/max at equal cardinality)
                                        # must not reuse the stale step
           tuple((a.fn, repr(a.expr)) for a in spec.aggs),
           _meta_key(spec.columns, meta),
           spec.n_groups, batch_rows, gather,
           id(mesh.devices.flat[0]),
           tuple(mesh.shape.items()))
    with _STEP_CACHE_LOCK:
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = build_batch_step(spec, meta, mesh,
                                                gather=gather)
        return _STEP_CACHE[key]


# ---------------------------------------------------------------------------
# device join tier: radix build / probe / device-resident assembly steps
# ---------------------------------------------------------------------------


def build_join_build_step(build: DeviceBuild, meta: dict, mesh: Mesh,
                          child_domains, gather=None):
    """(init_fn, step_fn) for one join build table:
    ``step(btab, *child_btabs, valid, *cols) -> btab'``.

    One batch of the build table's stream is filtered (its own conjuncts +
    NULL/domain/presence gating against already-built child tables) and
    scatter-added into the (card, 1 + n_payload) build matrix: lane 0
    counts presence (the runtime uniqueness witness — any slot > 1 means
    duplicate build keys and the query falls back to the host join), the
    payload lanes hold the build's group-key columns as float64 (unique
    keys make the add a set; the integer-coded payload types decode
    exactly).  All-add combine: the same carry idiom as the scan-agg tier,
    so dirty-writeback/eviction compose unchanged."""
    axes = _mesh_axes(mesh)
    rowspec = P(axes if len(axes) > 1 else axes[0])
    off, card = build.domain
    width = 1 + len(build.payload)
    n_children = len(build.probe_edges)
    edge_cols = [c for _, c in build.probe_edges]

    def fragment(child_btabs, valid, *cols):
        arrays = dict(zip(build.columns, cols))
        mask = valid
        for conj in build.conjuncts:
            r = _eval_jnp(conj, arrays, meta)
            m = r.values != 0
            if r.null is not None:
                m = m & ~r.null
            mask = mask & m
        kv = arrays[build.key]
        sent = NULL_SENTINEL[meta[build.key][0]]
        codef = kv.astype(jnp.float64) - off
        mask = mask & (kv != sent) & (codef >= 0) & (codef < card)
        code = jnp.clip(codef, 0, card - 1).astype(jnp.int32)
        mask = _join_edge_mask(arrays, meta, mask, edge_cols,
                               child_domains, child_btabs)
        lanes = [mask.astype(jnp.float64)]
        for p in build.payload:
            lanes.append(jnp.where(mask, arrays[p].astype(jnp.float64),
                                   0.0))
        stacked = jnp.stack(lanes, axis=1)
        seg = jax.ops.segment_sum(stacked, code, num_segments=card)
        return jax.lax.psum(seg, axes)

    if gather is None:
        def shard_fn(*args):
            return fragment(args[:n_children], args[n_children],
                            *args[n_children + 1:])
        n_rows_in = 1 + len(build.columns)
    else:
        def shard_fn(*args):
            inv = args[n_children]
            v, full = _gather_expand(gather, inv, args[n_children + 1],
                                     args[n_children + 2:])
            return fragment(args[:n_children], v, *full)
        n_rows_in = 2 + len(build.columns)
    sm = _shard_map_compat(
        shard_fn, mesh=mesh,
        in_specs=(P(),) * n_children + (rowspec,) * n_rows_in,
        out_specs=P())

    def step(btab, *args):
        return btab + sm(*args)

    rep_sh = NamedSharding(mesh, P())
    init = jax.jit(lambda: jnp.zeros((card, width), dtype=jnp.float64)
                   + jnp.float64(0.0), out_shardings=rep_sh)
    return init, jax.jit(step, out_shardings=rep_sh)


def _cached_join_build_step(build: DeviceBuild, meta: dict, mesh: Mesh,
                            batch_rows: int, child_domains, gather=None):
    key = ("jbuild", build.table, repr(build.conjuncts), build.key,
           build.domain, tuple(build.payload), tuple(build.probe_edges),
           tuple(child_domains), _meta_key(build.columns, meta),
           batch_rows, gather, id(mesh.devices.flat[0]),
           tuple(mesh.shape.items()))
    with _STEP_CACHE_LOCK:
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = build_join_build_step(
                build, meta, mesh, child_domains, gather=gather)
        return _STEP_CACHE[key]


def build_join_probe_step(spec: JoinAggSpec, meta: dict, mesh: Mesh,
                          gather=None):
    """(init_fn, step_fn) for the probe (fact) side of a device join:
    ``step(carry, *edge_btabs, valid, *cols) -> carry'``.

    The probe phase IS the scan-agg batch step over the probe table —
    identical prologue, partials and carry combine — plus presence gating
    through every probe-adjacent build matrix.  The gid is the group
    build's key code; rows with NULL / out-of-domain / unmatched keys are
    masked and contribute the combine identity."""
    pspec = spec.probe_spec()
    axes = _mesh_axes(mesh)
    rowspec = P(axes if len(axes) > 1 else axes[0])
    layout = partial_layout(pspec)
    domains = [spec.builds[bi].domain for bi, _ in spec.probe_edges]
    edge_cols = [c for _, c in spec.probe_edges]
    n_children = len(spec.probe_edges)

    def fragment(edge_btabs, valid, *cols):
        arrays = dict(zip(pspec.columns, cols))
        mask, gid = _fragment_mask_gid(pspec, meta, valid, arrays)
        mask = _join_edge_mask(arrays, meta, mask, edge_cols, domains,
                               edge_btabs)
        seg, extras = _fragment_partials(pspec, meta, mask, gid, arrays,
                                         axes)
        if not extras:
            return seg
        ecols = [extras[c][:, None] for c in sorted(extras)]
        return jnp.concatenate([seg] + ecols, axis=1)

    if gather is None:
        def shard_fn(*args):
            return fragment(args[:n_children], args[n_children],
                            *args[n_children + 1:])
        n_rows_in = 1 + len(pspec.columns)
    else:
        def shard_fn(*args):
            inv = args[n_children]
            v, full = _gather_expand(gather, inv, args[n_children + 1],
                                     args[n_children + 2:])
            return fragment(args[:n_children], v, *full)
        n_rows_in = 2 + len(pspec.columns)
    sm = _shard_map_compat(
        shard_fn, mesh=mesh,
        in_specs=(P(),) * n_children + (rowspec,) * n_rows_in,
        out_specs=P())
    kinds = layout.kinds

    def step(carry, *args):
        part = sm(*args)
        return jnp.where(kinds == 0, carry + part,
                         jnp.where(kinds == 1, jnp.minimum(carry, part),
                                   jnp.maximum(carry, part)))

    rep_sh = NamedSharding(mesh, P())
    g, k = pspec.n_groups, len(kinds)
    init = jax.jit(lambda: jnp.broadcast_to(
        jnp.asarray(layout.init), (g, k)) + jnp.float64(0.0),
        out_shardings=rep_sh)
    return init, jax.jit(step, out_shardings=rep_sh)


def _cached_join_probe_step(spec: JoinAggSpec, meta: dict, mesh: Mesh,
                            batch_rows: int, gather=None):
    pspec = spec.probe_spec()
    key = ("jprobe", spec.probe_table, repr(pspec.conjuncts),
           tuple(pspec.group_keys), tuple(pspec.key_domains),
           tuple((a.fn, repr(a.expr)) for a in pspec.aggs),
           tuple(spec.probe_edges),
           tuple(b.domain for b in spec.builds),
           _meta_key(pspec.columns, meta), pspec.n_groups,
           batch_rows, gather, id(mesh.devices.flat[0]),
           tuple(mesh.shape.items()))
    with _STEP_CACHE_LOCK:
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = build_join_probe_step(spec, meta, mesh,
                                                     gather=gather)
        return _STEP_CACHE[key]


def build_scalar_step(kind: str):
    """Tiny jitted reducers dispatched on device-resident state:
    ``"present"`` counts non-empty groups of a carry (the dispatch key for
    the exact-size compaction trace); ``"dupmax"`` is the max presence
    count of a build matrix — the uniqueness verification the device join
    tier's soundness rests on."""
    if kind == "present":
        return jax.jit(lambda m: jnp.sum(m[:, 0] > 0))
    return jax.jit(lambda m: jnp.max(m[:, 0]))


def _cached_scalar_step(kind: str):
    key = ("scalar", kind)
    with _STEP_CACHE_LOCK:
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = build_scalar_step(kind)
        return _STEP_CACHE[key]


def _finalize_rows_jnp(spec: ScanAggSpec, carry):
    """Traced mirror of ``finalize_partials`` — identical formulas, jnp
    ops — used by the device-resident assembly step so huge-group-domain
    partial matrices are finalized and compacted in HBM without ever
    materializing (n_groups, K) on the host."""
    layout = partial_layout(spec)
    cnt_star = carry[:, 0]
    outs = {}
    for i, kind, cnt_col, val_col in layout.plans:
        if kind == "count_star":
            outs[i] = cnt_star
        elif kind == "count":
            outs[i] = carry[:, cnt_col]
        else:
            cnt = carry[:, cnt_col]
            v = carry[:, val_col]
            outs[i] = jnp.where(
                cnt > 0,
                v if kind == "sum" else v / jnp.maximum(cnt, 1.0),
                jnp.nan)
    for i, _fn, cnt_col, out_col in layout.minmax:
        outs[i] = jnp.where(carry[:, cnt_col] > 0, carry[:, out_col],
                            jnp.nan)
    cols = [outs[i] for i in range(len(spec.aggs))] + [cnt_star]
    return jnp.stack(cols, axis=1)


def _device_sort_key(v, dbt, scale: int, desc: bool):
    """Traced mirror of ``executor._sort_key_float`` over a float64 copy
    of an assembled output column — identical arithmetic, so the lexsort
    permutation is identical to the host suffix sort's."""
    v = v.astype(jnp.float64)
    if dbt == DBType.VARCHAR:
        k, nulls = v, v == 0
    elif dbt == DBType.DECIMAL:
        k = v / (10 ** scale)
        nulls = v == NULL_SENTINEL[dbt]
    elif dbt in (DBType.FLOAT64, DBType.FLOAT32):
        k, nulls = v, jnp.isnan(v)
    else:
        k, nulls = v, v == NULL_SENTINEL[dbt]
    return jnp.where(nulls, jnp.inf, -k if desc else k)


def build_assemble_step(spec: ScanAggSpec, n_present: int, sort_cols,
                        limit, n_payload: int):
    """Device-resident assembly: finalize the carry, compact it to the
    ``n_present`` non-empty groups, gather the group build's payload lanes
    and — when an ORDER BY suffix was fused — compute the float sort keys
    and the (top-``limit``) lexsort permutation, all in HBM.  Only the
    compacted (and sorted) arrays are fetched to host.

    ``sort_cols`` is a tuple of ``(source, dbtype, scale, desc)`` where
    ``source`` is ``("digit", i)`` (mixed-radix group-key digit — for the
    join tier the single digit IS the build key code), ``("payload", j)``
    (a build payload lane) or ``("agg", i)``.  Returns
    ``(gids, finalized_rows, payload_rows)``."""
    doms = spec.key_domains

    def assemble(carry, btab=None):
        final = _finalize_rows_jnp(spec, carry)
        if spec.group_keys:
            gids = jnp.nonzero(carry[:, 0] > 0, size=n_present,
                               fill_value=0)[0]
        else:
            gids = jnp.zeros(1, dtype=jnp.int64)
        compact = final[gids]
        pay = btab[gids, 1:] if n_payload else \
            jnp.zeros((gids.shape[0], 0), dtype=jnp.float64)
        if sort_cols:
            rem = gids
            digits = []
            for off, card in reversed(doms):
                digits.append(rem % card)
                rem = rem // card
            digits.reverse()
            fkeys = []
            for (src, dbt, scale, desc) in sort_cols:
                if src[0] == "digit":
                    i = src[1]
                    v = digits[i].astype(jnp.float64)
                    if dbt != DBType.VARCHAR:
                        v = v + doms[i][0]
                elif src[0] == "payload":
                    v = pay[:, src[1]]
                else:
                    v = compact[:, src[1]]
                fkeys.append(_device_sort_key(v, dbt, scale, desc))
            perm = jnp.lexsort(tuple(reversed(fkeys)))
            if limit is not None:
                perm = perm[:limit]
            gids, compact, pay = gids[perm], compact[perm], pay[perm]
        return gids, compact, pay

    return jax.jit(assemble)


def _cached_assemble_step(spec: ScanAggSpec, n_present: int, sort_cols,
                          limit, n_payload: int, mesh: Mesh):
    key = ("assemble", spec.table, tuple(spec.group_keys),
           tuple(spec.key_domains),
           tuple((a.fn, repr(a.expr)) for a in spec.aggs),
           spec.n_groups, n_present, sort_cols, limit, n_payload,
           id(mesh.devices.flat[0]), tuple(mesh.shape.items()))
    with _STEP_CACHE_LOCK:
        if key not in _STEP_CACHE:
            _STEP_CACHE[key] = build_assemble_step(
                spec, n_present, sort_cols, limit, n_payload)
        return _STEP_CACHE[key]


# requires-lock: _DEVICE_DISPATCH_LOCK
def _assemble_on_device(plan: tuple, mesh: Mesh, carry, btab=None):
    """Device-resident assembly dispatch: count the present groups (the
    exact-size key of the compaction trace), run the finalize / compact /
    payload-gather / fused-sort step, fetch only the surviving rows.
    ``plan`` is ``(pspec, sort_cols, limit, n_payload)`` — data, not a
    closure, so the dispatch stays inside the lock-annotated call
    graph."""
    pspec, sort_cols, limit, n_payload = plan
    present_fn = _cached_scalar_step("present")
    n_present = int(present_fn(carry))
    fn = _cached_assemble_step(pspec, n_present, tuple(sort_cols), limit,
                               n_payload, mesh)
    gids, vals, pay = fn(carry) if btab is None else fn(carry, btab)
    return np.asarray(gids), np.asarray(vals), np.asarray(pay)


class _DeviceJoinFallback(Exception):
    """Raised when a runtime precondition of the device join fails
    (duplicate build keys); the executor falls back to the host join."""


class DistributedScanAgg:
    """Streamed device-tier execution of one Aggregate(Filter*(Scan)).

    The table's rows are cut into fixed-size batches (``batch_rows``,
    rounded up to a multiple of the shard count; NOT derived from the
    budget — identical batching across budgets is what makes the budget
    matrix bit-identical).  Each (column, batch) block flows through the
    ``DeviceBufferManager``:

    * resident tier: every block fits the budget at once; after the first
      query all blocks are cache hits and no host→device bytes move;
    * streamed tier: only batches fit; blocks of consumed batches are
      LRU-evicted to make room, and batch N+1's transfers are issued
      (non-blocking ``jax.device_put``) before batch N's compute so copy
      and compute overlap — ``jax`` orders them by data dependency, and
      the final host fetch of the carry is the ``block_until_ready``
      fence.

    The merge carry (a dirty intermediate block) may itself be evicted
    under a tight budget: it is copied back to host and transparently
    re-uploaded — the only writeback case, since base-column blocks are
    clean by definition."""

    def __init__(self, db, spec: ScanAggSpec, mesh: Mesh,
                 batch_rows: Optional[int] = None, skip_set=None):
        self.db = db
        self.spec = spec
        self.mesh = mesh
        self.devman: DeviceBufferManager = getattr(
            db, "device_manager", None) or DeviceBufferManager(
                stats=getattr(db, "buffer_manager", None).stats
                if getattr(db, "buffer_manager", None) else None)
        self.table = db.catalog.table(spec.table)
        self.n_rows = self.table.num_rows
        # transaction snapshots run under a unique key namespace: their
        # tables reuse the version number the next committed write gets,
        # so bare versions would let rolled-back rows alias committed ones
        self._key_ns = getattr(db, "device_key_namespace", 0)
        # delta geometry (base tables report delta_rows == 0): batches that
        # lie fully inside the immutable base are keyed by base_version only
        # and so survive appends; tail-overlapping batches carry the delta
        # epoch and are the only entries an append invalidates
        self.base_rows = self.table.base_rows
        self.delta_rows = self.table.delta_rows
        self.base_version_key = (self._key_ns, "b", self.table.base_version)
        self.delta_version_key = (self._key_ns, "d", self.table.base_version,
                                  self.table.delta_epoch)
        # mesh identity (device ids + axis layout) joins the shard key:
        # blocks are sharded FOR a mesh, and serving a 4-device block to a
        # 2-device step raises inside jit — which the executor would
        # swallow as a host fallback, silently losing the device tier
        self.mesh_key = (tuple(mesh.shape.items()),
                         tuple(d.id for d in mesh.devices.flat))
        # batch decomposition + byte footprint come from the physical
        # planner's shared geometry model — identical numbers whether the
        # tier was chosen through plan_physical or a direct construction
        geom = scan_agg_geometry(spec, self.table, mesh_shards(mesh),
                                 batch_rows)
        self.batch_rows = geom.batch_rows
        self.n_batches = geom.n_batches
        self.row_bytes = geom.row_bytes
        self.carry_nbytes = geom.carry_nbytes
        self.batch_bytes = geom.batch_bytes
        self.resident_bytes = geom.resident_bytes
        # imprint-derived skip-set (physplan.SkipSet): intersected with the
        # batch geometry so non-qualifying batches are never built, never
        # prefetched and never device_put.  Execution-time re-validation:
        # a skip-set derived against another table version (an append or
        # DELETE raced the lowering) is discarded, not half-trusted.
        if skip_set is not None and not skip_set.valid_for(self.table):
            skip_set = None
        self.skip_set = skip_set
        m = self.batch_rows
        self.live_batches = [
            b for b in range(self.n_batches)
            if skip_set is None or skip_set.batch_qualifies(
                b * m, min(self.n_rows, b * m + m))]
        # intra-batch skipping (gather): a *boundary* batch — one the zone
        # maps could not skip whole — usually still contains non-candidate
        # imprint blocks.  Cut each shard's slice into L skip-aligned slots
        # and upload only the candidate slots (padded to q, one gather
        # trace for every gathered batch) plus a tiny (L,)-per-shard int32
        # inverse map; the step reconstructs full rows on device
        # (``_gather_expand``).  Per-batch layout choice: a batch whose
        # every slot qualifies keeps the plain full-batch trace — only
        # batches with actual gaps pay the gather indirection, and only
        # when q < L (the compact upload is strictly smaller).
        self.shards = mesh_shards(mesh)
        self.gather = None
        self._gather_sel: dict = {}
        if skip_set is not None and self.live_batches:
            local = self.batch_rows // self.shards
            ublock = math.gcd(skip_set.block, local)
            L = local // ublock
            if L > 1:
                sels = {}
                maxq = 0
                for b in self.live_batches:
                    s0 = b * self.batch_rows
                    e = min(self.n_rows, s0 + self.batch_rows)
                    per_shard = []
                    batch_max = 0
                    for sdx in range(self.shards):
                        sel = []
                        for slot in range(L):
                            ss = s0 + sdx * local + slot * ublock
                            if ss >= e:       # padding rows: never upload
                                continue
                            if skip_set.batch_qualifies(
                                    ss, min(ss + ublock, e)):
                                sel.append(slot)
                        per_shard.append(tuple(sel))
                        batch_max = max(batch_max, len(sel))
                    if batch_max < L:         # this batch has gaps: gather
                        sels[b] = tuple(per_shard)
                        maxq = max(maxq, batch_max)
                q = 1
                while q < maxq:
                    q *= 2
                if sels and q < L:
                    self.gather = (q, ublock, L)
                    self._gather_sel = sels
        self.meta = {}
        for c in spec.columns:
            col = self.table.column(c)
            self.meta[c] = (col.dbtype, col.heap, col.scale)

    # -- placement decision ---------------------------------------------------
    def choose_tier(self) -> str:
        return choose_device_tier(
            self.resident_bytes, self.batch_bytes, self.devman.budget,
            host_budget=getattr(self.db, "memory_budget", None),
            host_bytes=self.resident_bytes,
            hit_history=self.devman.hit_history(self.spec.table))

    # -- block builders -------------------------------------------------------
    def _builders(self, b: int):
        """Yield (cache key, host-build thunk) for batch ``b``'s blocks:
        the valid mask first, then every referenced column, each padded to
        exactly ``batch_rows`` rows (one trace serves all batches).  The
        shard component of the key is ``(mesh, batch_rows, b)``: a block
        is only reusable by a query slicing the same geometry onto the
        same devices — a different ``device_batch_rows`` cuts different
        row ranges (a bare batch index would serve the wrong rows as a
        cache hit), and a different mesh needs differently-sharded
        placements."""
        spec, table = self.spec, self.table
        m = self.batch_rows
        s = b * m
        e = min(self.n_rows, s + m)
        vkey = self._batch_version_key(b)
        if b in self._gather_sel:
            # gathered (compact) layout: per shard, q candidate slots of
            # ublock rows each, plus the (L,)-per-shard inverse map.  The
            # selection joins the shard key — two queries whose conjuncts
            # pick different candidate slots must not alias blocks.
            q, ublock, L = self.gather
            sel = self._gather_sel[b]
            local = m // self.shards
            shard = (self.mesh_key, m, b, "g", q, sel)

            def slot_span(sdx, slot):
                ss = s + sdx * local + slot * ublock
                return ss, max(0, min(ss + ublock, e) - ss)

            def binv():
                a = np.full(self.shards * L, -1, dtype=np.int32)
                for sdx, ssel in enumerate(sel):
                    for j, slot in enumerate(ssel):
                        a[sdx * L + slot] = j
                return a

            yield (DeviceBlockKeys.column(spec.table, "#ginv", vkey,
                                          shard), binv)

            def bvalid():
                a = np.zeros(self.shards * q * ublock, dtype=bool)
                for sdx, ssel in enumerate(sel):
                    base = sdx * q * ublock
                    for j, slot in enumerate(ssel):
                        _, nv = slot_span(sdx, slot)
                        a[base + j * ublock:base + j * ublock + nv] = True
                return a

            yield DeviceBlockKeys.valid(spec.table, vkey, shard), bvalid
            for c in spec.columns:
                col = table.column(c)

                def bcol(col=col):
                    a = np.zeros(self.shards * q * ublock,
                                 dtype=col.data.dtype)
                    for sdx, ssel in enumerate(sel):
                        base = sdx * q * ublock
                        for j, slot in enumerate(ssel):
                            ss, nv = slot_span(sdx, slot)
                            a[base + j * ublock:base + j * ublock + nv] \
                                = col.data[ss:ss + nv]
                    return a

                yield (DeviceBlockKeys.column(spec.table, c, vkey, shard),
                       bcol)
            return
        shard = (self.mesh_key, m, b)

        def bvalid():
            a = np.zeros(m, dtype=bool)
            a[:e - s] = True
            return a

        yield DeviceBlockKeys.valid(spec.table, vkey, shard), bvalid
        for c in spec.columns:
            col = table.column(c)

            def bcol(col=col):
                a = np.zeros(m, dtype=col.data.dtype)
                a[:e - s] = col.data[s:e]       # memmap: pages one morsel
                return a

            yield (DeviceBlockKeys.column(spec.table, c, vkey, shard),
                   bcol)

    def _batch_version_key(self, b: int):
        """Epoch-keyed caching (delta store): the version component of batch
        ``b``'s block keys.  A batch whose rows lie entirely within the
        immutable base is keyed ``(ns, "b", base_version)`` — stable across
        appends, so a repeat scan after an append re-uploads only the tail.
        A batch overlapping the delta tail is keyed
        ``(ns, "d", base_version, delta_epoch)``; the next append bumps the
        epoch, orphaning exactly those entries (reaped by
        ``DeviceBufferManager.invalidate_delta`` / LRU).  Soundness: a batch
        that ends at the base boundary *before* an append keeps the same
        rows after it (the base is immutable), so serving its "b" entry as a
        hit is correct; a batch that gains rows by an append necessarily
        overlaps the tail and flips to a fresh "d" key — never a stale hit."""
        if self.delta_rows == 0:
            return self.base_version_key
        e = min(self.n_rows, (b + 1) * self.batch_rows)
        if e <= self.base_rows:
            return self.base_version_key
        return self.delta_version_key

    # requires-lock: _DEVICE_DISPATCH_LOCK
    def _issue_prefetch(self, b: int, prefetched: set, query_keys: set,
                        sh) -> None:
        """Start batch ``b``'s host→device copies (non-blocking) so they
        overlap the current batch's compute.  ``put`` recycles the budget
        by evicting *unpinned* (already-consumed) blocks, and the loop
        stops issuing the moment room would require touching a pinned one
        — double-buffering never breaks ``device_bytes_peak <= budget``."""
        for key, build in self._builders(b):
            if key in self.devman or key in prefetched:
                continue       # cached: will be a cache hit at consumption
            try:
                # single-flight even here: two streamed queries walking the
                # same table prefetch the same next batch — one upload,
                # the other attaches (and still takes its own pin)
                self.devman.get_or_put(key, build, sharding=sh, pin=True)
            except DeviceBudgetError:
                return
            prefetched.add(key)
            query_keys.add(key)

    def _account_skipping(self) -> None:
        """Bump what the zone maps saved: every block of every whole
        skipped batch would have been padded to batch_rows and uploaded.
        A skipped batch contributes exactly the carry-combine identity
        (+0 / +inf / -inf): not running its step leaves the carry
        bit-identical to running it."""
        live = self.live_batches
        if len(live) >= self.n_batches:
            return
        blk = self.skip_set.block
        live_set = set(live)
        skipped_blocks = 0
        for b in range(self.n_batches):
            if b in live_set:
                continue
            s = b * self.batch_rows
            e = min(self.n_rows, s + self.batch_rows)
            skipped_blocks += -(-(e - s) // blk)
        self.devman.bump(
            blocks_skipped=skipped_blocks,
            bytes_skipped_h2d=(self.n_batches - len(live))
            * self.batch_rows * self.row_bytes)

    # requires-lock: _DEVICE_DISPATCH_LOCK
    def _stream_batches(self, sh, query_keys: set, pinned: set,
                        prefetched: set):
        """Generator driving the live batches through the block cache:
        yields ``(b, arrs, nxt)`` per batch — the batch index (the
        caller picks the gathered or full step trace by membership in
        ``_gather_sel``), the device block handles (pinned), and the NEXT
        live batch index (None on the last batch).  The caller pins its
        own carry state *before* calling ``_issue_prefetch(nxt, ...)``
        (so double-buffering can never evict it), dispatches its step,
        and resumes the generator, which unpins the consumed batch.
        Shared by the scan-agg carry loop and the join tier's
        build/probe streams."""
        devman = self.devman
        self._account_skipping()
        live = self.live_batches
        for i, b in enumerate(live):
            arrs = []
            batch_keys = []
            for key, build in self._builders(b):
                if key in prefetched:
                    prefetched.discard(key)         # pinned at issue
                    arr = devman.peek(key)
                    devman.bump(device_prefetch_hits=1)
                else:
                    # single-flight: a concurrent query needing the
                    # same block attaches to one in-flight upload
                    # instead of issuing its own (shared morsel scans)
                    arr = devman.get_or_put(key, build, sharding=sh,
                                            pin=True)
                pinned.add(key)
                query_keys.add(key)
                batch_keys.append(key)
                arrs.append(arr)
            if b in self._gather_sel:
                # intra-batch savings, counted at consumption: the full
                # upload would have moved L slots per shard, the gathered
                # one moves q — whether the blocks were cache hits or not
                q, ublock, L = self.gather
                devman.bump(bytes_skipped_h2d=(L - q) * ublock
                            * self.shards * self.row_bytes)

            yield b, arrs, (live[i + 1] if i + 1 < len(live) else None)
            for key in batch_keys:
                devman.unpin(key)
                pinned.discard(key)

    # -- execution ------------------------------------------------------------
    def run(self, tier: Optional[str] = None, assemble=None):
        tier = tier or self.choose_tier()
        if tier == "host":
            raise DeviceBudgetError("input does not fit the device tier")
        # serialize the whole batch loop: every step() carries a psum, and
        # concurrent collective dispatch deadlocks the XLA rendezvous (see
        # _DEVICE_DISPATCH_LOCK).  Cross-query sharing still happens — a
        # later query attaches to this one's cached blocks via get_or_put
        with _DEVICE_DISPATCH_LOCK:
            return self._run_locked(tier, assemble=assemble)

    def _run_locked(self, tier: str, assemble=None):  # requires-lock: _DEVICE_DISPATCH_LOCK
        """Merge every live batch into the carry; then either fetch +
        finalize on host (default) or run the device-resident assembly
        described by the ``assemble`` plan tuple (the carry never reaches
        the host as a full (n_groups, K) matrix on that path)."""
        devman = self.devman
        spec = self.spec
        init_fn, step = _cached_batch_step(spec, self.meta, self.mesh,
                                           self.batch_rows)
        step_g = None
        if self.gather is not None:
            _, step_g = _cached_batch_step(spec, self.meta, self.mesh,
                                           self.batch_rows,
                                           gather=self.gather)
        axes = _mesh_axes(self.mesh)
        sh = NamedSharding(self.mesh, P(axes if len(axes) > 1 else axes[0]))
        rep_sh = NamedSharding(self.mesh, P())
        carry_key = DeviceBlockKeys.carry()
        query_keys: set = {carry_key}
        pinned: set = set()
        prefetched: set = set()
        try:
            carry = devman.adopt(carry_key, init_fn(),
                                 nbytes=self.carry_nbytes, dirty=True)
            for b, arrs, nxt in self._stream_batches(
                    sh, query_keys, pinned, prefetched):
                # the carry is unpinned between batches so a tight budget
                # may have evicted it (writeback); re-upload before use
                if carry_key not in devman:
                    host = devman.take_host(carry_key)
                    carry = devman.put(carry_key, host, sharding=rep_sh,
                                       pin=False, dirty=True)
                devman.pin(carry_key)
                if nxt is not None:
                    self._issue_prefetch(nxt, prefetched, query_keys, sh)
                st = step_g if b in self._gather_sel else step
                carry = st(carry, *arrs)                # async dispatch
                devman.unpin(carry_key)
                devman.adopt(carry_key, carry, nbytes=self.carry_nbytes,
                             dirty=True)
            if assemble is not None:
                return _assemble_on_device(assemble, self.mesh, carry)
            out = devman.take_host(carry_key)   # blocks: the final fence
            return finalize_partials(spec, out)
        finally:
            for key in pinned | prefetched:
                devman.unpin(key)
            devman.drop(carry_key)
            if devman.budget is None:
                # zero-config: no silent device-memory growth across
                # queries — cross-query caching is a budgeted feature
                for key in query_keys:
                    devman.drop(key)


class DistributedJoinAgg:
    """Streamed device-tier execution of one Aggregate(inner-join tree).

    Orchestrates per-table ``DistributedScanAgg`` block streams through the
    shared ``DeviceBufferManager``: build matrices are populated bottom-up
    (each build's batches probe the already-built child matrices, so
    semi-join filtering folds into the build itself), verified unique
    (``dupmax`` — a duplicate build key would double-count and falls back
    to the host join), then the probe table streams through the scan-agg
    carry loop with presence gating against every probe-adjacent matrix.
    Assembly is device-resident: the caller's ``assemble`` plan tuple
    drives ``_assemble_on_device`` — finalize/compact/sort happen in HBM
    and only the surviving rows are fetched; the (n_groups, K) carry and
    the (card, 1+P) group-build matrix never materialize on host."""

    def __init__(self, db, spec: JoinAggSpec, mesh: Mesh,
                 batch_rows: Optional[int] = None, skip_sets=None):
        self.db = db
        self.spec = spec
        self.mesh = mesh
        skip_sets = skip_sets or {}
        self.pspec = spec.probe_spec()
        self.probe = DistributedScanAgg(
            db, self.pspec, mesh, batch_rows=batch_rows,
            skip_set=skip_sets.get(spec.probe_table))
        self.devman = self.probe.devman
        # build-side streams: bare column streams (no grouping) — the
        # jitted build step applies the build's own conjuncts; a build
        # skip-set is sound because a masked row scatter-adds zero
        self.builds = [
            DistributedScanAgg(
                db, ScanAggSpec(b.table, [], [], [], [], 1,
                                list(b.columns)),
                mesh, batch_rows=batch_rows,
                skip_set=skip_sets.get(b.table))
            for b in spec.builds]
        geom = join_agg_geometry(spec, db.catalog, mesh_shards(mesh),
                                 batch_rows)
        self.resident_bytes = geom.resident_bytes
        self.working_bytes = geom.working_bytes
        self.delta_rows = self.probe.delta_rows \
            + sum(s.delta_rows for s in self.builds)

    def choose_mode(self) -> str:
        return choose_device_join_tier(
            self.resident_bytes, self.working_bytes, self.devman.budget,
            getattr(self.db, "memory_budget", None))

    def run(self, mode: Optional[str] = None, assemble=None):
        mode = mode or self.choose_mode()
        if mode == "host":
            raise DeviceBudgetError("join does not fit the device tier")
        with _DEVICE_DISPATCH_LOCK:
            return self._run_locked(assemble)

    def _run_locked(self, assemble):  # requires-lock: _DEVICE_DISPATCH_LOCK
        devman = self.devman
        mesh = self.mesh
        axes = _mesh_axes(mesh)
        sh = NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))
        rep_sh = NamedSharding(mesh, P())
        dup = _cached_scalar_step("dupmax")
        query_keys: set = set()
        pinned: set = set()
        prefetched: set = set()
        btab_keys: list = []
        btabs: list = []
        carry_key = DeviceBlockKeys.carry()
        query_keys.add(carry_key)
        try:
            for b, stream in zip(self.spec.builds, self.builds):
                child_idx = [ci for ci, _ in b.probe_edges]
                child_domains = tuple(self.spec.builds[ci].domain
                                      for ci in child_idx)
                init_fn, step = _cached_join_build_step(
                    b, stream.meta, mesh, stream.batch_rows,
                    child_domains)
                step_g = None
                if stream.gather is not None:
                    _, step_g = _cached_join_build_step(
                        b, stream.meta, mesh, stream.batch_rows,
                        child_domains, gather=stream.gather)
                key = DeviceBlockKeys.carry()
                btab_keys.append(key)
                query_keys.add(key)
                children = [btabs[ci] for ci in child_idx]
                # build matrices stay pinned for the whole query: later
                # builds and every probe batch read them (the planner
                # reserved state_bytes for exactly this residency)
                btab = devman.adopt(key, init_fn(), nbytes=b.table_bytes,
                                    dirty=True, pin=True)
                for bb, arrs, nxt in stream._stream_batches(
                        sh, query_keys, pinned, prefetched):
                    if nxt is not None:
                        stream._issue_prefetch(nxt, prefetched,
                                               query_keys, sh)
                    st = step_g if bb in stream._gather_sel else step
                    btab = st(btab, *children, *arrs)
                    devman.adopt(key, btab, nbytes=b.table_bytes,
                                 dirty=True, pin=True)
                # runtime uniqueness witness: the single-key gid is only
                # sound for unique build keys (one code, one group/row)
                if float(dup(btab)) > 1.0:
                    raise _DeviceJoinFallback(
                        f"duplicate join keys in build table {b.table}")
                btabs.append(btab)
            init_fn, pstep = _cached_join_probe_step(
                self.spec, self.probe.meta, mesh, self.probe.batch_rows)
            pstep_g = None
            if self.probe.gather is not None:
                _, pstep_g = _cached_join_probe_step(
                    self.spec, self.probe.meta, mesh,
                    self.probe.batch_rows, gather=self.probe.gather)
            edge_btabs = [btabs[bi] for bi, _ in self.spec.probe_edges]
            carry = devman.adopt(carry_key, init_fn(),
                                 nbytes=self.probe.carry_nbytes,
                                 dirty=True)
            for bb, arrs, nxt in self.probe._stream_batches(
                    sh, query_keys, pinned, prefetched):
                if carry_key not in devman:
                    host = devman.take_host(carry_key)
                    carry = devman.put(carry_key, host, sharding=rep_sh,
                                       pin=False, dirty=True)
                devman.pin(carry_key)
                if nxt is not None:
                    self.probe._issue_prefetch(nxt, prefetched,
                                               query_keys, sh)
                st = pstep_g if bb in self.probe._gather_sel else pstep
                carry = st(carry, *edge_btabs, *arrs)
                devman.unpin(carry_key)
                devman.adopt(carry_key, carry,
                             nbytes=self.probe.carry_nbytes, dirty=True)
            gb = self.spec.group_build
            return _assemble_on_device(
                assemble, mesh, carry,
                btabs[gb] if gb is not None else None)
        finally:
            for key in pinned | prefetched:
                devman.unpin(key)
            for key in btab_keys + [carry_key]:
                devman.unpin(key)
                devman.drop(key)
            if devman.budget is None:
                for key in query_keys:
                    devman.drop(key)


# ---------------------------------------------------------------------------
# executor integration
# ---------------------------------------------------------------------------


class _SuffixDatabase:
    """Minimal database view for suffix execution: one catalog entry — the
    assembled scan-agg core under ``AGG_RESULT_NAME`` — sharing the parent
    database's buffer manager (one budget accounting)."""

    class _Catalog:
        def __init__(self, table):
            self._table = table

        def table(self, name):
            if name != AGG_RESULT_NAME:
                raise KeyError(name)
            return self._table

    def __init__(self, table, buffer_manager):
        self.catalog = self._Catalog(table)
        self.buffer_manager = buffer_manager
        self.index_manager = None


class ParallelExecutor(Executor):
    """Routes qualifying plans to the shard_map tier (paper Fig. 2)."""

    def __init__(self, database, mesh: Optional[Mesh] = None,
                 use_pallas: bool = False):
        super().__init__(database)
        self.mesh = mesh
        self.use_pallas = use_pallas
        self.distributed_hits = 0

    def _default_mesh(self) -> Mesh:
        if self.mesh is None:
            dev = np.array(jax.devices())
            self.mesh = Mesh(dev.reshape(-1), ("data",))
        return self.mesh

    def execute(self, plan: PlanNode, do_optimize: bool = True):
        from .serving import lower_cached
        mesh = self._default_mesh()
        phys, rendered, hit = lower_cached(self.db, plan,
                                           do_optimize=do_optimize,
                                           distributed=True, mesh=mesh)
        self.policy = phys.policy
        self.stats.plan_repr = rendered
        self.stats.plan_cache_hit = hit
        with self._admitted(phys):
            if phys.device_tier():
                result = self._try_distributed(phys)
                if result is not None:
                    return result
                # the planner chose the device tier but runtime lowering
                # failed; the host program is the fallback — re-render so
                # EXPLAIN/stats reflect what actually ran
                phys.demote_device()
                self.stats.plan_repr = phys.render()
            prog = compile_plan(phys.plan, self.db.catalog)
            result = self.run_program(prog)
        self._plan_feedback(plan, True)
        return result

    @staticmethod
    def _stats_window():
        from .executor import (DEVICE_DELTA_FIELDS, INGEST_DELTA_FIELDS,
                               SKIP_DELTA_FIELDS, stats_base)
        fields = DEVICE_DELTA_FIELDS + SKIP_DELTA_FIELDS \
            + INGEST_DELTA_FIELDS
        return fields, stats_base

    def _claim_device(self, tier: str, fields, base, end, dm,
                      device_sorted: bool) -> None:
        # claim the device tier only once the WHOLE query succeeded: a
        # suffix failure falls back to a full host recompute, and
        # device_tier / distributed_hits must describe the result returned
        self.distributed_hits += 1
        self.stats.device_tier = tier
        self.stats.device_sorted = device_sorted
        for f, b, e in zip(fields, base, end):
            setattr(self.stats, f, getattr(self.stats, f) + e - b)
        # lifetime gauge, reported only by queries that ran on the device
        # tier (host-tier queries keep 0 alongside device_tier == "")
        self.stats.device_bytes_peak = dm.device_bytes_peak

    # -- distributed scan-agg -------------------------------------------------
    def _try_distributed(self, phys: PhysicalPlan):
        """Run the physical plan's core through the device tier (the tier
        the planner annotated), then the host-side suffix (ORDER BY /
        LIMIT / projection / HAVING) over the assembled aggregate — unless
        the sort was fused onto the device (``sort_on_device``), in which
        case assembly returns already-ordered rows and the suffix is
        skipped entirely; None means a runtime lowering gap — the caller
        falls back to the host program."""
        if phys.join_agg is not None:
            return self._try_join(phys)
        spec = phys.scan_agg
        table = self.db.catalog.table(spec.table)
        try:
            agg = DistributedScanAgg(
                self.db, spec, self._default_mesh(),
                batch_rows=getattr(self.db, "device_batch_rows", None),
                skip_set=phys.core_skip_set())
        except Exception:
            return None
        tier = "resident" if phys.agg_tier == TIER_DEVICE_RESIDENT \
            else "streamed"
        fields, stats_base = self._stats_window()
        dm = agg.devman.stats
        base = stats_base(dm, fields)
        assemble = None
        if phys.sort_on_device:
            sort_cols = self._sort_cols_scan(spec, table,
                                             phys.sort_node.keys)
            if sort_cols is not None:
                assemble = self._device_assemble(
                    spec, sort_cols, phys.sort_node.limit, 0)
        try:
            out = agg.run(tier, assemble=assemble)
        except Exception:
            return None      # fall back to the host tier on any lowering gap
        if agg.delta_rows:
            # merge-on-read visibility: the scan consumed a delta tail
            agg.devman.bump(delta_rows=agg.delta_rows)
        if assemble is not None:
            gids, vals, _pay = out
            result = self._assemble(spec, vals, table, gids=gids)
        else:
            result = self._assemble(spec, out, table)
        # close the device-counter window BEFORE the suffix runs (its host
        # program threads the same delta fields through run_program)
        end = stats_base(dm, fields)
        if phys.suffix_plan is not None and assemble is None:
            try:
                result = self._run_suffix(phys.suffix_plan, result)
            except Exception:
                return None  # suffix gap: host program recomputes everything
        self._claim_device(tier, fields, base, end, dm,
                           device_sorted=assemble is not None)
        return result

    # -- distributed join-agg -------------------------------------------------
    def _try_join(self, phys: PhysicalPlan):
        """Run the physical plan's join-agg core through the device join
        tier: builds bottom-up, probe stream, device-resident assembly
        (finalize + compact + fused ORDER BY all in HBM)."""
        jspec = phys.join_agg
        tables = [jspec.probe_table] + [b.table for b in jspec.builds]
        try:
            agg = DistributedJoinAgg(
                self.db, jspec, self._default_mesh(),
                batch_rows=getattr(self.db, "device_batch_rows", None),
                skip_sets={t: phys.skip_set_for_table(t) for t in tables})
        except Exception:
            return None
        mode = phys.join_mode or "streamed"
        fields, stats_base = self._stats_window()
        dm = agg.devman.stats
        base = stats_base(dm, fields)
        gb = jspec.group_build
        n_payload = len(jspec.builds[gb].payload) if gb is not None else 0
        sort_cols, limit = (), None
        if phys.sort_on_device:
            sort_cols = self._sort_cols_join(jspec, phys.sort_node.keys)
            if sort_cols is None:
                sort_cols = ()
            else:
                limit = phys.sort_node.limit
        device_sorted = bool(sort_cols)
        assemble = self._device_assemble(agg.pspec, sort_cols, limit,
                                         n_payload)
        try:
            gids, vals, pay = agg.run(mode, assemble=assemble)
        except _DeviceJoinFallback:
            return None     # duplicate build keys: host join is the truth
        except Exception:
            return None     # fall back to the host tier on any lowering gap
        if agg.delta_rows:
            agg.devman.bump(delta_rows=agg.delta_rows)
        result = self._assemble_join(jspec, gids, vals, pay)
        end = stats_base(dm, fields)
        if phys.suffix_plan is not None and not device_sorted:
            try:
                result = self._run_suffix(phys.suffix_plan, result)
            except Exception:
                return None
        self._claim_device("join-" + mode, fields, base, end, dm,
                           device_sorted=device_sorted)
        return result

    # -- device-resident assembly ---------------------------------------------
    def _device_assemble(self, pspec: ScanAggSpec, sort_cols, limit,
                         n_payload: int):
        """Assembly plan handed to the stream's ``run``: plain data (spec,
        sort sources, limit, payload width) that ``_assemble_on_device``
        turns into the finalize/compact/fused-sort dispatch under the
        stream's dispatch lock; only the compacted result rows come to
        host."""
        return (pspec, tuple(sort_cols), limit, n_payload)

    def _sort_cols_scan(self, spec: ScanAggSpec, table, keys):
        """Map ORDER BY keys of a scan-agg core onto assembly sort sources
        (group-key digit or agg column); None when a key is unmappable."""
        cols = []
        agg_names = [a.name for a in spec.aggs]
        for col, desc in keys:
            if col in spec.group_keys:
                c = table.column(col)
                cols.append((("digit", spec.group_keys.index(col)),
                             c.dbtype, c.scale, bool(desc)))
            elif col in agg_names:
                i = agg_names.index(col)
                dbt = DBType.INT64 if spec.aggs[i].fn == "count" \
                    else DBType.FLOAT64
                cols.append((("agg", i), dbt, 0, bool(desc)))
            else:
                return None
        return tuple(cols)

    def _sort_cols_join(self, jspec: JoinAggSpec, keys):
        """Join-core ORDER BY keys: group keys resolve through
        ``group_sources`` — the build key digit or a payload lane of the
        group build's matrix."""
        gb = jspec.builds[jspec.group_build] \
            if jspec.group_build is not None else None
        cols = []
        agg_names = [a.name for a in jspec.aggs]
        for col, desc in keys:
            if col in jspec.group_keys:
                src = jspec.group_sources[jspec.group_keys.index(col)]
                if src[0] == "key":
                    c = self.db.catalog.table(gb.table).column(gb.key)
                    cols.append((("digit", 0), c.dbtype, c.scale,
                                 bool(desc)))
                else:
                    c = self.db.catalog.table(gb.table).column(
                        gb.payload[src[1]])
                    cols.append((("payload", src[1]), c.dbtype, c.scale,
                                 bool(desc)))
            elif col in agg_names:
                i = agg_names.index(col)
                dbt = DBType.INT64 if jspec.aggs[i].fn == "count" \
                    else DBType.FLOAT64
                cols.append((("agg", i), dbt, 0, bool(desc)))
            else:
                return None
        return tuple(cols)

    def _run_suffix(self, suffix_plan: PlanNode, table):
        """Execute the suffix operators over the assembled aggregate: a
        host program against a one-table catalog holding the (tiny) core
        result.  Stats and policy are shared, so suffix sorts/limits that
        spill are counted against this query."""
        sdb = _SuffixDatabase(table, self.bufman)
        sub = Executor(sdb)
        sub.stats = self.stats
        sub.policy = self.policy
        prog = compile_plan(suffix_plan, sdb.catalog)
        return sub.run_program(prog)

    def _assemble(self, spec: ScanAggSpec, out: np.ndarray, table,
                  gids: Optional[np.ndarray] = None):
        from .column import Column
        from .table import Table
        from .types import ColumnSchema, TableSchema
        if gids is None:
            cnt_star = out[:, -1]
            present = cnt_star > 0 if spec.group_keys else np.ones(1, bool)
            gids = np.nonzero(present)[0]
            vals = out[gids]
        else:
            # device-resident assembly already compacted (and ordered)
            # the rows; ``out`` is (n_present, n_aggs + 1)
            vals = out
        cols = {}
        schemas = []
        # reconstruct key values from the mixed-radix gid
        rem = gids.copy()
        radices = [card for _, card in spec.key_domains]
        digits = []
        for off, card in reversed(spec.key_domains):
            digits.append(rem % card)
            rem = rem // card
        digits.reverse()
        for k, (off, card), d in zip(spec.group_keys, spec.key_domains,
                                     digits):
            col = table.column(k)
            if col.dbtype == DBType.VARCHAR:
                kv = d.astype(np.int32)
                cols[k] = Column(DBType.VARCHAR, kv, heap=col.heap)
            else:
                kv = (d + off).astype(col.data.dtype)
                cols[k] = Column(col.dbtype, kv, scale=col.scale)
            schemas.append(ColumnSchema(k, col.dbtype, scale=col.scale))
        for i, a in enumerate(spec.aggs):
            v = vals[:, i]
            if a.fn == "count":
                cols[a.name] = Column(DBType.INT64, v.astype(np.int64))
                schemas.append(ColumnSchema(a.name, DBType.INT64))
            else:
                cols[a.name] = Column(DBType.FLOAT64, v.astype(np.float64))
                schemas.append(ColumnSchema(a.name, DBType.FLOAT64))
        return Table(TableSchema("result", tuple(schemas)), cols)

    def _assemble_join(self, jspec: JoinAggSpec, gids: np.ndarray,
                       vals: np.ndarray, pay: np.ndarray):
        """Build the core result table of a device join from the
        device-assembled triple: group keys resolve through
        ``group_sources`` (build key code / payload lane), aggregates from
        the finalized rows — column order matches the host program's
        aggregate output (keys, then aggs)."""
        from .column import Column
        from .table import Table
        from .types import ColumnSchema, TableSchema
        catalog = self.db.catalog
        gb = jspec.builds[jspec.group_build] \
            if jspec.group_build is not None else None
        cols = {}
        schemas = []
        for k, src in zip(jspec.group_keys, jspec.group_sources):
            if src[0] == "key":
                col = catalog.table(gb.table).column(gb.key)
                v = (gids.astype(np.float64) + jspec.key_domain[0]) \
                    .astype(col.data.dtype)
            else:
                col = catalog.table(gb.table).column(gb.payload[src[1]])
                v = pay[:, src[1]].astype(col.data.dtype)
            if col.dbtype == DBType.VARCHAR:
                cols[k] = Column(DBType.VARCHAR, v, heap=col.heap)
            else:
                cols[k] = Column(col.dbtype, v, scale=col.scale)
            schemas.append(ColumnSchema(k, col.dbtype, scale=col.scale))
        for i, a in enumerate(jspec.aggs):
            v = vals[:, i]
            if a.fn == "count":
                cols[a.name] = Column(DBType.INT64, v.astype(np.int64))
                schemas.append(ColumnSchema(a.name, DBType.INT64))
            else:
                cols[a.name] = Column(DBType.FLOAT64,
                                      v.astype(np.float64))
                schemas.append(ColumnSchema(a.name, DBType.FLOAT64))
        return Table(TableSchema("result", tuple(schemas)), cols)

    # -- host-chunked fallback (Fig. 2 semantics without devices) -------------
    def run_chunked_host(self, spec: ScanAggSpec, n_chunks: int):
        """Reference chunked execution used by tests to validate that
        per-chunk partials + merge == sequential results."""
        db = self.db
        table = db.catalog.table(spec.table)
        n = table.num_rows
        bounds = np.linspace(0, n, n_chunks + 1).astype(int)
        partial_sums = None
        for ci in range(n_chunks):
            s, e = bounds[ci], bounds[ci + 1]
            arrays = {}
            meta = {}
            for c in spec.columns:
                col = table.column(c)
                arrays[c] = np.asarray(col.data)[s:e]
                meta[c] = (col.dbtype, col.heap, col.scale)
            ctx_mask = np.ones(e - s, dtype=bool)
            for conj in spec.conjuncts:
                r = conj.eval(EvalContext(arrays, meta, xp=np))
                m = np.asarray(r.values) != 0
                if r.null is not None:
                    m &= ~np.asarray(r.null)
                ctx_mask &= m
            gid = np.zeros(e - s, dtype=np.int64)
            for k, (off, card) in zip(spec.group_keys, spec.key_domains):
                t, heap, scale = meta[k]
                kv = arrays[k]
                code = kv.astype(np.int64) if t == DBType.VARCHAR \
                    else (kv.astype(np.float64) - off).astype(np.int64)
                code = np.clip(code, 0, card - 1)
                gid = gid * card + code
            chunk = np.zeros((spec.n_groups, 2 * len(spec.aggs) + 1))
            chunk[:, -1] = np.bincount(gid[ctx_mask],
                                       minlength=spec.n_groups)
            for i, a in enumerate(spec.aggs):
                if a.expr is None:
                    chunk[:, 2 * i] = chunk[:, -1]
                    chunk[:, 2 * i + 1] = chunk[:, -1]
                    continue
                r = a.expr.eval(EvalContext(arrays, meta, xp=np))
                ok = ctx_mask & ~_res_nulls(r)
                f = r.as_float(np)
                chunk[:, 2 * i] = np.bincount(
                    gid[ok], weights=f[ok], minlength=spec.n_groups)
                chunk[:, 2 * i + 1] = np.bincount(
                    gid[ok], minlength=spec.n_groups)
            partial_sums = chunk if partial_sums is None \
                else partial_sums + chunk
        return partial_sums
