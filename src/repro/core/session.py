"""Embedding interface (paper §3.2): startup / connect / query / append.

The API mirrors MonetDBLite's C API one-to-one:

    db  = startup(path_or_None)        # monetdb_startup
    con = db.connect()                 # monetdb_connect  (dummy client ctx)
    res = con.query("SELECT ...")      # monetdb_query -> Result
    col = res.fetch(0)                 # monetdb_result_fetch (low/high level)
    con.append("tbl", {...})           # monetdb_append (bulk, no INSERT parse)
    db.shutdown()                      # in-process shutdown, state released

Deliberate fixes of the paper's own known limitations (§5.1), enabled by
explicit state instead of C globals: multiple databases per process, and
multiple in-process handles per database directory.
"""

from __future__ import annotations

import itertools
import os
import threading
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .executor import Executor
from .indexes import IndexManager
from .relalg import PlanNode, Query, ScanNode
from .storage import Storage
from .table import Table
from .transactions import Transaction, TransactionManager
from .types import DBType

_open_dirs: dict[str, "Database"] = {}
_open_lock = threading.Lock()

# device-cache key namespaces for transaction snapshots (0 = committed
# catalog; see Connection.query)
_snapshot_ns = itertools.count(1)


class DatabaseError(RuntimeError):
    pass


@dataclass
class Catalog:
    tables: dict[str, Table] = field(default_factory=dict)

    def table(self, name: str) -> Table:
        if name not in self.tables:
            raise DatabaseError(f"no such table: {name!r}")
        return self.tables[name]

    def __contains__(self, name):
        return name in self.tables


class Database:
    """One embedded database instance (explicit state — no process globals).

    ``memory_budget`` (bytes) bounds the tracked working state of blocking
    query operators; queries whose intermediates exceed it spill to
    partitioned run files (out-of-core execution — the standard-RDBMS
    feature the paper contrasts against in-memory analytics tools).
    ``device_budget`` (bytes) is the same contract one tier up: it bounds
    device-resident (HBM) column blocks for distributed execution —
    over-budget inputs stream morsel batches through the device cache
    (``core.device_cache``) instead of requiring residency.  The default
    ``None`` means unlimited: zero configuration, no spilling/eviction."""

    def __init__(self, path: Optional[str] = None,
                 memory_budget: Optional[int] = None,
                 spill_codec: str = "for", spill_prefetch: bool = True,
                 device_budget: Optional[int] = None,
                 device_batch_rows: Optional[int] = None,
                 data_skipping: bool = True,
                 delta_compact_fraction: float = 0.5):
        from .buffers import BufferManager
        from .device_cache import DeviceBufferManager
        self.path = path
        self.memory_budget = memory_budget
        self.spill_codec = spill_codec
        self.spill_prefetch = spill_prefetch
        self.device_budget = device_budget
        self.device_batch_rows = device_batch_rows
        # delta-store compaction threshold: fold a table's delta tail into a
        # new base once it exceeds this fraction of memory_budget bytes (or,
        # unbudgeted, this fraction of the base rows).  0/None disables
        # automatic compaction.
        self.delta_compact_fraction = delta_compact_fraction
        # imprint-driven data skipping (paper §3.1): when True the planner
        # attaches zone-map skip-sets to scans and every tier prunes
        # non-qualifying blocks; False forces full scans (the differential
        # harness's control arm).  Results are bit-identical either way.
        self.data_skipping = data_skipping
        self.catalog = Catalog()
        self.txn_manager = TransactionManager()
        self.index_manager = IndexManager(self)
        self.storage: Optional[Storage] = None
        self._shutdown = False
        # per-thread last_stats view: one mutable attribute would be
        # clobbered by concurrent queries (thread A reads thread B's stats)
        self._stats_local = threading.local()
        if path is not None:
            self.storage = Storage(path)
            try:
                self.storage.acquire_lock()    # on-disk, cross-process
            except RuntimeError as e:
                raise DatabaseError(str(e)) from None
        try:
            if self.storage is not None:
                if self.storage.has_catalog():
                    self.catalog.tables = self.storage.load()
                # crash recovery: a previous process that died mid-query
                # may have left run files behind; the lock just acquired
                # proves no live owner exists, so the spill dir is stale.
                self.storage.reclaim_spill()
            # spill files live under the database directory in persistent
            # mode (paper §3.2: everything the instance owns is under one
            # dir), else a private temp dir; created lazily on first spill.
            self.buffer_manager = BufferManager(
                memory_budget,
                spill_dir=self.storage.spill_path()
                if self.storage is not None else None,
                codec=spill_codec, prefetch=spill_prefetch)
            # HBM tier: device blocks share the host tier's stats object so
            # one BufferStats reports both tiers (jax loads lazily on use)
            self.device_manager = DeviceBufferManager(
                device_budget, stats=self.buffer_manager.stats)
            # serving layer: plan cache + admission gate (core.serving).
            # The gate reserves each plan's summed per-operator budget
            # estimates before execution; the cache skips lowering on hot
            # repeated queries and is invalidated by append/DROP/DELETE.
            from .serving import AdmissionGate, PlanCache
            self.plan_cache = PlanCache()
            self.admission_gate = AdmissionGate(memory_budget,
                                                device_budget)
        except BaseException:
            # a failed open must not leave the directory locked forever
            if self.storage is not None:
                self.storage.release_lock()
            raise

    # ---- embedding API ------------------------------------------------------
    def connect(self) -> "Connection":
        self._check_alive()
        return Connection(self)

    def shutdown(self) -> None:
        """In-process shutdown: persist, then free all state (the paper's
        'garbage collection' challenge — everything must be reclaimable
        without process exit)."""
        if self._shutdown:
            return
        if self.storage is not None:
            self.storage.write_catalog(self.catalog.tables)
        self.catalog.tables.clear()
        self.index_manager.imprints.clear()
        self.index_manager.order_indexes.clear()
        self.buffer_manager.cleanup()
        self.device_manager.cleanup()
        self.plan_cache.clear()
        if self.storage is not None:
            self.storage.release_lock()
        self._shutdown = True
        if self.path is not None:
            with _open_lock:
                _open_dirs.pop(os.path.abspath(self.path), None)

    # ``with startup(path) as db:`` — shutdown (persist + lock release) is
    # guaranteed on scope exit, including on exceptions
    def __enter__(self) -> "Database":
        self._check_alive()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.shutdown()
        return False

    def checkpoint(self) -> None:
        """Fold the WAL into fresh column files (durability compaction)."""
        self._check_alive()
        if self.storage is not None:
            self.storage.write_catalog(self.catalog.tables)

    # ---- data definition ----------------------------------------------------
    def create_table(self, name: str, data, types=None, scales=None) -> Table:
        self._check_alive()
        t = data if isinstance(data, Table) else Table.from_dict(
            name, data, types, scales)
        if isinstance(data, Table) and data.name != name:
            t = data.rename(name)
        txn = self.txn_manager.begin(self)
        txn.create_table(t)
        txn.commit()
        return t

    def drop_table(self, name: str) -> None:
        self._check_alive()
        txn = self.txn_manager.begin(self)
        txn.drop_table(name)
        txn.commit()
        # a future table reusing this name is a different table: forget
        # the admission hit history along with the blocks
        self.device_manager.invalidate_table(name, drop_history=True)
        self.plan_cache.invalidate_table(name)

    def append(self, name: str, data, types=None, scales=None) -> None:
        """Bulk append (monetdb_append): no per-row INSERT parsing."""
        self._check_alive()
        base = self.catalog.table(name)
        chunk = data if isinstance(data, Table) else Table.from_dict(
            name, data,
            types or {c.name: c.dbtype for c in base.schema.columns},
            scales or {c.name: c.scale for c in base.schema.columns})
        txn = self.txn_manager.begin(self)
        txn.append(name, chunk)
        txn.commit()
        self._post_append(name)

    def _post_append(self, name: str) -> None:
        """Epoch-keyed cache invalidation after a committed append.

        A delta append leaves the base blocks byte-identical, so only the
        delta-tail device blocks (keyed on the old epoch) die — repeat scans
        re-upload the tail's bytes, not the table.  A rebase (VARCHAR heap
        re-sort) or a compaction changed the physical layout, so everything
        for the table is retired; version-carrying keys already keep either
        path correct — invalidation only frees dead blocks from the budget.
        The plan cache's keys carry (version, base_version, delta_epoch), so
        stale entries are unreachable and age out of the LRU on their own."""
        new = self.catalog.tables.get(name)
        if new is not None and new.delta_rows:
            self.device_manager.invalidate_delta(name)
        else:
            self.device_manager.invalidate_table(name)
            self.plan_cache.invalidate_table(name)

    def _maybe_compact(self, name: str) -> None:
        """Transaction-manager hook, called under the commit lock after an
        append install: fold an over-threshold delta tail into a plain base.
        The fold is content- and version-identical, so no validation window
        opens; with persistent storage the checkpoint folds the WAL and the
        existing GC sweeps the superseded column-version files."""
        from .delta import compact, should_compact
        t = self.catalog.tables.get(name)
        if not should_compact(t, self.delta_compact_fraction,
                              self.memory_budget):
            return
        new = compact(t, storage=self.storage, bufman=self.buffer_manager)
        self.catalog.tables[name] = new
        self.buffer_manager.bump(compactions=1)
        # same version, different physical layout: retire old base/tail
        # device blocks and cached plans for the table
        self.device_manager.invalidate_table(name)
        self.plan_cache.invalidate_table(name)
        if self.storage is not None:
            self.storage.write_catalog(self.catalog.tables)

    def ingest(self, name: str, source, types=None, scales=None) -> int:
        """Chunked bulk ingest: stream ``source`` — an iterable of
        ``{col: values}`` dicts or ``Table`` chunks — into ``name`` as delta
        appends.

        Each incoming chunk is re-chunked into budget-sized pieces
        (``choose_morsel_rows``) and pinned through ``BufferManager``
        accounting while its commit is in flight, so a table far larger
        than ``memory_budget`` loads with tracked ``peak <= budget``;
        threshold compaction (``delta_compact_fraction``) periodically folds
        the growing tail to disk in persistent mode.  The table is created
        from the first chunk's schema when absent.  Returns rows ingested."""
        from .buffers import choose_morsel_rows
        self._check_alive()
        total = 0
        for data in source:
            if name in self.catalog:
                base = self.catalog.table(name)
                chunk = data if isinstance(data, Table) else Table.from_dict(
                    name, data,
                    types or {c.name: c.dbtype for c in base.schema.columns},
                    scales or {c.name: c.scale for c in base.schema.columns})
            else:
                chunk = data if isinstance(data, Table) else Table.from_dict(
                    name, data, types, scales)
                # seed a zero-row base carrying the first chunk's schema and
                # heaps: subsequent pieces whose strings are covered by those
                # heaps append as O(delta) deltas instead of rebasing
                self.create_table(name, chunk.slice_rows(0, 0))
            row_bytes = max(1, sum(c.data.dtype.itemsize
                                   for c in chunk.columns.values()))
            rows = choose_morsel_rows(row_bytes, self.memory_budget)
            n = chunk.num_rows
            for s in range(0, n, rows):
                piece = chunk.slice_rows(s, min(s + rows, n))
                with self.buffer_manager.pinned(piece.nbytes):
                    txn = self.txn_manager.begin(self)
                    txn.append(name, piece)
                    txn.commit()
                self._post_append(name)
                total += piece.num_rows
        return total

    # ---- querying -------------------------------------------------------------
    def scan(self, name: str) -> Query:
        self._check_alive()
        self.catalog.table(name)
        return Query(ScanNode(name), self)

    def sql(self, text: str) -> Query:
        from .sqlparser import parse_sql
        self._check_alive()
        return Query(parse_sql(text, self.catalog), self)

    def delete(self, name: str, predicate) -> int:
        """DELETE FROM name WHERE predicate.  Tables are immutable values,
        so deletion installs a new filtered version through the normal
        begin/commit path (``txn.replace`` — first-committer-wins against
        concurrent appenders, validated under the commit lock like any
        write); per the paper's index lifecycle (§3.1), imprints/hash/order
        indexes on the table are destroyed (replace -> invalidate, unlike
        append's prefix-preserving merge path)."""
        import numpy as np
        from .expression import EvalContext
        self._check_alive()
        self.catalog.table(name)            # DatabaseError when unknown
        txn = self.txn_manager.begin(self)
        try:
            t = txn.snapshot[name]
            arrays = {c: np.asarray(col.data)
                      for c, col in t.columns.items()}
            meta = {c: (col.dbtype, col.heap, col.scale)
                    for c, col in t.columns.items()}
            r = predicate.eval(EvalContext(arrays, meta, xp=np))
            kill = np.asarray(r.values) != 0
            if r.null is not None:
                kill &= ~np.asarray(r.null)
            keep = np.nonzero(~kill)[0]
            new = Table(t.schema,
                        {c: col.take(keep) for c, col in t.columns.items()},
                        version=t.version + 1)
            txn.replace(name, new)
            txn.commit()
        except BaseException:
            # a failed delete (conflict, bad predicate) must not leak an
            # open transaction
            if txn.state == "open":
                txn.rollback()
            raise
        self.device_manager.invalidate_table(name)
        self.plan_cache.invalidate_table(name)
        if self.storage is not None:
            self.storage.write_catalog(self.catalog.tables)
        return int(kill.sum())

    def create_order_index(self, table: str, column: str):
        """CREATE ORDER INDEX (paper §3.1): explicit sorted index used for
        point/range lookups (binary search) and merge joins."""
        self._check_alive()
        self.catalog.table(table)
        return self.index_manager.create_order_index(table, column)

    # ``last_stats`` is a thread-local view: each thread sees the stats of
    # the last query IT ran — one shared mutable attribute would be
    # clobbered under concurrency (thread A reading thread B's spill
    # counts).  Per-result stats travel on ``Result.stats`` as well, which
    # is the concurrency-proof API.
    @property
    def last_stats(self):
        return getattr(self._stats_local, "stats", None)

    @last_stats.setter
    def last_stats(self, value) -> None:
        self._stats_local.stats = value

    def execute_plan(self, plan: PlanNode, do_optimize: bool = True,
                     distributed: bool = False, mesh=None) -> Table:
        self._check_alive()
        if distributed:
            from .parallel import ParallelExecutor
            ex = ParallelExecutor(self, mesh=mesh)
        else:
            ex = Executor(self)
        self.last_stats = ex.stats
        # query scope: cleanup() defers spill-file deletion while we run
        with self.buffer_manager.query_scope():
            return ex.execute(plan, do_optimize=do_optimize)

    # ---- hooks (storage + indexes) -------------------------------------------
    def _commit(self, txn: Transaction) -> None:
        self.txn_manager.commit(self, txn)

    def _on_table_created(self, table: Table) -> None:
        if self.storage is not None:
            self.storage.write_catalog(self.catalog.tables)

    def _on_append(self, table: Table, chunk: Table) -> None:
        if self.storage is not None:
            self.storage.log_append(table, chunk)

    def _on_replace(self, name: str) -> None:
        # a replace rewrites rows wholesale: indexes over the old contents
        # are dead (unlike append's prefix-preserving merge path)
        self.index_manager.invalidate_table(name)

    def _check_alive(self):
        if self._shutdown:
            raise DatabaseError("database has been shut down")

    # ---- introspection -----------------------------------------------------
    def table_names(self) -> list[str]:
        return sorted(self.catalog.tables)

    def table(self, name: str) -> Table:
        return self.catalog.table(name)


def startup(path: Optional[str] = None,
            memory_budget: Optional[int] = None,
            spill_codec: str = "for",
            spill_prefetch: bool = True,
            device_budget: Optional[int] = None,
            device_batch_rows: Optional[int] = None,
            data_skipping: bool = True,
            delta_compact_fraction: float = 0.5) -> Database:
    """monetdb_startup: persistent when ``path`` given, else in-memory.

    ``memory_budget`` (bytes, default unlimited) enables out-of-core
    execution: blocking operators spill partitioned run files to disk when
    their working state would exceed the budget, and over-budget final
    result tables stream to memmapped columns instead of a second RAM
    materialization (``result_spills`` in ``BufferStats``/``ExecStats``).
    Tier routing — spill vs in-memory vs the device tiers — is decided by
    the unified physical planner (``core.physplan``); inspect it with
    ``Query.explain(physical=True)`` or ``db.last_stats.plan_repr``.

    ``spill_codec`` selects the run-file encoding: ``"for"`` (default,
    frame-of-reference + byte-shuffle on integer streams — several-fold
    smaller spills on sorted/clustered keys) or ``"raw"``.
    ``spill_prefetch`` toggles double-buffered background loading of spill
    partitions (default on); prefetched bytes stay pinned inside the
    budget.  Both are no-ops until a query actually spills.

    ``device_budget`` (bytes, default unlimited) is the HBM analogue for
    distributed execution: all device-resident column blocks live under
    this budget in an LRU cache keyed on (table, column, version, shard).
    Inputs that fit stay resident (repeat scans skip the host→device
    transfer entirely); larger inputs stream morsel batches through the
    cache with double-buffered async prefetch and partial-aggregate carry
    — results are bit-identical across budgets.  ``device_batch_rows``
    fixes the streaming batch size (default 65536; the batch decomposition
    — not the budget — determines floating-point summation order).

    ``data_skipping`` (default True) wires the paper's §3.1 column imprints
    into every tier: the physical planner derives a per-scan skip-set (a
    block-qualification bitmap from per-2048-row zone maps) for simple
    range filters, and the device tier never uploads, the spill tier never
    spills, and the host/volcano paths never materialize a block the zone
    maps prove non-qualifying.  Observability: ``blocks_skipped`` /
    ``bytes_skipped_h2d`` / ``bytes_skipped_spill`` in ``BufferStats`` and
    ``ExecStats``, plus a ``(skip: k/N blocks)`` annotation in
    ``Query.explain(physical=True)``.  Skipping is sound by construction
    (bitmaps are supersets of qualifying blocks, re-validated against table
    versions at execution), so results are bit-identical with it off.

    VARCHAR keys spill too, even when the join sides were dictionary-encoded
    against different heaps: small dictionaries merge into one shared heap
    (codes recoded while spooling), oversized ones partition on decoded
    string bytes.  ``BufferStats.varchar_spills`` /
    ``ExecStats.varchar_spills`` count blocking ops that spilled with
    VARCHAR keys.

    Unlike the original (paper §5.1), several databases may be open in one
    process; a directory is single-owner ("database locked") to preserve the
    paper's on-disk locking contract."""
    if path is None:
        return Database(None, memory_budget=memory_budget,
                        spill_codec=spill_codec,
                        spill_prefetch=spill_prefetch,
                        device_budget=device_budget,
                        device_batch_rows=device_batch_rows,
                        data_skipping=data_skipping,
                        delta_compact_fraction=delta_compact_fraction)
    ap = os.path.realpath(path)      # symlink aliases are the same database
    with _open_lock:
        if ap in _open_dirs and not _open_dirs[ap]._shutdown:
            raise DatabaseError(f"database locked: {ap}")
        db = Database(ap, memory_budget=memory_budget,
                      spill_codec=spill_codec,
                      spill_prefetch=spill_prefetch,
                      device_budget=device_budget,
                      device_batch_rows=device_batch_rows,
                      data_skipping=data_skipping,
                      delta_compact_fraction=delta_compact_fraction)
        _open_dirs[ap] = db
    return db


@dataclass
class ResultColumnMeta:
    """High-level column header (paper Listing 2)."""
    name: str
    dbtype: DBType
    null_value: object
    scale: float
    count: int


class Result:
    """monetdb_result: semi-opaque header + per-column fetch.

    ``stats`` carries the query's own ``ExecStats`` — under concurrency
    this is THE reliable way to read per-query counters (``db.last_stats``
    is a per-thread convenience view and sees only the calling thread's
    last query)."""

    def __init__(self, table: Table, stats=None):
        self._table = table
        self.nrows = table.num_rows
        self.ncols = table.num_cols
        self.names = list(table.schema.names)
        self.stats = stats

    def fetch_raw(self, i: int) -> np.ndarray:
        """Low-level fetch: the engine's own packed array, zero-copy
        (requires knowledge of sentinel encoding — for wrappers)."""
        col = self._table.columns[self.names[i]]
        from .exchange import zero_copy_view
        return zero_copy_view(col)

    def fetch(self, i: int):
        """High-level fetch: decoded numpy + header struct."""
        from .types import NULL_SENTINEL
        name = self.names[i]
        col = self._table.columns[name]
        meta = ResultColumnMeta(name, col.dbtype,
                                NULL_SENTINEL[col.dbtype],
                                10.0 ** -col.scale if col.scale else 1.0,
                                len(col))
        return col.to_numpy(), meta

    def to_pydict(self):
        return self._table.to_pydict()


class Connection:
    """Dummy client context (paper §3.2): holds a query/transaction scope;
    many connections per database give inter-query parallelism + isolation."""

    def __init__(self, database: Database):
        self.database = database
        self._txn: Optional[Transaction] = None

    # -- transactions -----------------------------------------------------------
    def begin(self) -> None:
        if self._txn is not None:
            raise DatabaseError("transaction already open")
        self._txn = self.database.txn_manager.begin(self.database)

    def commit(self) -> None:
        if self._txn is None:
            raise DatabaseError("no open transaction")
        self._txn.commit()
        self._txn = None

    def rollback(self) -> None:
        if self._txn is None:
            raise DatabaseError("no open transaction")
        self._txn.rollback()
        self._txn = None

    # -- queries -----------------------------------------------------------------
    def query(self, sql: str, **kw) -> Result:
        from .sqlparser import parse_statement
        db = self.database
        kind, t, c = parse_statement(sql)
        if kind == "create_order_index":
            db.create_order_index(t, c)
            from .table import Table
            from .types import TableSchema
            return Result(Table(TableSchema("result", ()), {}))
        if self._txn is not None:
            # run against the snapshot: materialize a view database
            snap_db = Database(None, memory_budget=db.memory_budget,
                               spill_codec=db.spill_codec,
                               spill_prefetch=db.spill_prefetch,
                               device_budget=db.device_budget,
                               device_batch_rows=db.device_batch_rows,
                               data_skipping=db.data_skipping,
                               delta_compact_fraction=db.delta_compact_fraction)
            # a FRESH IndexManager over the snapshot catalog: skip-sets and
            # imprints derive from the snapshot's own (uncommitted) tables,
            # never from the committed table sharing the version number
            snap_db.catalog.tables = self._txn.tables()
            snap_db.index_manager = IndexManager(snap_db)
            snap_db.buffer_manager = db.buffer_manager   # shared accounting
            # ONE admission accounting too: snapshot queries reserve
            # against the same gate as committed-catalog queries (the
            # budgets are shared, so the reservations must be).  The plan
            # cache stays the snapshot's own throwaway instance — snapshot
            # tables reuse the version number the next committed write
            # gets, so parent-cache entries could alias them
            snap_db.admission_gate = db.admission_gate
            # the parent's device manager is shared too — ONE budget
            # accounting, so physical device residency stays under
            # device_budget even while a snapshot query runs — but under a
            # unique key namespace: a snapshot table reuses the version
            # number the next committed write will get, and namespaced
            # keys keep rolled-back rows from ever being served to later
            # queries as cache hits.  The namespace is invalidated when
            # the query ends (its blocks are uncommitted by definition).
            snap_db.device_manager = db.device_manager
            ns = next(_snapshot_ns)
            snap_db.device_key_namespace = ns
            try:
                table = snap_db.sql(sql).execute(**kw)
            finally:
                db.device_manager.invalidate_namespace(ns)
            # thread per-query stats (spilled_ops, varchar_spills, spill
            # byte deltas) to the parent database: the snapshot view is
            # discarded, but db.last_stats must reflect the last query run
            # through this connection regardless of transaction scope.
            # Both sides are thread-local properties now, so the copy-back
            # moves this thread's snapshot stats into this thread's parent
            # view — concurrent queries on other threads are untouched
            db.last_stats = snap_db.last_stats
        else:
            table = db.sql(sql).execute(**kw)
        return Result(table, stats=db.last_stats)

    def append(self, name: str, data, **kw) -> None:
        if self._txn is not None:
            base = self._txn.table(name)
            chunk = Table.from_dict(
                name, data,
                {c.name: c.dbtype for c in base.schema.columns},
                {c.name: c.scale for c in base.schema.columns})
            self._txn.append(name, chunk)
        else:
            self.database.append(name, data, **kw)
