"""Type system for the embedded columnar engine.

Mirrors MonetDBLite's storage model (paper §3.1):

* every column is a tightly packed 1-D array;
* row numbers are implicit (position in the array);
* missing values are stored as *in-domain sentinel values* -- e.g. a NULL in
  an INTEGER column is ``-2**31`` -- never as a separate validity bitmap;
* variable-length values (VARCHAR) are dictionary-encoded: the column holds
  int32 codes into a duplicate-eliminated heap (paper's "variable-sized
  heap"), with code 0 reserved for NULL.

The sentinel choice matters on TPU: predicates and aggregates stay branch-free
vector ops over packed arrays, which is exactly what the VPU wants.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass

import numpy as np


class DBType(enum.Enum):
    INT32 = "int32"
    INT64 = "int64"
    FLOAT32 = "float32"
    FLOAT64 = "float64"
    BOOL = "bool"           # stored as int8; sentinel -128
    DATE = "date"           # stored as int32 days since 1970-01-01
    DECIMAL = "decimal"     # stored as int64 scaled by 10**scale
    VARCHAR = "varchar"     # stored as int32 dictionary codes; 0 == NULL


# numpy storage dtype for each logical type
STORAGE_DTYPE: dict[DBType, np.dtype] = {
    DBType.INT32: np.dtype(np.int32),
    DBType.INT64: np.dtype(np.int64),
    DBType.FLOAT32: np.dtype(np.float32),
    DBType.FLOAT64: np.dtype(np.float64),
    DBType.BOOL: np.dtype(np.int8),
    DBType.DATE: np.dtype(np.int32),
    DBType.DECIMAL: np.dtype(np.int64),
    DBType.VARCHAR: np.dtype(np.int32),
}

# in-domain NULL sentinel per type (paper §3.1 "Data Storage")
NULL_SENTINEL = {
    DBType.INT32: np.int32(-(2**31)),
    DBType.INT64: np.int64(-(2**63)),
    DBType.FLOAT32: np.float32(np.nan),
    DBType.FLOAT64: np.float64(np.nan),
    DBType.BOOL: np.int8(-128),
    DBType.DATE: np.int32(-(2**31)),
    DBType.DECIMAL: np.int64(-(2**63)),
    DBType.VARCHAR: np.int32(0),
}

_FLOAT_TYPES = (DBType.FLOAT32, DBType.FLOAT64)
_NUMERIC_TYPES = (
    DBType.INT32,
    DBType.INT64,
    DBType.FLOAT32,
    DBType.FLOAT64,
    DBType.DECIMAL,
)


def is_numeric(t: DBType) -> bool:
    return t in _NUMERIC_TYPES


def is_float(t: DBType) -> bool:
    return t in _FLOAT_TYPES


def null_mask(values: np.ndarray, t: DBType) -> np.ndarray:
    """Boolean mask of NULL positions, derived from the sentinel."""
    if is_float(t):
        return np.isnan(values)
    return values == NULL_SENTINEL[t]


def common_type(a: DBType, b: DBType) -> DBType:
    """Implicit arithmetic type promotion."""
    if a == b:
        return a
    order = [DBType.BOOL, DBType.INT32, DBType.DATE, DBType.INT64,
             DBType.DECIMAL, DBType.FLOAT32, DBType.FLOAT64]
    if a in order and b in order:
        return order[max(order.index(a), order.index(b))]
    raise TypeError(f"no common type for {a} and {b}")


@dataclass(frozen=True)
class ColumnSchema:
    name: str
    dbtype: DBType
    scale: int = 0          # DECIMAL scale (10**scale fixed-point)
    nullable: bool = True

    @property
    def storage_dtype(self) -> np.dtype:
        return STORAGE_DTYPE[self.dbtype]


@dataclass(frozen=True)
class TableSchema:
    name: str
    columns: tuple[ColumnSchema, ...]

    def __post_init__(self):
        names = [c.name for c in self.columns]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate column names in {self.name}: {names}")

    def column(self, name: str) -> ColumnSchema:
        for c in self.columns:
            if c.name == name:
                return c
        raise KeyError(f"{self.name} has no column {name!r}")

    @property
    def names(self) -> tuple[str, ...]:
        return tuple(c.name for c in self.columns)


# ---------------------------------------------------------------------------
# DATE helpers: DATE is int32 days since epoch.  We provide vectorized
# conversions without external deps (paper: dependencies stripped, §3.4).
# ---------------------------------------------------------------------------

_EPOCH = np.datetime64("1970-01-01", "D")


def date_from_string(s) -> np.ndarray:
    """Parse 'YYYY-MM-DD' strings (scalar or array-like) to day numbers."""
    arr = np.asarray(s, dtype="datetime64[D]")
    return (arr - _EPOCH).astype(np.int32)


def date_to_string(days: np.ndarray) -> np.ndarray:
    return (np.asarray(days, dtype=np.int32).astype("timedelta64[D]")
            + _EPOCH).astype(str)


def date_year(days: np.ndarray) -> np.ndarray:
    d = np.asarray(days).astype("timedelta64[D]") + _EPOCH
    return d.astype("datetime64[Y]").astype(np.int32) + 1970


def decimal_encode(x, scale: int) -> np.ndarray:
    """Fixed-point encode floats/ints at 10**scale (DECIMAL storage)."""
    return np.round(np.asarray(x, dtype=np.float64) * (10**scale)).astype(np.int64)


def decimal_decode(v: np.ndarray, scale: int) -> np.ndarray:
    return np.asarray(v, dtype=np.float64) / (10**scale)
