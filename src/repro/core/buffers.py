"""Memory-budgeted buffer manager + spill codec for out-of-core execution.

The paper's pitch for MonetDBLite over in-memory analytics tools is that it
keeps "features that are standard for RDBMSes, e.g. out-of-core query
execution".  This module is the accounting + storage half of that feature:

* a ``BufferManager`` owns a configurable byte budget, tracks pinned
  operator working state (pin/unpin), and manages the lifecycle of spill
  files under the database directory (persistent mode) or a private temp
  directory (in-memory mode);
* a lightweight **spill codec** encodes every run-file stream in
  self-describing blocks.  Integer streams (group keys, row indexes) use
  frame-of-reference + byte-shuffle: values are rebased against the block
  minimum, the delta bytes are transposed into per-significance planes, and
  all-zero planes are dropped — sorted or clustered int64 keys typically
  keep only one or two of their eight planes, cutting spill I/O 2-8x.
  Float streams (and any block the codec cannot shrink) pass through raw.
  Variable-width string streams (object arrays, dtype ``object`` in the
  stream declaration) use an offsets+bytes layout: a length sub-block —
  a normal integer codec block, so it keeps the raw fallback — followed by
  the concatenated utf-8 bytes.  Each block carries a header with the codec
  id, so readers never guess and a stream can mix block kinds.

Contract with the spill operators (spill.py):

* operators *pin* working buffers before touching them and *unpin* when the
  buffer is dropped; ``peak`` therefore bounds tracked operator state, and
  tests assert ``peak <= budget``;
* partition/run files are created through ``new_spill_file`` and registered
  so a query abort or ``cleanup()`` can always reclaim them; ``cleanup``
  deletes *only* registered files — a db-owned spill directory may hold a
  concurrent query's run files, which must survive;
* ``SpillPartition.load`` decodes whole streams (pinned by the caller at
  their decoded size), while ``iter_blocks`` streams a partition
  block-by-block for re-partitioning passes that must stay under budget.

``budget=None`` (the default) means unlimited: no spilling, zero overhead —
the paper's zero-config spirit.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import threading
from dataclasses import dataclass
from time import monotonic as _monotonic
from typing import Iterator, Optional

import numpy as np

# ---------------------------------------------------------------------------
# spill codec: frame-of-reference + byte-shuffle, block-oriented
# ---------------------------------------------------------------------------

CODEC_RAW = 0        # payload = arr.tobytes()
CODEC_FOR = 1        # payload = plane-bitmap byte + kept byte planes
CODEC_STR = 2        # payload = [length sub-block][concatenated utf-8 bytes]

CODEC_NAMES = {"raw": CODEC_RAW, "for": CODEC_FOR}

# Per-block header: codec id, row count, payload bytes, frame-of-reference
# base value (meaningful for CODEC_FOR only).  Fixed little-endian layout so
# files are self-describing; dtype itself comes from the stream declaration.
_BLOCK_HDR = np.dtype([("codec", "<u1"), ("flags", "<u1"), ("n", "<u4"),
                       ("payload", "<u8"), ("ref", "<i8")])
BLOCK_HEADER_BYTES = _BLOCK_HDR.itemsize


def _utf8(s) -> bytes:
    """One string value's utf-8 bytes; already-encoded ``bytes`` pass
    through — spool paths pre-encode each value once and hash/account/write
    from the same bytes."""
    return s if isinstance(s, bytes) else str(s).encode("utf-8")


def logical_nbytes(arr: np.ndarray) -> int:
    """Decoded (pre-codec) byte size of a stream chunk.  Fixed-width arrays
    report ``arr.nbytes``; object arrays of strings report the utf-8 payload
    plus a 4-byte length per value (``arr.nbytes`` would only count the
    PyObject pointers)."""
    arr = np.asarray(arr)
    if arr.dtype != object:
        return int(arr.nbytes)
    return int(sum(len(_utf8(s)) for s in arr)) + 4 * len(arr)


def _str_block(bs: list, codec: int) -> bytes:
    """String (offsets+bytes) block from already-encoded utf-8 values: a
    length sub-block — itself a normal codec block, so it inherits the
    integer codec's raw fallback — followed by the concatenated bytes.
    NULLs are not representable here: VARCHAR streams spill either as int32
    dictionary codes (NULL = code 0) or as decoded strings of
    pre-null-filtered rows."""
    lens = np.fromiter((len(b) for b in bs), dtype=np.int32, count=len(bs))
    body = encode_block(lens, codec) + b"".join(bs)
    hdr = np.zeros(1, dtype=_BLOCK_HDR)
    hdr["codec"], hdr["n"] = CODEC_STR, len(bs)
    hdr["payload"], hdr["ref"] = len(body), 0
    return hdr.tobytes() + body


def _decode_str_payload(hdr, payload: bytes) -> np.ndarray:
    n = int(hdr["n"])
    out = np.empty(n, dtype=object)
    if n == 0:
        return out
    sub = np.frombuffer(payload, _BLOCK_HDR, count=1)[0]
    off = BLOCK_HEADER_BYTES
    pl = int(sub["payload"])
    lens = _decode_payload(sub, payload[off:off + pl], np.dtype(np.int32))
    off += pl
    ends = off + np.cumsum(lens.astype(np.int64))
    starts = ends - lens
    for i in range(n):
        out[i] = payload[starts[i]:ends[i]].decode("utf-8")
    return out


def encode_block_ex(arr: np.ndarray, codec: int) -> tuple[bytes, int]:
    """Encode one stream chunk; returns (block, decoded logical bytes).

    ``codec`` is the *requested* codec; the block falls back to raw when the
    dtype is not integral or the encoded form would not be smaller (the
    header records what was actually used).  Object arrays of strings always
    take the string (offsets+bytes) layout — ``codec`` then only selects the
    encoding of the embedded length sub-block.  Object elements may be
    ``str`` or pre-encoded utf-8 ``bytes`` (spool paths encode each value
    once up front and hash/pin/write from the same bytes)."""
    if np.asarray(arr).dtype == object:
        bs = [_utf8(s) for s in np.asarray(arr)]
        return (_str_block(bs, codec),
                sum(len(b) for b in bs) + 4 * len(bs))
    arr = np.ascontiguousarray(arr)
    n = len(arr)
    ref = 0
    cid = CODEC_RAW
    payload: Optional[bytes] = None
    if codec == CODEC_FOR and arr.dtype.kind in "iu" and n > 0 \
            and arr.dtype.itemsize in (2, 4, 8):
        w = arr.dtype.itemsize
        mask = (1 << (8 * w)) - 1
        ref = int(arr.min())
        if ref > (1 << 63) - 1:              # uint64 minima past int64 max:
            ref -= 1 << 64                   # two's-complement into the i8
                                             # header (decode re-masks)
        # rebase in modular unsigned arithmetic: exact for any value mix,
        # including the in-domain NULL sentinel -2**63
        u = arr.view(np.dtype(f"u{w}"))
        delta = u - np.asarray(ref & mask, dtype=f"u{w}")
        # byte-shuffle: plane j holds byte j (LE significance) of every value
        planes = delta.view(np.uint8).reshape(n, w).T
        bitmap = 0
        kept = []
        for j in range(w):
            if planes[j].any():
                bitmap |= 1 << j
                kept.append(np.ascontiguousarray(planes[j]).tobytes())
        body = bytes([bitmap]) + b"".join(kept)
        if len(body) < arr.nbytes:
            payload, cid = body, CODEC_FOR
    if payload is None:
        payload = arr.tobytes()
    hdr = np.zeros(1, dtype=_BLOCK_HDR)
    hdr["codec"], hdr["n"] = cid, n
    hdr["payload"], hdr["ref"] = len(payload), ref
    return hdr.tobytes() + payload, int(arr.nbytes)


def encode_block(arr: np.ndarray, codec: int) -> bytes:
    """Encode one stream chunk as a self-describing block (see
    ``encode_block_ex`` for the accounting-aware variant)."""
    return encode_block_ex(arr, codec)[0]


def _decode_payload(hdr, payload: bytes, dtype: np.dtype) -> np.ndarray:
    n = int(hdr["n"])
    if int(hdr["codec"]) == CODEC_STR:
        return _decode_str_payload(hdr, payload)
    if int(hdr["codec"]) == CODEC_RAW:
        return np.frombuffer(payload, dtype=dtype, count=n)
    w = dtype.itemsize
    bitmap = payload[0]
    mat = np.zeros((w, n), dtype=np.uint8)
    p = 1
    for j in range(w):
        if (bitmap >> j) & 1:
            mat[j] = np.frombuffer(payload, np.uint8, count=n, offset=p)
            p += n
    delta = np.ascontiguousarray(mat.T).reshape(-1).view(np.dtype(f"u{w}"))
    ref = np.asarray(int(hdr["ref"]) & ((1 << (8 * w)) - 1), dtype=f"u{w}")
    return (delta + ref).view(dtype)


def decode_stream(data: bytes, dtype) -> np.ndarray:
    """Decode a whole stream (concatenated blocks) back into one array."""
    dtype = np.dtype(dtype)
    parts = []
    off, total = 0, len(data)
    while off < total:
        hdr = np.frombuffer(data, _BLOCK_HDR, count=1, offset=off)[0]
        off += BLOCK_HEADER_BYTES
        pl = int(hdr["payload"])
        parts.append(_decode_payload(hdr, data[off:off + pl], dtype))
        off += pl
    if not parts:
        return np.empty(0, dtype=dtype)
    return parts[0] if len(parts) == 1 else np.concatenate(parts)


def write_stream_block(f, arr: np.ndarray, codec: int,
                       bufman: Optional["BufferManager"] = None
                       ) -> tuple[int, int]:
    """Encode + write one block; accounts raw vs stored bytes on ``bufman``
    and returns (stored, logical) sizes — strings are encoded only once."""
    block, logical = encode_block_ex(arr, codec)
    f.write(block)
    if bufman is not None:
        bufman.note_spilled(logical, len(block))
    return len(block), logical


def read_stream_block(f, dtype) -> Optional[np.ndarray]:
    """Read + decode the next block from an open file; None at EOF."""
    hb = f.read(BLOCK_HEADER_BYTES)
    if len(hb) < BLOCK_HEADER_BYTES:
        return None
    hdr = np.frombuffer(hb, _BLOCK_HDR)[0]
    payload = f.read(int(hdr["payload"]))
    return _decode_payload(hdr, payload, np.dtype(dtype))


# ---------------------------------------------------------------------------
# buffer manager
# ---------------------------------------------------------------------------


@dataclass
class BufferStats:
    pinned: int = 0              # bytes currently pinned
    peak: int = 0                # high-water mark of pinned bytes
    spill_count: int = 0         # spill files created
    bytes_spilled: int = 0       # post-codec bytes actually written
    bytes_spilled_raw: int = 0   # pre-codec (logical) spilled bytes
    spilled_ops: int = 0         # blocking operators that took the spill path
    varchar_spills: int = 0      # spilled ops whose keys include VARCHAR
    result_spills: int = 0       # final tables streamed to memmapped columns
    prefetch_hits: int = 0       # partitions served by the async prefetcher
    repartitions: int = 0        # oversized partitions split recursively
    # device tier (device_cache.py): HBM-budgeted block cache counters
    device_bytes_peak: int = 0   # high-water of tracked device-resident bytes
    device_bytes_h2d: int = 0    # host→device bytes actually transferred
    device_cache_hits: int = 0   # blocks served from the cross-query cache
    device_prefetch_hits: int = 0  # batches whose transfer was issued ahead
    device_evictions: int = 0    # blocks evicted under budget pressure
    device_writebacks: int = 0   # dirty (intermediate) blocks copied to host
    # serving layer (serving.py): concurrent-query counters
    plan_cache_hits: int = 0     # queries that skipped lowering entirely
    plan_cache_misses: int = 0   # queries that paid a full lowering pass
    admission_waits: int = 0     # admissions that queued for budget room
    shared_scan_attaches: int = 0  # block requests served by another
                                   # query's in-flight build/upload
    # imprint-driven data skipping (physplan.SkipSet): blocks the zone maps
    # proved non-qualifying, and the bytes each tier never moved for them
    blocks_skipped: int = 0        # imprint blocks never read/uploaded
    bytes_skipped_h2d: int = 0     # host→device bytes skipped batches held
    bytes_skipped_spill: int = 0   # column bytes kept out of scan→filter→
                                   # partition streams (logical estimate)
    # delta-store ingest (delta.py): merge-on-read appends + compaction
    delta_bytes_h2d: int = 0       # h2d bytes for delta-tail device blocks
    delta_rows: int = 0            # delta-tail rows consumed by scans
    compactions: int = 0           # delta tails folded into a new base

    @property
    def bytes_spilled_compressed(self) -> int:
        """Alias of ``bytes_spilled``, named for the raw/compressed pair."""
        return self.bytes_spilled


class BufferManager:
    """Byte-budget accounting + spill-file lifecycle for one database."""

    def __init__(self, budget: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 codec: str = "for", prefetch: bool = True):
        if budget is not None and budget <= 0:
            raise ValueError(f"memory budget must be positive, got {budget}")
        if codec not in CODEC_NAMES:
            raise ValueError(f"spill_codec must be one of "
                             f"{sorted(CODEC_NAMES)}, got {codec!r}")
        self.budget = budget
        self.codec = CODEC_NAMES[codec]
        self.prefetch = bool(prefetch)
        self._spill_dir = spill_dir          # created lazily on first spill
        self._owns_dir = spill_dir is None   # temp dir -> remove on cleanup
        self._dir_ready = False
        self._seq = 0
        self._files: set[str] = set()
        self._lock = threading.Lock()
        # query-scope tracking: cleanup() must not unlink spill files
        # registered to a query still running on another thread, so queries
        # announce themselves (query_scope) and cleanup defers until the
        # last one drains
        self._query_cond = threading.Condition()
        self._active_queries = 0
        self._cleanup_deferred = False
        self.stats = BufferStats()

    # ---- budget accounting -------------------------------------------------
    def would_exceed(self, nbytes: int) -> bool:
        """True when pinning ``nbytes`` more would overflow the budget.

        Check-only: a concurrent pin can land between this test and a
        subsequent ``pin``, jointly overshooting the budget.  Use
        ``try_pin`` for the atomic reserve-or-fail form; this predicate
        remains for single-threaded size probes."""
        if self.budget is None:
            return False
        return self.stats.pinned + int(nbytes) > self.budget

    def try_pin(self, nbytes: int) -> bool:
        """Atomic reserve-or-fail: pin ``nbytes`` iff it fits the budget
        *under the lock* — the thread-safe replacement for the
        ``would_exceed()`` + ``pin()`` check-then-act pair, which two
        threads could both pass and jointly exceed the budget."""
        nbytes = int(nbytes)
        with self._lock:
            if self.budget is not None \
                    and self.stats.pinned + nbytes > self.budget:
                return False
            self.stats.pinned += nbytes
            self.stats.peak = max(self.stats.peak, self.stats.pinned)
            return True

    def pin(self, nbytes: int) -> int:
        nbytes = int(nbytes)
        with self._lock:
            self.stats.pinned += nbytes
            self.stats.peak = max(self.stats.peak, self.stats.pinned)
        return nbytes

    def unpin(self, nbytes: int) -> None:
        with self._lock:
            self.stats.pinned = max(0, self.stats.pinned - int(nbytes))

    def bump(self, **deltas: int) -> None:
        """Atomically add ``deltas`` to stats counters.  Operator code must
        use this (or a ``stats_base``/``stats_apply_delta`` window) instead
        of ``bm.stats.field += n`` — the bare form is an unlocked
        read-modify-write that loses updates under concurrency."""
        with self._lock:
            for name, delta in deltas.items():
                setattr(self.stats, name, getattr(self.stats, name) + delta)

    class _Pin:
        def __init__(self, mgr: "BufferManager", nbytes: int):
            self._mgr, self._n = mgr, int(nbytes)

        def __enter__(self):
            self._mgr.pin(self._n)
            return self

        def __exit__(self, *exc):
            self._mgr.unpin(self._n)
            return False

    def pinned(self, nbytes: int) -> "_Pin":
        """Context manager: pin on entry, unpin on exit."""
        return self._Pin(self, nbytes)

    # ---- spill files -------------------------------------------------------
    @property
    def spill_dir(self) -> str:
        with self._lock:
            if not self._dir_ready:
                if self._spill_dir is None:
                    self._spill_dir = tempfile.mkdtemp(
                        prefix="litecol-spill-")
                else:
                    os.makedirs(self._spill_dir, exist_ok=True)
                self._dir_ready = True
            return self._spill_dir

    def new_spill_file(self, hint: str = "run") -> str:
        with self._lock:
            self._seq += 1
            seq = self._seq
        path = os.path.join(self.spill_dir, f"{hint}.{seq:06d}.bin")
        with self._lock:
            self._files.add(path)
            self.stats.spill_count += 1
        return path

    def note_spilled(self, raw_nbytes: int,
                     stored_nbytes: Optional[int] = None) -> None:
        """Record one spill write: logical (pre-codec) vs stored bytes."""
        raw_nbytes = int(raw_nbytes)
        stored = raw_nbytes if stored_nbytes is None else int(stored_nbytes)
        with self._lock:
            self.stats.bytes_spilled += stored
            self.stats.bytes_spilled_raw += raw_nbytes

    def release_file(self, path: str) -> None:
        with self._lock:
            self._files.discard(path)
        # unlink outside the accounting lock (pin/unpin/note_spilled stay
        # hot); a concurrent release of the same path is tolerated instead
        # of raced-for — unlink errors on a missing file are expected
        try:
            os.unlink(path)
        except OSError:
            pass

    @property
    def active_files(self) -> int:
        with self._lock:
            return len(self._files)

    # ---- query scope -------------------------------------------------------
    @property
    def active_queries(self) -> int:
        with self._query_cond:
            return self._active_queries

    def begin_query(self) -> None:
        with self._query_cond:
            self._active_queries += 1

    def end_query(self) -> None:
        run_deferred = False
        with self._query_cond:
            self._active_queries = max(0, self._active_queries - 1)
            if self._active_queries == 0:
                self._query_cond.notify_all()
                run_deferred = self._cleanup_deferred
        if run_deferred:
            # a cleanup() arrived while we were running and deferred
            # instead of unlinking our files out from under us — honour it
            # now that the last query has drained
            self.cleanup()

    class _QueryScope:
        def __init__(self, mgr: "BufferManager"):
            self._mgr = mgr

        def __enter__(self):
            self._mgr.begin_query()
            return self

        def __exit__(self, *exc):
            self._mgr.end_query()
            return False

    def query_scope(self) -> "_QueryScope":
        """Context manager marking one query in flight on this manager —
        cleanup() defers file deletion while any scope is open."""
        return self._QueryScope(self)

    # ---- lifecycle ---------------------------------------------------------
    def cleanup(self, wait: float = 2.0) -> None:
        """Delete every *registered* spill file (and the temp dir if owned).

        A db-owned spill directory is shared by every connection of this
        database: only files this manager registered are removed, never the
        whole directory listing (a concurrent query's run files survive).
        Stale files from a crashed process are reclaimed at startup instead
        (``Storage.reclaim_spill``).

        While queries are in flight (``query_scope``) the registered files
        may belong to them — unlinking would yank run files out from under
        another thread mid-join.  Cleanup waits up to ``wait`` seconds for
        the queries to drain; if they don't, it *defers*: nothing is
        deleted now, and the last ``end_query`` performs the cleanup."""
        with self._query_cond:
            deadline = _monotonic() + wait
            while self._active_queries > 0:
                remaining = deadline - _monotonic()
                if remaining <= 0:
                    self._cleanup_deferred = True
                    return
                self._query_cond.wait(remaining)
            self._cleanup_deferred = False
        with self._lock:
            files = list(self._files)
            self._files.clear()
            # snapshot the owned-dir decision under the lock too: a
            # concurrent spill_dir may be mid-creation, and reading
            # _dir_ready/_spill_dir outside the lock races it
            spill_dir = self._spill_dir
            remove_dir = self._owns_dir and self._dir_ready \
                and spill_dir is not None
            if remove_dir:
                self._dir_ready = False
        for p in files:
            try:
                os.unlink(p)       # tolerate a concurrent release_file win
            except OSError:
                pass
        if remove_dir and os.path.isdir(spill_dir):
            shutil.rmtree(spill_dir, ignore_errors=True)


class PartitionWriter:
    """Hash/range-partitioned spill writer: N partitions x M named streams.

    Each (partition, stream) pair is one file of codec blocks of a fixed
    dtype; ``append`` scatters row chunks to their partitions (one block per
    touched stream per call, so blocks stay row-aligned across a
    partition's streams), ``finalize`` returns per-partition readers, and
    ``abort`` closes + releases everything after a mid-spool error.  This is
    the grace-hash fan-out file layout.

    Block granularity follows the caller's morsel: under very small budgets
    a morsel scattered over many partitions makes small header-heavy
    blocks, which is accepted — consolidating them would need a write
    buffer of n_parts * block_bytes, i.e. exactly the memory the budget
    denies (the repartition path coalesces its input blocks back up to a
    morsel before re-scattering for the same reason)."""

    MAX_PARTITIONS = 64      # bounded fd usage; 64 * budget/4 input headroom

    # transfers-ownership: registered paths are released by finalize()
    # readers or abort(), never here
    def __init__(self, bufman: BufferManager, n_parts: int,
                 streams: dict[str, np.dtype], hint: str = "part",
                 codec: Optional[int] = None):
        self.bufman = bufman
        self.n_parts = int(n_parts)
        self.codec = bufman.codec if codec is None else int(codec)
        self.streams = {k: np.dtype(v) for k, v in streams.items()}
        self._paths = [{s: bufman.new_spill_file(f"{hint}{p}.{s}")
                        for s in streams} for p in range(self.n_parts)]
        self._handles = [{s: None for s in streams}
                         for _ in range(self.n_parts)]
        self._rows = [0] * self.n_parts
        self._nbytes = [0] * self.n_parts    # decoded (logical) bytes/part

    def append(self, part_ids: np.ndarray, chunks: dict[str, np.ndarray]):
        """Scatter one chunk of rows into partition files by ``part_ids``."""
        for p in np.unique(part_ids):
            p = int(p)
            m = part_ids == p
            n = int(m.sum())
            if n == 0:
                continue
            for s, arr in chunks.items():
                h = self._handles[p][s]
                if h is None:
                    h = open(self._paths[p][s], "wb")
                    self._handles[p][s] = h
                data = arr[m].astype(self.streams[s], copy=False)
                _, logical = write_stream_block(h, data, self.codec,
                                                self.bufman)
                self._nbytes[p] += logical
            self._rows[p] += n

    def _close(self) -> None:
        for hs in self._handles:
            for s, h in hs.items():
                if h is not None:
                    h.close()
                    hs[s] = None

    def finalize(self) -> list["SpillPartition"]:
        self._close()
        return [SpillPartition(self.bufman, self._paths[p], self.streams,
                               self._rows[p], logical_bytes=self._nbytes[p])
                for p in range(self.n_parts)]

    def abort(self) -> None:
        """Error path: close handles and release every partition file, so a
        query that dies mid-spool leaks nothing until db cleanup()."""
        self._close()
        for paths in self._paths:
            for p in paths.values():
                self.bufman.release_file(p)


class SpillPartition:
    """One partition's streams; ``load`` pins the bytes it reads into RAM."""

    def __init__(self, bufman: BufferManager, paths: dict[str, str],
                 streams: dict[str, np.dtype], rows: int,
                 logical_bytes: Optional[int] = None):
        self.bufman = bufman
        self.paths = paths
        self.streams = streams
        self.rows = int(rows)
        self._logical = logical_bytes

    @property
    def nbytes(self) -> int:
        """Decoded (logical) size — what ``load`` materializes and what the
        caller pins; the on-disk footprint may be smaller via the codec.
        The writer-tracked figure is preferred because object (string)
        streams have no meaningful fixed itemsize."""
        if self._logical is not None:
            return self._logical
        return sum(self.rows * dt.itemsize for dt in self.streams.values())

    def read_streams(self) -> dict[str, bytes]:
        """The I/O half of ``load``: raw (still-encoded) stream bytes.  The
        async prefetcher runs this off-thread — plain file reads release the
        GIL, whereas numpy decode work would contend with the consumer — and
        the consumer decodes on arrival via ``decode_streams``."""
        if self.rows == 0:
            return {s: b"" for s in self.streams}
        out = {}
        for s in self.streams:
            with open(self.paths[s], "rb") as f:
                out[s] = f.read()
        return out

    def decode_streams(self, raw: dict[str, bytes]) -> dict[str, np.ndarray]:
        """The CPU half of ``load`` (empty partitions are zero-length)."""
        return {s: (np.empty(0, dtype=dt) if self.rows == 0
                    else decode_stream(raw[s], dt))
                for s, dt in self.streams.items()}

    def load(self) -> dict[str, np.ndarray]:
        """Read + decode every stream into RAM (caller pins via ``pinned``
        around the partition's processing)."""
        return self.decode_streams(self.read_streams())

    def iter_blocks(self) -> Iterator[dict[str, np.ndarray]]:
        """Stream the partition one row-aligned block at a time (bounded
        memory) — the recursive-repartition path reads this way instead of
        materializing an over-budget partition via ``load``."""
        if self.rows == 0:
            return
        fs = {s: open(self.paths[s], "rb") for s in self.streams}
        try:
            while True:
                blk = {}
                for s, dt in self.streams.items():
                    a = read_stream_block(fs[s], dt)
                    if a is None:
                        return
                    blk[s] = a
                yield blk
        finally:
            for f in fs.values():
                f.close()

    def release(self) -> None:
        for p in self.paths.values():
            self.bufman.release_file(p)


def choose_partitions(est_bytes: int, budget: Optional[int]) -> int:
    """Power-of-two partition count targeting ~budget/4 bytes/partition.

    An unlimited budget (None) never *needs* partitioning for memory; the
    minimum fan-out keeps explicitly-requested spools valid."""
    if budget is None:
        return 2
    p = 1
    target = max(1, budget // 4)
    while p < PartitionWriter.MAX_PARTITIONS and est_bytes / p > target:
        p *= 2
    return max(p, 2)


def choose_morsel_rows(row_bytes: int, budget: Optional[int],
                       default: int = 1 << 16) -> int:
    """Chunk size for streaming passes: small enough that one in-flight
    morsel stays inside the budget (the 64-row floor means budgets below
    ~64 rows of state can still overshoot — the practical lower bound)."""
    if budget is None:
        return default
    return int(min(default, max(64, budget // (4 * max(1, row_bytes)))))
