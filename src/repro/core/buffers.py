"""Memory-budgeted buffer manager for out-of-core query execution.

The paper's pitch for MonetDBLite over in-memory analytics tools is that it
keeps "features that are standard for RDBMSes, e.g. out-of-core query
execution".  This module is the accounting half of that feature: a
``BufferManager`` owns a configurable byte budget, tracks pinned operator
working state (pin/unpin), and manages the lifecycle of spill files under
the database directory (persistent mode) or a private temp directory
(in-memory mode).

Contract with the spill operators (spill.py):

* operators *pin* working buffers before touching them and *unpin* when the
  buffer is dropped; ``peak`` therefore bounds tracked operator state, and
  tests assert ``peak <= budget``;
* partition/run files are created through ``new_spill_file`` and registered
  so a query abort or ``cleanup()`` can always reclaim them;
* run files are read back as ``np.memmap`` views so the merge phase streams
  through the OS page cache instead of pinned RAM — the same design as the
  memory-mapped base columns (paper §3.1 "Memory Management").

``budget=None`` (the default) means unlimited: no spilling, zero overhead —
the paper's zero-config spirit.
"""

from __future__ import annotations

import os
import shutil
import tempfile
import threading
from dataclasses import dataclass, field
from typing import Optional

import numpy as np


@dataclass
class BufferStats:
    pinned: int = 0              # bytes currently pinned
    peak: int = 0                # high-water mark of pinned bytes
    spill_count: int = 0         # spill files created
    bytes_spilled: int = 0       # total bytes written to spill files
    spilled_ops: int = 0         # blocking operators that took the spill path


class BufferManager:
    """Byte-budget accounting + spill-file lifecycle for one database."""

    def __init__(self, budget: Optional[int] = None,
                 spill_dir: Optional[str] = None):
        if budget is not None and budget <= 0:
            raise ValueError(f"memory budget must be positive, got {budget}")
        self.budget = budget
        self._spill_dir = spill_dir          # created lazily on first spill
        self._owns_dir = spill_dir is None   # temp dir -> remove on cleanup
        self._dir_ready = False
        self._seq = 0
        self._files: set[str] = set()
        self._lock = threading.Lock()
        self.stats = BufferStats()

    # ---- budget accounting -------------------------------------------------
    def would_exceed(self, nbytes: int) -> bool:
        """True when pinning ``nbytes`` more would overflow the budget."""
        if self.budget is None:
            return False
        return self.stats.pinned + int(nbytes) > self.budget

    def pin(self, nbytes: int) -> int:
        nbytes = int(nbytes)
        with self._lock:
            self.stats.pinned += nbytes
            self.stats.peak = max(self.stats.peak, self.stats.pinned)
        return nbytes

    def unpin(self, nbytes: int) -> None:
        with self._lock:
            self.stats.pinned = max(0, self.stats.pinned - int(nbytes))

    class _Pin:
        def __init__(self, mgr: "BufferManager", nbytes: int):
            self._mgr, self._n = mgr, int(nbytes)

        def __enter__(self):
            self._mgr.pin(self._n)
            return self

        def __exit__(self, *exc):
            self._mgr.unpin(self._n)
            return False

    def pinned(self, nbytes: int) -> "_Pin":
        """Context manager: pin on entry, unpin on exit."""
        return self._Pin(self, nbytes)

    # ---- spill files -------------------------------------------------------
    @property
    def spill_dir(self) -> str:
        with self._lock:
            if not self._dir_ready:
                if self._spill_dir is None:
                    self._spill_dir = tempfile.mkdtemp(
                        prefix="litecol-spill-")
                else:
                    os.makedirs(self._spill_dir, exist_ok=True)
                self._dir_ready = True
            return self._spill_dir

    def new_spill_file(self, hint: str = "run") -> str:
        with self._lock:
            self._seq += 1
            seq = self._seq
        path = os.path.join(self.spill_dir, f"{hint}.{seq:06d}.bin")
        with self._lock:
            self._files.add(path)
            self.stats.spill_count += 1
        return path

    def note_spilled(self, nbytes: int) -> None:
        with self._lock:
            self.stats.bytes_spilled += int(nbytes)

    def release_file(self, path: str) -> None:
        with self._lock:
            self._files.discard(path)
        if os.path.exists(path):
            os.unlink(path)

    @property
    def active_files(self) -> int:
        return len(self._files)

    # ---- lifecycle ---------------------------------------------------------
    def cleanup(self) -> None:
        """Delete every registered spill file (and the temp dir if owned)."""
        with self._lock:
            files = list(self._files)
            self._files.clear()
        for p in files:
            if os.path.exists(p):
                os.unlink(p)
        if self._dir_ready and self._spill_dir \
                and os.path.isdir(self._spill_dir):
            if self._owns_dir:
                shutil.rmtree(self._spill_dir, ignore_errors=True)
                self._dir_ready = False
            else:
                # db-owned spill dir: keep the directory, drop stale content
                for name in os.listdir(self._spill_dir):
                    try:
                        os.unlink(os.path.join(self._spill_dir, name))
                    except OSError:
                        pass


class PartitionWriter:
    """Hash/range-partitioned spill writer: N partitions x M named streams.

    Each (partition, stream) pair is one flat binary file of a fixed dtype;
    ``append`` scatters row chunks to their partitions, ``finalize`` returns
    per-partition readers.  This is the grace-hash fan-out file layout."""

    MAX_PARTITIONS = 64      # bounded fd usage; 64 * budget/4 input headroom

    def __init__(self, bufman: BufferManager, n_parts: int,
                 streams: dict[str, np.dtype], hint: str = "part"):
        self.bufman = bufman
        self.n_parts = int(n_parts)
        self.streams = {k: np.dtype(v) for k, v in streams.items()}
        self._paths = [{s: bufman.new_spill_file(f"{hint}{p}.{s}")
                        for s in streams} for p in range(self.n_parts)]
        self._handles = [{s: None for s in streams}
                         for _ in range(self.n_parts)]
        self._rows = [0] * self.n_parts

    def append(self, part_ids: np.ndarray, chunks: dict[str, np.ndarray]):
        """Scatter one chunk of rows into partition files by ``part_ids``."""
        for p in np.unique(part_ids):
            p = int(p)
            m = part_ids == p
            n = int(m.sum())
            if n == 0:
                continue
            for s, arr in chunks.items():
                h = self._handles[p][s]
                if h is None:
                    h = open(self._paths[p][s], "wb")
                    self._handles[p][s] = h
                data = np.ascontiguousarray(
                    arr[m].astype(self.streams[s], copy=False))
                h.write(data.tobytes())
                self.bufman.note_spilled(int(data.nbytes))
            self._rows[p] += n

    def finalize(self) -> list["SpillPartition"]:
        for hs in self._handles:
            for h in hs.values():
                if h is not None:
                    h.close()
        return [SpillPartition(self.bufman, self._paths[p], self.streams,
                               self._rows[p]) for p in range(self.n_parts)]


class SpillPartition:
    """One partition's streams; ``load`` pins the bytes it reads into RAM."""

    def __init__(self, bufman: BufferManager, paths: dict[str, str],
                 streams: dict[str, np.dtype], rows: int):
        self.bufman = bufman
        self.paths = paths
        self.streams = streams
        self.rows = int(rows)

    @property
    def nbytes(self) -> int:
        return sum(self.rows * dt.itemsize for dt in self.streams.values())

    def load(self) -> dict[str, np.ndarray]:
        """Read every stream into RAM (caller pins via ``pinned`` around the
        partition's processing; empty partitions are zero-length arrays)."""
        out = {}
        for s, dt in self.streams.items():
            if self.rows == 0:
                out[s] = np.empty(0, dtype=dt)
            else:
                out[s] = np.fromfile(self.paths[s], dtype=dt)
        return out

    def release(self) -> None:
        for p in self.paths.values():
            self.bufman.release_file(p)


def choose_partitions(est_bytes: int, budget: int) -> int:
    """Power-of-two partition count targeting ~budget/4 bytes/partition."""
    p = 1
    target = max(1, budget // 4)
    while p < PartitionWriter.MAX_PARTITIONS and est_bytes / p > target:
        p *= 2
    return max(p, 2)


def choose_morsel_rows(row_bytes: int, budget: Optional[int],
                       default: int = 1 << 16) -> int:
    """Chunk size for streaming passes: small enough that one in-flight
    morsel stays inside the budget (the 64-row floor means budgets below
    ~64 rows of state can still overshoot — the practical lower bound)."""
    if budget is None:
        return default
    return int(min(default, max(64, budget // (4 * max(1, row_bytes)))))
