"""SQL front-end: a recursive-descent parser for the analytical subset.

Covers the TPC-H-style single-block queries the paper benchmarks with:

  SELECT [DISTINCT] expr [AS name], ...
  FROM t1 [a1] [, t2 ... | [LEFT] JOIN t2 ON c1 = c2 [AND ...]]
  WHERE pred        (comma-joins: equi conditions are lifted into joins)
  GROUP BY cols     HAVING pred
  ORDER BY name [ASC|DESC], ...    LIMIT n

Aggregates: SUM/COUNT/AVG/MIN/MAX/MEDIAN/COUNT(DISTINCT x)/STDDEV/VARIANCE.
Scalar: arithmetic, comparisons, AND/OR/NOT, BETWEEN, IN, LIKE, IS [NOT]
NULL, CASE WHEN, CAST, EXTRACT(YEAR ...), DATE 'yyyy-mm-dd', SUBSTRING-free
functions from expression.Func.  Subqueries are out of scope (the paper's
queries that need them are expressed through the builder API; see
data/tpch_queries.py).
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional

from .expression import (BinOp, Case, Cast, Col, DateLit, Expr, Func, InList,
                         IsNull, Like, Lit, Not)
from .relalg import (AggregateNode, AggSpec, FilterNode, JoinNode, LimitNode,
                     OrderByNode, PlanNode, ProjectNode, ScanNode)
from .types import DBType


class SQLError(ValueError):
    pass


_TOKEN_RE = re.compile(r"""
    \s*(?:
      (?P<num>\d+\.\d*|\.\d+|\d+)
    | (?P<str>'(?:[^']|'')*')
    | (?P<op><>|<=|>=|!=|\|\||[-+*/%(),.<>=])
    | (?P<name>[A-Za-z_][A-Za-z_0-9]*)
    )""", re.VERBOSE)

_KEYWORDS = {
    "select", "distinct", "from", "where", "group", "by", "having", "order",
    "limit", "as", "and", "or", "not", "between", "in", "like", "is",
    "null", "case", "when", "then", "else", "end", "cast", "date",
    "asc", "desc", "join", "inner", "left", "outer", "on", "extract",
    "year", "interval", "true", "false",
}

_AGG_NAMES = {"sum", "count", "avg", "min", "max", "median",
              "stddev", "variance"}
_AGG_MAP = {"stddev": "std", "variance": "var"}


@dataclass
class Token:
    kind: str          # num | str | op | name | kw
    text: str


def tokenize(sql: str) -> list[Token]:
    out = []
    pos = 0
    sql = sql.strip().rstrip(";")
    while pos < len(sql):
        m = _TOKEN_RE.match(sql, pos)
        if not m:
            raise SQLError(f"cannot tokenize at: {sql[pos:pos+30]!r}")
        pos = m.end()
        if m.lastgroup == "name":
            t = m.group("name")
            kind = "kw" if t.lower() in _KEYWORDS else "name"
            out.append(Token(kind, t.lower() if kind == "kw" else t))
        elif m.lastgroup == "str":
            out.append(Token("str", m.group("str")[1:-1].replace("''", "'")))
        elif m.lastgroup == "num":
            out.append(Token("num", m.group("num")))
        else:
            out.append(Token("op", m.group("op")))
    return out


class Parser:
    def __init__(self, tokens: list[Token], catalog):
        self.toks = tokens
        self.i = 0
        self.catalog = catalog
        self.alias_to_table: dict[str, str] = {}
        self._agg_specs: list[AggSpec] = []
        self._agg_ctr = 0

    # -- token helpers -------------------------------------------------------
    def peek(self, k: int = 0) -> Optional[Token]:
        j = self.i + k
        return self.toks[j] if j < len(self.toks) else None

    def next(self) -> Token:
        t = self.peek()
        if t is None:
            raise SQLError("unexpected end of query")
        self.i += 1
        return t

    def accept(self, kind: str, text: Optional[str] = None) -> Optional[Token]:
        t = self.peek()
        if t is not None and t.kind == kind and (text is None or t.text == text):
            self.i += 1
            return t
        return None

    def expect(self, kind: str, text: Optional[str] = None) -> Token:
        t = self.accept(kind, text)
        if t is None:
            raise SQLError(f"expected {text or kind}, got {self.peek()}")
        return t

    # -- query ---------------------------------------------------------------
    def parse_query(self) -> PlanNode:
        self.expect("kw", "select")
        distinct = self.accept("kw", "distinct") is not None
        select_items = self._select_list()

        self.expect("kw", "from")
        plan = self._from_clause()

        where = None
        if self.accept("kw", "where"):
            where = self._expr()
        group_keys: list[str] = []
        if self.accept("kw", "group"):
            self.expect("kw", "by")
            group_keys = self._name_list()
        having = None
        if self.accept("kw", "having"):
            having = self._expr()
        order = []
        if self.accept("kw", "order"):
            self.expect("kw", "by")
            order = self._order_list(select_items)
        limit = None
        if self.accept("kw", "limit"):
            limit = int(self.expect("num").text)
        if self.peek() is not None:
            raise SQLError(f"trailing tokens: {self.peek()}")

        # lift comma-join equi conditions out of WHERE
        if where is not None:
            plan, where = self._lift_joins(plan, where)
        if where is not None:
            plan = FilterNode(plan, where)

        # expand SELECT * against the (joined) FROM relation
        if self._star:
            star_items = [(Col(c), c)
                          for c in plan.output_columns(self.catalog)]
            select_items = star_items + select_items

        # aggregates: rewrite agg calls into synthetic columns
        rewritten = []
        self._agg_specs = []
        for expr, name in select_items:
            rewritten.append((self._extract_aggs(expr), name))
        having_rw = self._extract_aggs(having) if having is not None else None

        if self._agg_specs or group_keys:
            plan = AggregateNode(plan, tuple(group_keys),
                                 tuple(self._agg_specs))
            if having_rw is not None:
                # HAVING sits between aggregation and projection: it may
                # reference aggregates that the SELECT list drops.
                plan = FilterNode(plan, having_rw)
            plan = ProjectNode(plan, tuple(rewritten))
        else:
            plan = ProjectNode(plan, tuple(rewritten))
            if distinct:
                names = [n for _, n in rewritten]
                plan = AggregateNode(plan, tuple(names), ())

        if order:
            plan = OrderByNode(plan, tuple(order), limit)
        elif limit is not None:
            plan = LimitNode(plan, limit)
        return plan

    # -- clauses ---------------------------------------------------------------
    def _select_list(self):
        items = []
        while True:
            if self.accept("op", "*"):
                items.append(("*", "*"))
            else:
                e = self._expr()
                name = None
                if self.accept("kw", "as"):
                    name = self.next().text
                elif self.peek() is not None and self.peek().kind == "name":
                    name = self.next().text
                if name is None:
                    name = _default_name(e, len(items))
                items.append((e, name))
            if not self.accept("op", ","):
                break
        # expand * lazily once the FROM relation is known
        self._star = any(isinstance(e, str) and e == "*" for e, _ in items)
        return [it for it in items if not (isinstance(it[0], str))]

    def _table_ref(self) -> tuple[PlanNode, str]:
        name = self.expect("name").text
        if name not in self.catalog.tables:
            raise SQLError(f"unknown table {name!r}")
        alias = None
        t = self.peek()
        if t is not None and t.kind == "name":
            alias = self.next().text
        self.alias_to_table[alias or name] = name
        return ScanNode(name), name

    def _from_clause(self) -> PlanNode:
        plan, _ = self._table_ref()
        while True:
            if self.accept("op", ","):
                right, _ = self._table_ref()
                # cartesian placeholder: joined later via WHERE equi-conds
                plan = _PendingCross(plan, right)
            elif self.peek() is not None and self.peek().kind == "kw" \
                    and self.peek().text in ("join", "inner", "left"):
                how = "inner"
                if self.accept("kw", "left"):
                    self.accept("kw", "outer")
                    how = "left"
                else:
                    self.accept("kw", "inner")
                self.expect("kw", "join")
                right, _ = self._table_ref()
                self.expect("kw", "on")
                cond = self._expr()
                lk, rk = self._equi_keys(cond, plan, right)
                plan = JoinNode(plan, right, tuple(lk), tuple(rk), how)
            else:
                break
        return plan

    def _equi_keys(self, cond: Expr, left: PlanNode, right: PlanNode):
        lcols = set(left.output_columns(self.catalog)) \
            if not isinstance(left, _PendingCross) else set(_cross_cols(left, self.catalog))
        rcols = set(right.output_columns(self.catalog))
        lk, rk = [], []
        for c in _conjuncts(cond):
            if isinstance(c, BinOp) and c.op == "=" \
                    and isinstance(c.left, Col) and isinstance(c.right, Col):
                a, b = c.left.name, c.right.name
                if a in lcols and b in rcols:
                    lk.append(a)
                    rk.append(b)
                    continue
                if b in lcols and a in rcols:
                    lk.append(b)
                    rk.append(a)
                    continue
            raise SQLError(f"unsupported join condition: {c!r}")
        return lk, rk

    def _lift_joins(self, plan: PlanNode, where: Expr):
        """Turn _PendingCross + WHERE equi-conds into explicit joins."""
        crosses = []
        base = plan
        while isinstance(base, _PendingCross):
            crosses.append(base.right)
            base = base.left
        if not crosses:
            return plan, where
        crosses.reverse()
        parts = [base] + crosses
        conds = _conjuncts(where)
        joins, rest = [], []
        for c in conds:
            if isinstance(c, BinOp) and c.op == "=" \
                    and isinstance(c.left, Col) and isinstance(c.right, Col):
                joins.append(c)
            else:
                rest.append(c)
        current = parts.pop(0)
        cur_cols = set(current.output_columns(self.catalog))
        progress = True
        while parts and progress:
            progress = False
            for p in list(parts):
                pcols = set(p.output_columns(self.catalog))
                lk, rk, used = [], [], []
                for c in joins:
                    a, b = c.left.name, c.right.name
                    if a in cur_cols and b in pcols:
                        lk.append(a); rk.append(b); used.append(c)
                    elif b in cur_cols and a in pcols:
                        lk.append(b); rk.append(a); used.append(c)
                if lk:
                    current = JoinNode(current, p, tuple(lk), tuple(rk),
                                       "inner")
                    cur_cols |= pcols
                    parts.remove(p)
                    for c in used:
                        joins.remove(c)
                    progress = True
        if parts:
            raise SQLError("comma-joined tables without join condition "
                           "(cartesian products unsupported)")
        rest.extend(joins)   # join conds between same side fall back to filter
        where_rest = None
        if rest:
            where_rest = rest[0]
            for c in rest[1:]:
                where_rest = BinOp("and", where_rest, c)
        return current, where_rest

    def _name_list(self) -> list[str]:
        out = [self._qualified_name()]
        while self.accept("op", ","):
            out.append(self._qualified_name())
        return out

    def _order_list(self, select_items):
        out = []
        while True:
            name = self._qualified_name()
            desc = False
            if self.accept("kw", "desc"):
                desc = True
            else:
                self.accept("kw", "asc")
            out.append((name, desc))
            if not self.accept("op", ","):
                break
        return out

    def _qualified_name(self) -> str:
        n = self.expect("name").text
        if self.accept("op", "."):
            n = self.expect("name").text    # alias.col -> col
        return n

    # -- aggregate extraction ---------------------------------------------------
    def _extract_aggs(self, e):
        if isinstance(e, _AggCall):
            self._agg_ctr += 1
            name = f"__agg{self._agg_ctr}"
            self._agg_specs.append(AggSpec(e.fn, e.arg, name))
            return Col(name)
        if isinstance(e, BinOp):
            return BinOp(e.op, self._extract_aggs(e.left),
                         self._extract_aggs(e.right))
        if isinstance(e, Not):
            return Not(self._extract_aggs(e.child))
        if isinstance(e, Cast):
            return Cast(self._extract_aggs(e.child), e.to)
        if isinstance(e, Func):
            f = Func.__new__(Func)
            f.name = e.name
            f.args = tuple(self._extract_aggs(a) for a in e.args)
            return f
        if isinstance(e, Case):
            return Case(tuple((self._extract_aggs(c), self._extract_aggs(v))
                              for c, v in e.branches),
                        self._extract_aggs(e.default))
        return e

    # -- expressions (precedence climbing) ---------------------------------------
    def _expr(self) -> Expr:
        return self._or()

    def _or(self) -> Expr:
        e = self._and()
        while self.accept("kw", "or"):
            e = BinOp("or", e, self._and())
        return e

    def _and(self) -> Expr:
        e = self._not()
        while self.accept("kw", "and"):
            e = BinOp("and", e, self._not())
        return e

    def _not(self) -> Expr:
        if self.accept("kw", "not"):
            return Not(self._not())
        return self._comparison()

    def _comparison(self) -> Expr:
        e = self._additive()
        t = self.peek()
        if t is None:
            return e
        if t.kind == "op" and t.text in ("=", "<>", "!=", "<", "<=", ">", ">="):
            op = self.next().text
            if op == "!=":
                op = "<>"
            return BinOp(op, e, self._additive())
        if t.kind == "kw" and t.text == "between":
            self.next()
            lo = self._additive()
            self.expect("kw", "and")
            hi = self._additive()
            return BinOp("and", BinOp(">=", e, lo), BinOp("<=", e, hi))
        if t.kind == "kw" and t.text == "in":
            self.next()
            self.expect("op", "(")
            vals = [self._literal_value()]
            while self.accept("op", ","):
                vals.append(self._literal_value())
            self.expect("op", ")")
            return InList(e, vals)
        if t.kind == "kw" and t.text == "like":
            self.next()
            pat = self.expect("str").text
            return Like(e, pat)
        if t.kind == "kw" and t.text == "not":
            nxt = self.peek(1)
            if nxt is not None and nxt.text in ("like", "in", "between"):
                self.next()
                inner_tok = self.peek().text
                inner = self._comparison_tail(e, inner_tok)
                return Not(inner)
        if t.kind == "kw" and t.text == "is":
            self.next()
            neg = self.accept("kw", "not") is not None
            self.expect("kw", "null")
            return IsNull(e, negate=neg)
        return e

    def _comparison_tail(self, e: Expr, which: str) -> Expr:
        if which == "like":
            self.expect("kw", "like")
            return Like(e, self.expect("str").text)
        if which == "in":
            self.expect("kw", "in")
            self.expect("op", "(")
            vals = [self._literal_value()]
            while self.accept("op", ","):
                vals.append(self._literal_value())
            self.expect("op", ")")
            return InList(e, vals)
        self.expect("kw", "between")
        lo = self._additive()
        self.expect("kw", "and")
        hi = self._additive()
        return BinOp("and", BinOp(">=", e, lo), BinOp("<=", e, hi))

    def _literal_value(self):
        t = self.next()
        if t.kind == "num":
            return float(t.text) if "." in t.text else int(t.text)
        if t.kind == "str":
            return t.text
        raise SQLError(f"expected literal, got {t}")

    def _additive(self) -> Expr:
        e = self._multiplicative()
        while True:
            t = self.peek()
            if t is not None and t.kind == "op" and t.text in ("+", "-"):
                op = self.next().text
                e = BinOp(op, e, self._multiplicative())
            else:
                return e

    def _multiplicative(self) -> Expr:
        e = self._unary()
        while True:
            t = self.peek()
            if t is not None and t.kind == "op" and t.text in ("*", "/", "%"):
                op = self.next().text
                e = BinOp(op, e, self._unary())
            else:
                return e

    def _unary(self) -> Expr:
        if self.accept("op", "-"):
            return BinOp("-", Lit(0), self._unary())
        if self.accept("op", "+"):
            return self._unary()
        return self._primary()

    def _primary(self) -> Expr:
        t = self.peek()
        if t is None:
            raise SQLError("unexpected end in expression")
        if t.kind == "num":
            self.next()
            return Lit(float(t.text) if "." in t.text else int(t.text))
        if t.kind == "str":
            self.next()
            return Lit(t.text)
        if t.kind == "op" and t.text == "(":
            self.next()
            e = self._expr()
            self.expect("op", ")")
            return e
        if t.kind == "kw":
            if t.text == "date":
                self.next()
                return DateLit(self.expect("str").text)
            if t.text == "null":
                self.next()
                return Lit(None)
            if t.text in ("true", "false"):
                self.next()
                return Lit(t.text == "true")
            if t.text == "case":
                return self._case()
            if t.text == "cast":
                self.next()
                self.expect("op", "(")
                e = self._expr()
                self.expect("kw", "as")
                tname = self.next().text.lower()
                self.expect("op", ")")
                tmap = {"int": DBType.INT64, "integer": DBType.INT64,
                        "bigint": DBType.INT64, "float": DBType.FLOAT64,
                        "double": DBType.FLOAT64, "date": DBType.DATE}
                return Cast(e, tmap[tname])
            if t.text == "extract":
                self.next()
                self.expect("op", "(")
                self.expect("kw", "year")
                self.expect("kw", "from")
                e = self._expr()
                self.expect("op", ")")
                return Func("year", e)
            raise SQLError(f"unexpected keyword {t.text!r} in expression")
        # name: column, function call, aggregate
        name = self.next().text
        if self.accept("op", "("):
            low = name.lower()
            if low in _AGG_NAMES:
                if low == "count" and self.accept("op", "*"):
                    self.expect("op", ")")
                    return _AggCall("count", None)
                distinct = self.accept("kw", "distinct") is not None
                arg = self._expr()
                self.expect("op", ")")
                fn = _AGG_MAP.get(low, low)
                if distinct:
                    if fn != "count":
                        raise SQLError("DISTINCT only with COUNT")
                    fn = "count_distinct"
                return _AggCall(fn, arg)
            args = []
            if not self.accept("op", ")"):
                args.append(self._expr())
                while self.accept("op", ","):
                    args.append(self._expr())
                self.expect("op", ")")
            return Func(name, *args)
        if self.accept("op", "."):
            col = self.expect("name").text
            return Col(col)            # alias.col -> col (globally unique)
        return Col(name)

    def _case(self) -> Expr:
        self.expect("kw", "case")
        branches = []
        while self.accept("kw", "when"):
            c = self._expr()
            self.expect("kw", "then")
            v = self._expr()
            branches.append((c, v))
        default = Lit(None)
        if self.accept("kw", "else"):
            default = self._expr()
        self.expect("kw", "end")
        return Case(tuple(branches), default)


@dataclass(eq=False)
class _AggCall(Expr):
    fn: str
    arg: Optional[Expr]

    def columns(self):
        return self.arg.columns() if self.arg is not None else set()


@dataclass
class _PendingCross(PlanNode):
    left: PlanNode
    right: PlanNode

    @property
    def children(self):
        return (self.left, self.right)

    def output_columns(self, catalog):
        return _cross_cols(self, catalog)

    def with_children(self, children):
        return _PendingCross(children[0], children[1])


def _cross_cols(n: PlanNode, catalog) -> list[str]:
    if isinstance(n, _PendingCross):
        return _cross_cols(n.left, catalog) + _cross_cols(n.right, catalog)
    return n.output_columns(catalog)


def _conjuncts(e: Expr) -> list[Expr]:
    if isinstance(e, BinOp) and e.op == "and":
        return _conjuncts(e.left) + _conjuncts(e.right)
    return [e]


def _default_name(e, i: int) -> str:
    if isinstance(e, Col):
        return e.name
    if isinstance(e, _AggCall):
        return f"{e.fn}_{e.arg.name}" if isinstance(e.arg, Col) else e.fn
    return f"col{i}"


_CREATE_ORDER_RE = re.compile(
    r"^\s*create\s+order\s+index\s+(?:\w+\s+)?on\s+"
    r"(\w+)\s*\(\s*(\w+)\s*\)\s*;?\s*$", re.IGNORECASE)


def parse_statement(sql: str):
    """Statement router: returns ("query", plan_fn) or
    ("create_order_index", table, column) — the paper's §3.1 CREATE ORDER
    INDEX statement is a DDL statement, not a query."""
    m = _CREATE_ORDER_RE.match(sql)
    if m:
        return ("create_order_index", m.group(1), m.group(2))
    return ("query", None, None)


def parse_sql(sql: str, catalog) -> PlanNode:
    return Parser(tokenize(sql), catalog).parse_query()
