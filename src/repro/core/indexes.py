"""Automatic indexing (paper §3.1): imprints, hash/order indexes.

* **Imprints** — per-block zone maps.  MonetDB's imprints are per-cache-line
  bitmaps; the TPU adaptation (DESIGN.md §3) builds min/max + a 16-bin
  presence bitmap per 2048-row block (the VMEM tile granularity), built by
  the ``kernels/imprint`` Pallas kernel.  Range selections consult the zone
  maps and skip non-qualifying blocks entirely.
* **Order index** — an argsort permutation (paper: CREATE ORDER INDEX).  It
  answers point/range queries by binary search and turns equi-joins into
  merge joins.  We also *auto-create* it on join/group keys of base tables,
  playing the role of the paper's automatically-built hash tables (on TPU a
  sorted permutation + binary search is the hash-table idiom; see DESIGN.md).
* Lifecycle follows the paper: built on first qualifying use, cached,
  persisted by storage.py, and **invalidated on column modification** —
  except order indexes on append, which are incrementally merged (the paper
  updates hash tables on appends).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from .column import Column
from .types import DBType, is_float

IMPRINT_BLOCK = 2048          # rows per zone-map block (VMEM tile multiple)
IMPRINT_BINS = 16
AUTO_ORDER_MIN_ROWS = 1024    # don't index tiny columns (paper: heuristics)


@dataclass
class Imprint:
    block: int
    mins: np.ndarray          # (n_blocks,) float64
    maxs: np.ndarray          # (n_blocks,) float64
    bitmaps: np.ndarray       # (n_blocks,) uint16 presence bitmap
    lo: float                 # histogram range for the bitmap bins
    hi: float
    n_rows: int

    def candidate_blocks(self, lo: float, hi: float,
                         lo_strict: bool, hi_strict: bool) -> np.ndarray:
        """Boolean per-block: may this block contain values in [lo, hi]?"""
        ok_lo = (self.maxs > lo) if lo_strict else (self.maxs >= lo)
        ok_hi = (self.mins < hi) if hi_strict else (self.mins <= hi)
        cand = ok_lo & ok_hi
        # refine with the presence bitmap for equality/narrow ranges
        if np.isfinite(lo) and np.isfinite(hi) and self.hi > self.lo:
            b0 = int(np.clip((lo - self.lo) / (self.hi - self.lo)
                             * IMPRINT_BINS, 0, IMPRINT_BINS - 1))
            b1 = int(np.clip((hi - self.lo) / (self.hi - self.lo)
                             * IMPRINT_BINS, 0, IMPRINT_BINS - 1))
            want = np.uint16(0)
            for b in range(b0, b1 + 1):
                want |= np.uint16(1 << b)
            cand &= (self.bitmaps & want) != 0
        return cand


def build_imprint(col: Column) -> Optional[Imprint]:
    """Zone maps for a numeric/date/decimal column (kernel-built when the
    Pallas path is enabled; numpy fallback mirrors ref.py)."""
    if col.dbtype == DBType.VARCHAR or col.dbtype == DBType.BOOL:
        return None
    from ..kernels.imprint import ops as imprint_ops
    v = np.asarray(col.data)
    if col.dbtype == DBType.DECIMAL:
        f = v.astype(np.float64) / (10 ** col.scale)
    else:
        f = v.astype(np.float64)
    if is_float(col.dbtype):
        nulls = np.isnan(f)
    else:
        from .types import NULL_SENTINEL
        nulls = v == NULL_SENTINEL[col.dbtype]
    mins, maxs, bitmaps, lo, hi = imprint_ops.build_zone_maps(
        f, nulls, IMPRINT_BLOCK, IMPRINT_BINS)
    return Imprint(IMPRINT_BLOCK, mins, maxs, bitmaps, lo, hi, len(v))


def _extend_imprint(imp: Imprint, t, cname: str) -> Optional[Imprint]:
    """Extend zone maps over appended rows without touching complete blocks.

    Recomputes only blocks covering rows ``[floor(prev/block)*block, n)``.
    Appended values are binned against the ORIGINAL ``(lo, hi)`` histogram
    range with clipping — the same monotone transform ``candidate_blocks``
    applies to query bounds — so the presence bitmap stays a superset of
    the truth and pruning stays sound even when new values fall outside the
    old range (mins/maxs stay exact either way)."""
    try:
        cs = t.schema.column(cname)
    except KeyError:
        return None
    if cs.dbtype == DBType.VARCHAR or cs.dbtype == DBType.BOOL:
        return None
    n_rows = t.num_rows
    if n_rows < imp.n_rows:
        return None
    keep = imp.n_rows // imp.block          # complete, untouched blocks
    start = keep * imp.block
    v = np.asarray(t.tail_array(cname, start))
    if cs.dbtype == DBType.DECIMAL:
        f = v.astype(np.float64) / (10 ** cs.scale)
    else:
        f = v.astype(np.float64)
    if is_float(cs.dbtype):
        nulls = np.isnan(f)
    else:
        from .types import NULL_SENTINEL
        nulls = v == NULL_SENTINEL[cs.dbtype]
    inv = (IMPRINT_BINS / (imp.hi - imp.lo)) if imp.hi > imp.lo else 0.0
    nb_new = max(1, -(-len(v) // imp.block)) if len(v) else 0
    mins = np.full(nb_new, np.inf)
    maxs = np.full(nb_new, -np.inf)
    bitmaps = np.zeros(nb_new, dtype=np.uint16)
    for b in range(nb_new):
        s, e = b * imp.block, min((b + 1) * imp.block, len(v))
        ok = ~nulls[s:e]
        vv = f[s:e][ok]
        if vv.size:
            mins[b] = vv.min()
            maxs[b] = vv.max()
            if inv > 0:
                bins = np.clip(((vv - imp.lo) * inv).astype(np.int64),
                               0, IMPRINT_BINS - 1)
                bitmaps[b] = np.bitwise_or.reduce(
                    (1 << bins).astype(np.uint16))
            else:
                bitmaps[b] = 1
    return Imprint(imp.block,
                   np.concatenate([imp.mins[:keep], mins]),
                   np.concatenate([imp.maxs[:keep], maxs]),
                   np.concatenate([imp.bitmaps[:keep], bitmaps]),
                   imp.lo, imp.hi, n_rows)


@dataclass
class IndexManager:
    """Per-database index cache keyed by (table, column, table_version)."""
    database: object
    imprints: dict = field(default_factory=dict)
    order_indexes: dict = field(default_factory=dict)
    stats_hits: int = 0
    stats_built: int = 0

    # -- invalidation --------------------------------------------------------
    def invalidate_table(self, table: str) -> None:
        self.imprints = {k: v for k, v in self.imprints.items()
                         if k[0] != table}
        self.order_indexes = {k: v for k, v in self.order_indexes.items()
                              if k[0] != table}

    def on_append(self, table: str) -> None:
        """Append lifecycle: imprints are *extended*, not destroyed.

        Every append path preserves the existing row prefix (delta chunks
        by construction; numeric columns under a VARCHAR-forced rebase are
        still pure concatenations), so zone maps for blocks fully inside
        the old prefix remain exact — only the trailing (possibly partial)
        block and the new tail are recomputed, an O(delta rows) update read
        through ``tail_array`` so the delta tail never forces a merge.
        Order indexes still rebuild lazily (the paper's contract).  Replaces
        and drops go through ``invalidate_table`` instead."""
        t = self.database.catalog.tables.get(table)
        extended = {}
        if t is not None:
            for (tb, cname, ver), imp in self.imprints.items():
                if tb != table or imp is None or ver >= t.version:
                    continue
                ext = _extend_imprint(imp, t, cname)
                if ext is not None:
                    extended[(table, cname, t.version)] = ext
        self.invalidate_table(table)
        self.imprints.update(extended)

    # -- imprints -------------------------------------------------------------
    def _key(self, table: str, column: str):
        t = self.database.catalog.table(table)
        return (table, column, t.version)

    def get_imprint(self, table: str, column: str) -> Optional[Imprint]:
        key = self._key(table, column)
        if key not in self.imprints:
            col = self.database.catalog.table(table).column(column)
            if len(col) < AUTO_ORDER_MIN_ROWS:
                return None
            self.imprints[key] = build_imprint(col)
            self.stats_built += 1
        return self.imprints[key]

    def imprint_mask(self, table: str, column: str, lo: float, hi: float,
                     lo_strict: bool, hi_strict: bool):
        """Range-select through zone maps.  Returns (mask, blocks_skipped)
        or None when no imprint applies."""
        imp = self.get_imprint(table, column)
        if imp is None:
            return None
        self.stats_hits += 1
        col = self.database.catalog.table(table).column(column)
        v = np.asarray(col.data)
        if col.dbtype == DBType.DECIMAL:
            f = v.astype(np.float64) / (10 ** col.scale)
        else:
            f = v.astype(np.float64)
        cand = imp.candidate_blocks(lo, hi, lo_strict, hi_strict)
        mask = np.zeros(len(v), dtype=bool)
        nb = len(cand)
        skipped = int((~cand).sum())
        for b in np.nonzero(cand)[0]:
            s, e = b * imp.block, min((b + 1) * imp.block, len(v))
            fv = f[s:e]
            m = np.ones(e - s, dtype=bool)
            m &= (fv > lo) if lo_strict else (fv >= lo)
            m &= (fv < hi) if hi_strict else (fv <= hi)
            if is_float(col.dbtype):
                m &= ~np.isnan(fv)
            else:
                # NULL sentinel (INT64_MIN) satisfies open lower bounds like
                # ``col < x`` (lo = -inf); SQL comparisons reject NULL.
                from .types import NULL_SENTINEL
                m &= v[s:e] != NULL_SENTINEL[col.dbtype]
            mask[s:e] = m
        return mask, skipped

    def candidate_info(self, table: str, column: str, lo: float, hi: float,
                       lo_strict: bool, hi_strict: bool):
        """Planning-side zone-map probe: per-block candidate bitmap without
        materializing a row mask.  Returns (cand, block_rows, n_rows) or
        None when no imprint applies (small/VARCHAR/BOOL columns)."""
        imp = self.get_imprint(table, column)
        if imp is None:
            return None
        self.stats_hits += 1
        cand = imp.candidate_blocks(lo, hi, lo_strict, hi_strict)
        return cand, imp.block, imp.n_rows

    # -- order index ----------------------------------------------------------
    def create_order_index(self, table: str, column: str) -> np.ndarray:
        """Explicit CREATE ORDER INDEX (paper §3.1)."""
        key = self._key(table, column)
        if key not in self.order_indexes:
            col = self.database.catalog.table(table).column(column)
            self.order_indexes[key] = np.argsort(
                np.asarray(col.data), kind="stable").astype(np.int64)
            self.stats_built += 1
        return self.order_indexes[key]

    def get_order_index(self, table: str, column: str) -> Optional[np.ndarray]:
        return self.order_indexes.get(self._key(table, column))

    def auto_order_index(self, table: str, column: str,
                         probe_codes: np.ndarray) -> Optional[np.ndarray]:
        """Auto-create on join-key use (paper's auto hash tables).

        Only valid when the join ran on raw column codes — i.e. the build
        side is a single non-VARCHAR key whose factorized codes are
        order-isomorphic to the raw values.  We verify applicability by
        checking the column is numeric and unfiltered (caller guarantees),
        then return the permutation that sorts the *codes*, which equals the
        permutation sorting the raw values because factorization through
        np.unique is monotone."""
        t = self.database.catalog.table(table)
        col = t.column(column)
        if col.dbtype == DBType.VARCHAR:
            return None   # cross-heap factorization need not be monotone
        if len(col) < AUTO_ORDER_MIN_ROWS or len(probe_codes) != len(col):
            return None
        perm = self.create_order_index(table, column)
        return perm

    # -- point lookup through order index (binary search; paper §3.1) --------
    def point_lookup(self, table: str, column: str, value) -> np.ndarray:
        perm = self.create_order_index(table, column)
        col = self.database.catalog.table(table).column(column)
        v = np.asarray(col.data)[perm]
        lo = np.searchsorted(v, value, "left")
        hi = np.searchsorted(v, value, "right")
        return perm[lo:hi]
