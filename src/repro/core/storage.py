"""Persistence: memory-mapped column files, JSON catalog, append WAL.

Persistent mode (paper §3.2): a database directory holds one binary file per
column version, mapped back in with ``np.memmap`` on load — the host-tier
analogue of MonetDB keeping columns as memory-mapped files and letting the
OS page them (paper §3.1 "Memory Management").  In-memory mode never touches
this module.

Durability contract: ``monetdb_append``-style bulk appends go to a WAL
(one npz per append + a JSONL manifest) and are replayed on open; an
explicit ``checkpoint`` folds them into fresh column files and truncates the
WAL.  All file replacements are atomic (write-new + rename).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile

import numpy as np

from .column import Column, StringHeap
from .table import Table
from .types import ColumnSchema, DBType, TableSchema

CATALOG = "catalog.json"
DATA_DIR = "data"
WAL_DIR = "wal"
SPILL_DIR = "spill"    # out-of-core run files live under the db directory
FORMAT_VERSION = 2     # bumped on layout change; loader upgrades old dbs

# Morsel granularity for streaming scans: column files are memory-mapped, so
# a query that consumes them morsel-by-morsel (the spill tier, partitioning
# passes) never forces the whole base table resident — the OS pages each
# morsel-sized window in and out (paper §3.1 "Memory Management").
MORSEL_ROWS = 1 << 16


def morsel_ranges(n: int, morsel_rows: int = MORSEL_ROWS):
    """Yield (start, end) row ranges covering ``n`` rows morsel-by-morsel."""
    step = max(1, int(morsel_rows))
    for s in range(0, int(n), step):
        yield s, min(s + step, int(n))


def iter_morsels(arr, morsel_rows: int = MORSEL_ROWS):
    """Stream an array (typically an ``np.memmap`` column) in morsel-sized
    windows; each yield is a zero-copy view of the mapped file."""
    for s, e in morsel_ranges(len(arr), morsel_rows):
        yield arr[s:e]


def _fsync_dir(path: str) -> None:
    """Flush a directory entry (the rename itself) to stable storage;
    best-effort on filesystems that refuse O_RDONLY dir fsync."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _atomic_write(path: str, write_fn) -> None:
    """Write-new + fsync + rename + dir-fsync: after this returns, a crash
    at any point leaves either the old file or the complete new one — the
    temp file's *contents* are durable before the rename makes them
    visible, and the rename is durable before callers (e.g. the catalog
    pointing at fresh column files) build on it."""
    d = os.path.dirname(path)
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d)
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        _fsync_dir(d)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _col_file(table: str, col: str, version: int) -> str:
    return f"{DATA_DIR}/{table}.{col}.v{version}.bin"


def _heap_file(table: str, col: str, version: int) -> str:
    return f"{DATA_DIR}/{table}.{col}.v{version}.heap.json"


def save_table(root: str, table: Table) -> dict:
    """Write all columns of one table version; returns catalog entry.

    A column whose host array is already the memmap of its own target file
    (the streamed-compaction path writes and adopts ``<table>.<col>.v<N>.bin``
    directly) is durable as written — skip the byte rewrite so a checkpoint
    right after compaction costs no second O(table) pass."""
    cols_meta = []
    for cs in table.schema.columns:
        col = table.columns[cs.name]
        rel = _col_file(table.name, cs.name, table.version)
        target = os.path.join(root, rel)
        fn = getattr(col.data, "filename", None)
        on_disk = (isinstance(col.data, np.memmap) and fn is not None
                   and os.path.abspath(fn) == os.path.abspath(target))
        if not on_disk:
            _atomic_write(target,
                          lambda f, c=col: f.write(
                              np.ascontiguousarray(c.data).tobytes()))
        entry = {"name": cs.name, "type": cs.dbtype.value,
                 "scale": cs.scale, "file": rel}
        if col.heap is not None:
            hrel = _heap_file(table.name, cs.name, table.version)
            hpath = os.path.join(root, hrel)
            if not (on_disk and os.path.exists(hpath)):
                payload = json.dumps(
                    [str(v) for v in col.heap.values]).encode()
                _atomic_write(hpath, lambda f, p=payload: f.write(p))
            entry["heap"] = hrel
        cols_meta.append(entry)
    return {"version": table.version, "nrows": table.num_rows,
            "columns": cols_meta}


def load_table(root: str, name: str, meta: dict) -> Table:
    cols: dict[str, Column] = {}
    schemas = []
    for cm in meta["columns"]:
        t = DBType(cm["type"])
        from .types import STORAGE_DTYPE
        data = np.memmap(os.path.join(root, cm["file"]),
                         dtype=STORAGE_DTYPE[t], mode="r")
        heap = None
        if "heap" in cm:
            with open(os.path.join(root, cm["heap"])) as f:
                vals = json.load(f)
            hv = np.empty(len(vals), dtype=object)
            hv[:] = vals
            heap = StringHeap(hv)
        cols[cm["name"]] = Column(t, data, heap=heap, scale=cm["scale"])
        schemas.append(ColumnSchema(cm["name"], t, scale=cm["scale"]))
    return Table(TableSchema(name, tuple(schemas)), cols,
                 version=meta["version"])


class Storage:
    """Directory-backed persistence for one database."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, DATA_DIR), exist_ok=True)
        os.makedirs(os.path.join(root, WAL_DIR), exist_ok=True)
        self._wal_seq = 0
        self._locked = False
        self._lock_fd = -1

    # -- on-disk lock --------------------------------------------------------
    def acquire_lock(self) -> None:
        """Single-owner directory lock (paper §3.2 "database locked"), held
        *across processes* via flock(2) on ``<root>/LOCK`` — the in-process
        registry in session.py only sees this process.  The kernel
        arbitrates concurrent opens atomically, conflicts are detected even
        through symlink aliases of the directory, and a crashed owner's
        lock evaporates with its file descriptors — so no stale-pid
        takeover protocol exists to race on, and ``reclaim_spill`` can
        never destroy a live owner's run files.  The pid written inside is
        informational (error messages only)."""
        import fcntl
        path = os.path.join(self.root, "LOCK")
        fd = os.open(path, os.O_CREAT | os.O_RDWR, 0o644)
        try:
            fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
        except OSError:
            try:
                owner = os.read(fd, 64).decode(errors="replace").strip()
            except OSError:
                owner = ""
            os.close(fd)
            raise RuntimeError(
                f"database locked by process {owner or '?'}: {self.root}")
        try:
            os.ftruncate(fd, 0)
            os.write(fd, str(os.getpid()).encode())
        except OSError:
            # the pid note is informational; a failure writing it must not
            # leak the fd (closing it also drops the flock we just took)
            os.close(fd)
            raise
        self._lock_fd = fd
        self._locked = True

    def release_lock(self) -> None:
        """Closing the fd drops the flock — that *is* the release.  The
        LOCK file itself is never unlinked: removing it would let one
        waiter lock the ghost inode while another locks a fresh file, and
        both believe they own the directory."""
        if not self._locked:
            return
        self._locked = False
        try:
            os.close(self._lock_fd)
        except OSError:
            pass

    def spill_path(self) -> str:
        """Directory for out-of-core run files (created lazily by the
        buffer manager; cleared on shutdown)."""
        return os.path.join(self.root, SPILL_DIR)

    def reclaim_spill(self) -> None:
        """Delete stale run files left by a crashed process.  Called at
        database open *after* ``acquire_lock`` succeeded, so no live
        instance — in this process or any other — can own files here."""
        d = self.spill_path()
        if not os.path.isdir(d):
            return
        for name in os.listdir(d):
            try:
                os.unlink(os.path.join(d, name))
            except OSError:
                pass

    # -- streamed column writes (delta compaction) ---------------------------
    def write_column_pieces(self, table: str, col: str, version: int,
                            pieces: list, bufman=None) -> np.memmap:
        """Stream ``pieces`` (base array first, then delta chunks) into the
        versioned column file morsel-by-morsel and adopt the result as a
        read-only memmap.  Peak memory is one morsel (pinned through
        ``bufman`` when given), so compacting a table far larger than the
        memory budget never materializes it."""
        rel = _col_file(table, col, version)
        path = os.path.join(self.root, rel)
        dtype = pieces[0].dtype
        # budget-aware morsel: the pinned streaming window must fit the
        # SAME budget the ingest loop pins its pieces against, or a
        # compaction fired mid-ingest would blow `peak <= budget`
        from .buffers import choose_morsel_rows
        rows = choose_morsel_rows(int(dtype.itemsize),
                                  None if bufman is None else bufman.budget,
                                  default=MORSEL_ROWS)
        morsel_bytes = rows * int(dtype.itemsize)

        def _write(f):
            for arr in pieces:
                for s in range(0, len(arr), rows):
                    f.write(np.ascontiguousarray(
                        arr[s:s + rows]).tobytes())

        if bufman is not None:
            with bufman.pinned(morsel_bytes):
                _atomic_write(path, _write)
        else:
            _atomic_write(path, _write)
        return np.memmap(path, dtype=dtype, mode="r")

    # -- catalog -------------------------------------------------------------
    def write_catalog(self, tables: dict[str, Table]) -> None:
        cat = {"format": FORMAT_VERSION,
               "tables": {name: save_table(self.root, t)
                          for name, t in tables.items()}}
        _atomic_write(os.path.join(self.root, CATALOG),
                      lambda f: f.write(json.dumps(cat, indent=1).encode()))
        self._truncate_wal()
        self._sweep_stale_versions(cat)

    def _sweep_stale_versions(self, cat: dict) -> None:
        """Garbage-collect superseded column versions: after a successful
        catalog write, delete every ``data/`` file the new catalog no
        longer references (old ``*.v<N>.bin``/``*.heap.json`` versions) —
        otherwise the directory grows without bound across checkpoints.
        Safe while old versions are still memory-mapped in this process:
        POSIX keeps the unlinked inode alive until the maps go away."""
        keep = set()
        for meta in cat["tables"].values():
            for cm in meta["columns"]:
                keep.add(cm["file"])
                if "heap" in cm:
                    keep.add(cm["heap"])
        d = os.path.join(self.root, DATA_DIR)
        for name in os.listdir(d):
            if f"{DATA_DIR}/{name}" in keep:
                continue
            try:
                os.unlink(os.path.join(d, name))
            except OSError:
                pass

    def has_catalog(self) -> bool:
        return os.path.exists(os.path.join(self.root, CATALOG))

    def load(self) -> dict[str, Table]:
        path = os.path.join(self.root, CATALOG)
        with open(path) as f:
            cat = json.load(f)
        if cat.get("format", 1) > FORMAT_VERSION:
            raise RuntimeError(
                f"database created by a newer version ({cat['format']})")
        tables = {name: load_table(self.root, name, meta)
                  for name, meta in cat["tables"].items()}
        # crash recovery: replay WAL appends newer than the catalog.  Each
        # replayed chunk installs as a delta over the memmapped base (same
        # layout the crashed process had), so replay is O(delta rows) and
        # never forces the base columns resident.
        from .delta import delta_append
        for rec, arrays in self._read_wal():
            name = rec["table"]
            if name not in tables:
                continue
            chunk = _chunk_to_table(tables[name], arrays, rec)
            tables[name] = delta_append(tables[name], chunk)
        return tables

    # -- WAL -----------------------------------------------------------------
    def log_append(self, table: Table, chunk: Table) -> None:
        self._wal_seq += 1
        seq = self._wal_seq
        data_rel = f"{WAL_DIR}/{seq:08d}.npz"
        arrays = {}
        heaps = {}
        for cs in chunk.schema.columns:
            col = chunk.columns[cs.name]
            arrays[cs.name] = np.ascontiguousarray(col.data)
            if col.heap is not None:
                heaps[cs.name] = [str(v) for v in col.heap.values]
        _atomic_write(os.path.join(self.root, data_rel),
                      lambda f: np.savez(f, **arrays))
        rec = {"seq": seq, "table": table.name, "file": data_rel,
               "heaps": heaps,
               "types": {cs.name: cs.dbtype.value
                         for cs in chunk.schema.columns},
               "scales": {cs.name: cs.scale
                          for cs in chunk.schema.columns}}
        with open(os.path.join(self.root, WAL_DIR, "wal.jsonl"), "a") as f:
            f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def _read_wal(self):
        """Replayable WAL records, torn tails repaired.

        A crash can leave (a) a partial trailing manifest line — the append
        of the line itself was torn — or (b) a manifest entry whose npz
        never became durable (pre-fsync databases).  Both truncate replay
        to the longest consistent *prefix*: replaying past a hole would
        reorder appends relative to commit order.  When a tear was found
        the manifest is rewritten (atomically) to that prefix, so appends
        accepted after recovery stay reachable on the next replay instead
        of hiding behind a broken line."""
        manifest = os.path.join(self.root, WAL_DIR, "wal.jsonl")
        if not os.path.exists(manifest):
            return
        # cheap scan first: manifest lines + npz presence, no array loads —
        # replay memory stays one append's payload, as before
        entries = []                    # (line text, rec)
        torn = False
        with open(manifest) as f:
            for line in f:
                stripped = line.strip()
                if not stripped:
                    continue
                try:
                    rec = json.loads(stripped)
                    npz_path = os.path.join(self.root, rec["file"])
                except (json.JSONDecodeError, KeyError, TypeError):
                    torn = True         # partial trailing line
                    break
                if not os.path.exists(npz_path):
                    torn = True         # entry without its data: stop here
                    break
                entries.append((stripped, rec))
        # stream the payloads one append at a time; a truncated/zero-byte/
        # corrupt npz (np.load raises EOFError, BadZipFile or ValueError
        # depending on how much survived) is the same durability hole as a
        # missing one — everything already yielded is the consistent
        # prefix.  ONLY those corruption errors trigger the destructive
        # manifest repair: a transient I/O failure (OSError — fd limits,
        # network filesystems) propagates and fails the open instead of
        # permanently discarding durable appends.
        import zipfile
        good = 0
        for stripped, rec in entries:
            try:
                with np.load(os.path.join(self.root, rec["file"]),
                             allow_pickle=False) as z:
                    arrays = {k: z[k] for k in z.files}
            except (EOFError, ValueError, zipfile.BadZipFile):
                torn = True
                break
            good += 1
            self._wal_seq = max(self._wal_seq, rec["seq"])
            yield rec, arrays
        if torn:
            _atomic_write(manifest, lambda f: f.write(
                ("".join(ln + "\n" for ln, _ in entries[:good])).encode()))

    def _truncate_wal(self) -> None:
        wal = os.path.join(self.root, WAL_DIR)
        if os.path.isdir(wal):
            shutil.rmtree(wal)
        os.makedirs(wal, exist_ok=True)
        self._wal_seq = 0


def _chunk_to_table(base: Table, arrays: dict, rec: dict) -> Table:
    cols = {}
    schemas = []
    for cs in base.schema.columns:
        t = DBType(rec["types"][cs.name])
        data = arrays[cs.name]
        heap = None
        if cs.name in rec.get("heaps", {}):
            vals = rec["heaps"][cs.name]
            hv = np.empty(len(vals), dtype=object)
            hv[:] = vals
            heap = StringHeap(hv)
        cols[cs.name] = Column(t, data, heap=heap,
                               scale=rec["scales"][cs.name])
        schemas.append(ColumnSchema(cs.name, t, scale=rec["scales"][cs.name]))
    return Table(TableSchema(base.name, tuple(schemas)), cols)
