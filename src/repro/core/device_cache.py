"""Device-tier buffer manager: HBM as a budgeted cache over host memory.

The paper's central memory-management trick (§3.1) is treating one tier of
the hierarchy as a cache over the next — memory-mapped columns let the OS
page data larger than RAM.  PRs 1-3 built that host tier (``BufferManager``
+ ``spill.py``); this module is its HBM analogue, one level up: all
device-resident column blocks live under a ``device_budget`` byte budget,
so the sharded fast path can *stream* tables larger than accelerator memory
instead of declining them.

``DeviceBufferManager`` owns every device-resident block:

* **pin/unpin accounting** mirroring the host ``BufferManager``: blocks in
  use by a running query are pinned; ``device_bytes_peak`` (the high-water
  mark of tracked resident bytes) never exceeds the budget because room is
  made *before* a transfer is issued;
* **LRU eviction** of unpinned blocks when a new block needs room.  Clean
  blocks (base columns — the host copy is authoritative) are simply
  dropped; dirty blocks (query-produced intermediates, e.g. the partial-
  aggregate carry) are copied back to host first and transparently
  re-uploaded on next use;
* a **cross-query cache** keyed on ``(table, column, version, shard)``:
  repeated scans of the same column version skip the host→device transfer
  entirely (``device_cache_hits``, and ``device_bytes_h2d`` stays flat);
* **async prefetch** support: ``jax.device_put`` is non-blocking, so the
  execution tier (``parallel.DistributedScanAgg``) issues batch N+1's
  transfers while batch N computes.  ``put`` makes room by evicting
  *unpinned* blocks only and raises ``DeviceBudgetError`` when everything
  resident is pinned — the prefetcher stops issuing at that point, so
  double-buffering stays inside the budget exactly like the host tier's
  ``PartitionPrefetcher`` skips loads it cannot pin.

``budget=None`` (the default) means unlimited *placement* but no
cross-query retention: queries drop their blocks on completion, preserving
the zero-config spirit (no silent device-memory growth).  Stats are shared
with the host tier's ``BufferStats`` so one object reports both tiers.

jax is imported lazily inside methods: constructing a manager (every
``startup()``) must not pull in the accelerator runtime.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass
from typing import Optional

import numpy as np

from .buffers import BufferStats

# Cache keys are 4-tuples (table, column, version, shard).  Pseudo-column
# names starting with "#" never collide with real schema names (SQL
# identifiers), so valid masks and query intermediates share the key space.
VALID_PSEUDOCOL = "#valid"
CARRY_TABLE = "#carry"


class DeviceBudgetError(RuntimeError):
    """Raised when a block cannot be placed: every resident block is pinned
    and the budget leaves no room.  Callers fall back to the host tier."""


def _is_delta_key(key: tuple) -> bool:
    """True for epoch-tagged delta-tail block keys.

    The execution tier keys batches fully inside a table's immutable base
    as ``(ns, "b", base_version)`` and batches overlapping the delta tail
    as ``(ns, "d", base_version, delta_epoch)`` — see
    ``parallel.DistributedScanAgg._batch_version_key``."""
    v = key[2]
    return isinstance(v, tuple) and len(v) >= 3 and v[1] == "d"


def _jax():
    """Lazy jax import.  x64 is forced on exactly as parallel.py does at
    import: analytical columns are int64/float64 and a silent downcast in
    ``device_put`` would corrupt them when this module is used before the
    execution tier was imported."""
    import jax
    jax.config.update("jax_enable_x64", True)
    return jax


@dataclass
class _DeviceBlock:
    array: object                # jax.Array
    nbytes: int
    pins: int = 0
    dirty: bool = False          # query-produced: evict => copy back to host
    sharding: object = None      # restored on re-upload after a writeback


class DeviceBufferManager:
    """Byte-budgeted ownership of all device-resident column blocks."""

    def __init__(self, budget: Optional[int] = None,
                 stats: Optional[BufferStats] = None):
        if budget is not None and budget <= 0:
            raise ValueError(
                f"device budget must be positive, got {budget}")
        self.budget = budget
        self.stats = stats if stats is not None else BufferStats()
        self._blocks: "OrderedDict[tuple, _DeviceBlock]" = OrderedDict()
        self._host: dict[tuple, np.ndarray] = {}   # written-back dirty blocks
        self._resident = 0
        self._lock = threading.RLock()
        # shared scans: one in-flight build/upload per key — concurrent
        # queries over the same (table, column, version, shard) attach to
        # the first query's transfer instead of each re-reading and
        # re-uploading the block (serving.SingleFlight; lazy import keeps
        # module load order flexible)
        from .serving import SingleFlight
        self._flight = SingleFlight()
        # per-table cumulative cache hits: the runtime statistic the
        # physical planner's admission policy biases residency with
        # (physplan.choose_device_tier hit_history).  Survives version
        # bumps — repeat-access evidence is about the workload, not one
        # table version — and resets on DROP TABLE
        # (invalidate_table(drop_history=True)) and cleanup().
        self._table_hits: dict[str, int] = {}

    # ---- introspection -----------------------------------------------------
    @property
    def resident_bytes(self) -> int:
        with self._lock:
            return self._resident

    @property
    def resident_blocks(self) -> int:
        with self._lock:
            return len(self._blocks)

    def __contains__(self, key: tuple) -> bool:
        with self._lock:
            return key in self._blocks

    def bump(self, **deltas: int) -> None:
        """Atomically add ``deltas`` to stats counters — the locked
        replacement for ``devman.stats.field += n`` in operator code."""
        with self._lock:
            for name, delta in deltas.items():
                setattr(self.stats, name, getattr(self.stats, name) + delta)

    # ---- placement ---------------------------------------------------------
    def _account(self, nbytes: int) -> None:  # requires-lock: _lock
        self._resident += nbytes
        self.stats.device_bytes_peak = max(self.stats.device_bytes_peak,
                                           self._resident)

    def _make_room(self, nbytes: int) -> None:  # requires-lock: _lock
        """Evict LRU unpinned blocks until ``nbytes`` fits the budget.
        Runs *before* the new block is accounted, so tracked resident bytes
        — and therefore ``device_bytes_peak`` — never exceed the budget."""
        if self.budget is None:
            return
        if nbytes > self.budget:
            raise DeviceBudgetError(
                f"block of {nbytes} bytes exceeds device budget "
                f"{self.budget}")
        while self._resident + nbytes > self.budget:
            victim = None
            for key, blk in self._blocks.items():     # LRU order
                if blk.pins == 0:
                    victim = key
                    break
            if victim is None:
                raise DeviceBudgetError(
                    f"cannot place {nbytes} bytes: "
                    f"{self._resident} resident bytes all pinned "
                    f"(budget {self.budget})")
            self._evict(victim)

    def _evict(self, key: tuple) -> None:  # requires-lock: _lock
        blk = self._blocks.pop(key)
        if blk.dirty:
            # query-produced intermediate: host has no authoritative copy,
            # write back (with its sharding, so the re-upload restores the
            # placement consumers were traced against) before dropping the
            # device reference
            self._host[key] = (np.asarray(blk.array), blk.sharding)
            self.stats.device_writebacks += 1
        self._resident -= blk.nbytes
        self.stats.device_evictions += 1

    def put(self, key: tuple, host_array: np.ndarray, sharding=None,
            pin: bool = False, dirty: bool = False) -> object:
        """Upload one host block (non-blocking ``jax.device_put``); evicts
        LRU blocks first if the budget requires it.  Returns the device
        array immediately — the transfer overlaps whatever the caller does
        next until something forces the value (that is the prefetch
        mechanism).  ``dirty=True`` marks re-uploaded intermediates, whose
        only authoritative copy must follow them back out on eviction."""
        jax = _jax()
        arr = np.ascontiguousarray(host_array)
        nbytes = int(arr.nbytes)
        with self._lock:
            if key in self._blocks:        # replace (e.g. recycled key)
                self.drop(key)
            self._make_room(nbytes)
            dev = jax.device_put(arr, sharding) if sharding is not None \
                else jax.device_put(arr)
            self._blocks[key] = _DeviceBlock(dev, nbytes,
                                             pins=1 if pin else 0,
                                             dirty=dirty, sharding=sharding)
            self._account(nbytes)
            self.stats.device_bytes_h2d += nbytes
            if _is_delta_key(key):
                # delta-tail uploads tracked separately: the epoch-keyed
                # survival claim is "repeat scans after an append move only
                # the tail's bytes", and this is the counter that proves it
                self.stats.delta_bytes_h2d += nbytes
            self._host.pop(key, None)
            return dev

    def adopt(self, key: tuple, device_array, nbytes: Optional[int] = None,
              pin: bool = False, dirty: bool = True) -> object:
        """Register an array already on device (a query-produced
        intermediate) — accounted against the budget but no host→device
        bytes.  Dirty blocks are copied back to host on eviction."""
        if nbytes is None:
            nbytes = int(np.dtype(device_array.dtype).itemsize
                         * int(np.prod(device_array.shape)))
        with self._lock:
            if key in self._blocks:
                self.drop(key)
            self._make_room(int(nbytes))
            self._blocks[key] = _DeviceBlock(
                device_array, int(nbytes), pins=1 if pin else 0,
                dirty=dirty,
                sharding=getattr(device_array, "sharding", None))
            self._account(int(nbytes))
            self._host.pop(key, None)
            return device_array

    # ---- lookup ------------------------------------------------------------
    def get(self, key: tuple, pin: bool = False):
        """Cache lookup; bumps LRU recency and ``device_cache_hits`` on a
        hit.  A dirty block that was evicted (written back to host) is
        transparently re-uploaded.  Returns None on a clean miss."""
        with self._lock:
            blk = self._blocks.get(key)
            if blk is not None:
                self._blocks.move_to_end(key)
                if pin:
                    blk.pins += 1
                self.stats.device_cache_hits += 1
                if not key[0].startswith("#"):     # real tables only
                    self._table_hits[key[0]] = \
                        self._table_hits.get(key[0], 0) + 1
                return blk.array
            entry = self._host.get(key)
        if entry is None:
            return None
        host, sharding = entry
        return self.put(key, host, sharding=sharding, pin=pin,
                        dirty=True)                       # re-upload

    def get_or_put(self, key: tuple, build, sharding=None,
                   pin: bool = False):
        """Shared-scan lookup: cache hit, else single-flight build+upload.

        ``build`` produces the host block (a file read / memmap page-in);
        the first caller of a key runs it and uploads, every concurrent
        caller of the same key *attaches* — it blocks on the in-flight
        transfer and then takes its own pin from the cache, so a
        repeat-heavy concurrent mix does ONE read and ONE host→device copy
        per block instead of N (``shared_scan_attaches`` counts the saved
        ones).  An attacher that finds the block already evicted (tight
        budget) or the build failed loops and becomes the builder itself —
        one query's error never poisons another's.  The build/upload runs
        outside the manager lock."""
        attached = False
        while True:
            arr = self.get(key, pin=pin)
            if arr is not None:
                if attached:
                    with self._lock:
                        self.stats.shared_scan_attaches += 1
                return arr
            arr, waited = self._flight.do(
                key, lambda: self.put(key, build(), sharding=sharding,
                                      pin=pin))
            if not waited:
                return arr         # we built: put() already took our pin
            attached = True        # loop: take our own pin via get()

    def hit_history(self, table: str) -> int:
        """Cumulative cache hits on one table's blocks — the repeat-access
        evidence ``physplan.choose_device_tier`` biases admission with."""
        with self._lock:
            return self._table_hits.get(table, 0)

    def peek(self, key: tuple):
        """Lookup without recency bump or hit accounting (the prefetch
        consumer uses this to distinguish prefetch hits from cache hits)."""
        with self._lock:
            blk = self._blocks.get(key)
            return None if blk is None else blk.array

    # ---- pin accounting ----------------------------------------------------
    def pin(self, key: tuple) -> None:
        with self._lock:
            self._blocks[key].pins += 1

    def unpin(self, key: tuple) -> None:
        with self._lock:
            blk = self._blocks.get(key)
            if blk is not None and blk.pins > 0:
                blk.pins -= 1

    # ---- explicit lifecycle ------------------------------------------------
    def drop(self, key: tuple) -> None:
        """Remove a block without writeback or eviction accounting (query
        teardown of its own blocks; budget-pressure eviction is
        ``_make_room``'s job)."""
        with self._lock:
            blk = self._blocks.pop(key, None)
            if blk is not None:
                self._resident -= blk.nbytes
            self._host.pop(key, None)

    def take_host(self, key: tuple) -> Optional[np.ndarray]:
        """Fetch a block's value to host and drop it: device copy if
        resident (blocks until the value is ready), else the written-back
        host copy."""
        with self._lock:
            blk = self._blocks.pop(key, None)
            if blk is not None:
                self._resident -= blk.nbytes
                return np.asarray(blk.array)
            entry = self._host.pop(key, None)
            return None if entry is None else entry[0]

    def invalidate_table(self, table: str,
                         drop_history: bool = False) -> None:
        """Drop every block of one table (all columns, versions, shards) —
        called when a table is dropped or rewritten in place.

        ``drop_history=True`` (DROP TABLE) also forgets the table's
        admission hit history: a future table reusing the name is a
        different table and must earn residency from scratch.  Appends and
        in-place rewrites keep the history — repeat-access evidence is
        about the workload, not one table version."""
        with self._lock:
            for key in [k for k in self._blocks if k[0] == table]:
                self.drop(key)
            for key in [k for k in self._host if k[0] == table]:
                self._host.pop(key, None)
            if drop_history:
                self._table_hits.pop(table, None)

    def invalidate_delta(self, table: str) -> None:
        """Drop only one table's delta-tail blocks (epoch-tagged keys).

        The base blocks stay: a delta append leaves them byte-identical and
        their ``(ns, "b", base_version)`` keys unchanged, so repeat scans
        re-upload nothing but the new tail.  Superseded-epoch tail blocks
        are unreachable either way (keys carry the epoch) — dropping them
        just frees their budget immediately."""
        def _match(k):
            return k[0] == table and _is_delta_key(k)
        with self._lock:
            for key in [k for k in self._blocks if _match(k)]:
                self.drop(key)
            for key in [k for k in self._host if _match(k)]:
                self._host.pop(key, None)

    def invalidate_namespace(self, ns) -> None:
        """Drop every block whose version component carries key namespace
        ``ns`` (a transaction snapshot's blocks, once its query ends)."""
        def _match(k):
            return isinstance(k[2], tuple) and len(k[2]) >= 2 \
                and k[2][0] == ns
        with self._lock:
            for key in [k for k in self._blocks if _match(k)]:
                self.drop(key)
            for key in [k for k in self._host if _match(k)]:
                self._host.pop(key, None)

    def cleanup(self) -> None:
        """Release everything (database shutdown)."""
        with self._lock:
            self._blocks.clear()
            self._host.clear()
            self._table_hits.clear()
            self._resident = 0


__all__ = ["DeviceBufferManager", "DeviceBudgetError", "DeviceBlockKeys",
           "VALID_PSEUDOCOL", "CARRY_TABLE"]


class DeviceBlockKeys:
    """Key builders for the shared 4-tuple key space.

    ``shard`` identifies the block's slice of the column and must encode
    its geometry (the execution tier passes ``(batch_rows, batch_index)``)
    — two slicings of the same column version are distinct blocks.
    ``version`` may be a plain table version or a namespace-carrying tuple
    — ``(ns, "b", base_version)`` for blocks inside a table's immutable
    base, ``(ns, "d", base_version, delta_epoch)`` for blocks overlapping
    the delta tail.  Transaction snapshots use a unique ``ns`` because
    their tables reuse the version number the next committed write will
    get; the base/delta split is what lets an append invalidate only the
    tail (``invalidate_delta``) while base blocks keep hitting."""

    @staticmethod
    def column(table: str, column: str, version, shard) -> tuple:
        return (table, column, version, shard)

    @staticmethod
    def valid(table: str, version, shard) -> tuple:
        return (table, VALID_PSEUDOCOL, version, shard)

    _seq = 0
    _seq_lock = threading.Lock()

    @classmethod
    def carry(cls) -> tuple:
        """Unique per-query intermediate key (never cached across queries)."""
        with cls._seq_lock:
            cls._seq += 1
            return (CARRY_TABLE, "partial", cls._seq, 0)
