"""MAL-style physical programs: linear column-at-a-time instruction lists.

The relational tree compiles into a ``MALProgram`` — a sequence of
instructions over named column registers, mirroring MonetDB's Monet Assembly
Language (paper §3.1).  Every instruction processes *whole columns*; each is
marked ``parallelizable`` or ``blocking`` exactly like the paper's Fig. 2:
the chunked/distributed executor (parallel.py) maps parallelizable prefixes
over shards and merges at blocking instructions.

Instruction set
---------------
load   t.c            -> r          [par]  pull a base column (page-in)
expr   {col->reg}, E  -> r          [par]  vectorized scalar expression
select {col->reg}, P  -> m          [par]  predicate -> bool selection mask
mand   m1, m2         -> m          [par]  mask conjunction
fetch  r, idx         -> r'         [par]  positional gather (join output)
join   lkeys, rkeys, lm, rm, how -> (lidx, ridx)       [blocking]
group  keys, m        -> (gid, n, repidx)              [blocking]
agg    fn, val, gid, m, n -> r (len n_groups)          [blocking; partial-izable]
sort   keys, descs, limit -> idx                        [blocking]
take   r, idx         -> r'         [par]
result names, regs                                      [blocking]
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

PARALLELIZABLE = {"load", "expr", "select", "mand", "fetch", "take"}
BLOCKING = {"join", "group", "agg", "sort", "result"}


@dataclass
class Instr:
    op: str
    out: tuple[str, ...]            # output register name(s)
    args: tuple[str, ...]           # input register names
    payload: Any = None             # op-specific static data

    @property
    def parallelizable(self) -> bool:
        # 'agg' is algebraically partial-izable (sum/count/min/max partials
        # merge associatively); the distributed executor exploits that, the
        # sequential one treats it as blocking.
        return self.op in PARALLELIZABLE

    def signature(self) -> tuple:
        return (self.op, self.args, repr(self.payload))

    def __repr__(self):
        outs = ",".join(self.out)
        args = ",".join(self.args)
        p = f" {self.payload!r}" if self.payload is not None else ""
        flag = "P" if self.parallelizable else "B"
        return f"[{flag}] {outs} := {self.op}({args}){p}"


@dataclass
class MALProgram:
    instrs: list[Instr] = field(default_factory=list)
    result_names: list[str] = field(default_factory=list)
    _cse: dict = field(default_factory=dict)
    _ctr: int = 0

    def fresh(self, hint: str = "r") -> str:
        self._ctr += 1
        return f"{hint}{self._ctr}"

    def emit(self, op: str, args: tuple[str, ...], payload=None,
             n_out: int = 1, hint: str = "r") -> tuple[str, ...]:
        """Append an instruction with MAL-level CSE (paper optimization
        level 2): identical (op, args, payload) reuse the existing output."""
        ins = Instr(op, (), tuple(args), payload)
        sig = ins.signature()
        if sig in self._cse and op != "result":
            return self._cse[sig]
        outs = tuple(self.fresh(hint) for _ in range(n_out))
        ins.out = outs
        self.instrs.append(ins)
        self._cse[sig] = outs
        return outs

    def listing(self) -> str:
        return "\n".join(repr(i) for i in self.instrs)

    def __len__(self):
        return len(self.instrs)
