"""Optimistic concurrency control (paper §3.1 "Concurrency Control").

Transactions operate on a snapshot of the database (a pinned set of
immutable table versions).  Writes are buffered; commit performs optimistic
conflict detection — first committer wins per table — and either installs
new versions atomically or raises ``ConflictError``.

Because tables are immutable values, snapshot isolation is structural: a
reader's snapshot can never observe a concurrent writer.  This is the
functional-array restatement of MonetDBLite's model.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

from .delta import delta_append
from .table import Table


class ConflictError(RuntimeError):
    pass


class TransactionError(RuntimeError):
    pass


@dataclass
class Transaction:
    database: object
    snapshot: dict[str, Table]                 # pinned versions
    writes: dict[str, list[Table]] = field(default_factory=dict)  # appends
    creates: dict[str, Table] = field(default_factory=dict)
    replaces: dict[str, Table] = field(default_factory=dict)      # DELETE
    drops: set = field(default_factory=set)
    state: str = "open"                        # open | committed | aborted

    # -- reads ---------------------------------------------------------------
    def table(self, name: str) -> Table:
        self._check_open()
        if name in self.creates:
            return self.creates[name]
        if name in self.drops:
            raise KeyError(f"table {name} dropped in this transaction")
        t = self.replaces.get(name, self.snapshot[name])
        for chunk in self.writes.get(name, ()):   # read-your-own-writes
            t = delta_append(t, chunk)
        return t

    def tables(self) -> dict[str, Table]:
        out = {n: self.table(n)
               for n in list(self.snapshot) + list(self.creates)
               if n not in self.drops}
        return out

    # -- writes --------------------------------------------------------------
    def append(self, name: str, chunk: Table) -> None:
        self._check_open()
        if name not in self.snapshot and name not in self.creates:
            raise KeyError(f"unknown table {name}")
        self.writes.setdefault(name, []).append(chunk)

    def replace(self, name: str, table: Table) -> None:
        """Replace a table's contents wholesale (the DELETE path).  Validated
        against the snapshot version at commit, exactly like appends."""
        self._check_open()
        if name not in self.snapshot:
            raise KeyError(f"unknown table {name}")
        self.replaces[name] = table

    def create_table(self, table: Table) -> None:
        self._check_open()
        if table.name in self.snapshot or table.name in self.creates:
            raise TransactionError(f"table {table.name} already exists")
        self.creates[table.name] = table

    def drop_table(self, name: str) -> None:
        self._check_open()
        if name not in self.snapshot and name not in self.creates:
            raise KeyError(name)
        self.creates.pop(name, None)
        self.drops.add(name)

    # -- lifecycle -------------------------------------------------------------
    def commit(self) -> None:
        self._check_open()
        self.database._commit(self)
        self.state = "committed"

    def rollback(self) -> None:
        self._check_open()
        self.state = "aborted"

    def _check_open(self):
        if self.state != "open":
            raise TransactionError(f"transaction is {self.state}")


class TransactionManager:
    """Owns the committed table map; serializes commits under a lock
    (commits are short: version checks + pointer swaps)."""

    def __init__(self):
        self._lock = threading.Lock()

    def begin(self, database) -> Transaction:
        with self._lock:
            snap = dict(database.catalog.tables)
        return Transaction(database, snap)

    def commit(self, database, txn: Transaction) -> None:
        with self._lock:
            cat = database.catalog
            # optimistic validation: every written table must be unchanged
            for name in (list(txn.writes) + list(txn.replaces)
                         + list(txn.drops)):
                if name in txn.creates:
                    continue
                cur = cat.tables.get(name)
                base = txn.snapshot.get(name)
                if cur is None or base is None or cur.version != base.version:
                    raise ConflictError(
                        f"write-write conflict on table {name!r}")
            for name in txn.creates:
                if name in cat.tables:
                    raise ConflictError(f"table {name!r} created concurrently")
            # install
            for name, table in txn.creates.items():
                cat.tables[name] = table
                database._on_table_created(table)
            for name, table in txn.replaces.items():
                cat.tables[name] = table
                database._on_replace(name)
            for name, chunks in txn.writes.items():
                t = cat.tables[name]
                for chunk in chunks:
                    database._on_append(t, chunk)
                    # delta install: the base version is shared, the chunk
                    # rides as an immutable tail — O(delta rows) per commit
                    t = delta_append(t, chunk)
                cat.tables[name] = t
                database.index_manager.on_append(name)
                # threshold compaction folds an oversized tail back into a
                # plain base, still under the commit lock (the fold keeps
                # version and content, so no validation window opens)
                database._maybe_compact(name)
            for name in txn.drops:
                del cat.tables[name]
                database.index_manager.invalidate_table(name)
