"""LiteColumn: an embedded analytical columnar engine (MonetDBLite repro).

Public API:
    startup(path=None) -> Database      # the embedding interface
    Col, Lit, DateLit, Func, Case, ...  # expression builders
"""

from .buffers import BufferManager
from .column import Column, StringHeap
from .device_cache import DeviceBufferManager
from .exchange import (LazyFrame, copy_for_write, export_table,
                       import_arrays, to_device, zero_copy_view)
from .expression import (BinOp, Case, Cast, Col, DateLit, Func, InList,
                         IsNull, Like, Lit, Not)
from .relalg import AggSpec, Query
from .session import Connection, Database, DatabaseError, Result, startup
from .table import Table
from .transactions import ConflictError, TransactionError
from .types import ColumnSchema, DBType, TableSchema

__all__ = [
    "AggSpec", "BinOp", "BufferManager", "Case", "Cast", "Col", "Column",
    "ColumnSchema",
    "ConflictError", "Connection", "Database", "DatabaseError", "DateLit",
    "DeviceBufferManager",
    "DBType", "Func", "InList", "IsNull", "LazyFrame", "Like", "Lit", "Not",
    "Query", "Result", "StringHeap", "Table", "TableSchema",
    "TransactionError", "copy_for_write", "export_table", "import_arrays",
    "startup", "to_device", "zero_copy_view",
]
