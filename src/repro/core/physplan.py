"""Unified physical planner: one logical→physical lowering pass.

Every query — builder API, SQL, transaction-scoped — flows through
``plan_physical`` before execution.  The pass has three jobs, matching the
paper's one-planner-many-frontends architecture (§3: the same optimizer and
execution machinery serve every entry point, choosing strategies from data
statistics rather than per-API code paths):

1. **Normalization** — SQL and builder plans converge to identical shapes:
   trivial (identity) projections are elided, pure-rename projections over
   an aggregate are pushed into the aggregate's output names, and filter
   conjuncts are merged + canonically ordered.  This is what fixes "SQL
   plans never match the device tier": ``parse_sql`` wraps aggregates in a
   rename ProjectNode that used to hide the Aggregate(Filter*(Scan)) shape
   from ``match_scan_agg``.

2. **Tier annotation** — each operator gets a tier decision
   (``device-resident`` / ``device-streamed`` / ``parallel-host`` /
   ``spill`` / ``in-memory``) and a budget reservation.  The byte models
   and routing thresholds that used to be smeared across ``executor.py``,
   ``parallel.py``, ``volcano.py`` and ``optimizer.py`` live here, in ONE
   costed policy (``TierPolicy``).  Plan-time annotations are predictions
   from level-1 statistics (``optimizer.estimate_rows``); at runtime the
   executors refine the blocking-operator decisions with actual
   cardinalities — through the *same* policy object, so there is exactly
   one definition of every threshold.  Device admission is biased by the
   ``DeviceBufferManager``'s cache-hit history: repeated queries on a
   borderline table flip from streamed to resident.

3. **Observability** — ``PhysicalPlan.render()`` is the EXPLAIN text
   surfaced through ``Query.explain(physical=True)`` and
   ``ExecStats.plan_repr``, so tier choices are golden-testable.

The executors are *consumers* of this plan: ``executor.py`` asks the policy
per blocking instruction, ``parallel.py`` reads the scan-agg core + device
tier + suffix, ``volcano.py`` asks for its row-spool estimate.  Adding the
next tier (device joins/sorts) means a new annotation here — not a fifth
ad-hoc routing fork.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from .expression import BinOp, Col, DateLit, Expr, Lit
from .optimizer import estimate_bytes, estimate_rows, optimize, \
    split_conjuncts
from .relalg import (AggregateNode, AggSpec, FilterNode, JoinNode, LimitNode,
                     OrderByNode, PlanNode, ProjectNode, ScanNode, node_line)
from .types import DBType, NULL_SENTINEL

# ---------------------------------------------------------------------------
# tier names (the vocabulary of the physical plan)
# ---------------------------------------------------------------------------

TIER_DEVICE_RESIDENT = "device-resident"
TIER_DEVICE_STREAMED = "device-streamed"
TIER_DEVICE_JOIN = "device-join"
TIER_DEVICE_SORT = "device-sort"
TIER_PARALLEL_HOST = "parallel-host"
TIER_SPILL = "spill"
TIER_IN_MEMORY = "in-memory"

# tiers whose reservations count against the DEVICE budget at admission
DEVICE_TIERS = (TIER_DEVICE_RESIDENT, TIER_DEVICE_STREAMED,
                TIER_DEVICE_JOIN, TIER_DEVICE_SORT)

# pattern limits for the device scan-agg tier (previously in parallel.py)
MAX_DENSE_GROUPS = 4096
MIN_ROWS_TO_SHARD = 4096      # paper: don't split small columns
DEVICE_BATCH_ROWS = 1 << 16   # morsel batch streamed through the device
                              # cache; fixed per database (not per budget)
                              # so results are budget-invariant
SUPPORTED_DEVICE_AGGS = {"count", "sum", "avg", "min", "max"}

# device join tier: the dense build-table domain may exceed the scan-agg
# group cap because the merged partial matrix never materializes on host —
# device-resident assembly compacts it in HBM first.  Build keys must be
# unique (verified at runtime; duplicates fall back to the host join).
MAX_DEVICE_JOIN_DOMAIN = 1 << 21
# build-payload columns are scatter-added as float64 and must decode
# exactly; integer-coded types only (|v| < 2^53 for the int64 widths the
# engine stores — the sentinel -2^63 is a power of two and round-trips)
DEVICE_JOIN_PAYLOAD_TYPES = (DBType.INT32, DBType.INT64, DBType.DATE,
                             DBType.BOOL, DBType.VARCHAR)
DEVICE_JOIN_KEY_TYPES = (DBType.INT32, DBType.INT64, DBType.DATE)

# smarter admission (ROADMAP): a table that fits the device budget but
# would monopolize more than this fraction of the cache is only admitted
# *resident* once its cache-hit history proves repeat access; until then it
# streams (whose blocks still populate the cache, accruing that history).
DEVICE_BORDERLINE_FRACTION = 0.5
DEVICE_PROMOTE_HITS = 1

# table name of the materialized scan-agg core inside a suffix plan ("#"
# prefix: never collides with SQL identifiers, same convention as the
# device cache's pseudo-columns)
AGG_RESULT_NAME = "#agg"


# ---------------------------------------------------------------------------
# scan-agg pattern (THE device-tier shape) — single definition
# ---------------------------------------------------------------------------


@dataclass
class ScanAggSpec:
    table: str
    conjuncts: list[Expr]
    group_keys: list[str]
    key_domains: list[tuple[float, int]]     # (offset, cardinality) per key
    aggs: list[AggSpec]
    n_groups: int
    columns: list[str]                       # all referenced base columns


def match_scan_agg(plan: PlanNode, catalog) -> Optional[ScanAggSpec]:
    """Aggregate( Filter* ( Scan ) ) with dense-domain group keys."""
    if not isinstance(plan, AggregateNode):
        return None
    if any(a.fn not in SUPPORTED_DEVICE_AGGS for a in plan.aggs):
        return None
    node = plan.child
    conjuncts: list[Expr] = []
    while isinstance(node, FilterNode):
        conjuncts = split_conjuncts(node.predicate) + conjuncts
        node = node.child
    if not isinstance(node, ScanNode):
        return None
    table = catalog.table(node.table)
    # dense domains for the keys
    domains = []
    n_groups = 1
    for k in plan.group_by:
        col = table.column(k)
        if col.dbtype == DBType.VARCHAR:
            offset, card = 0.0, len(col.heap)
        elif col.dbtype == DBType.BOOL:
            offset, card = 0.0, 2
        elif col.dbtype in (DBType.INT32, DBType.INT64, DBType.DATE):
            v = np.asarray(col.data)
            nn = v[v != NULL_SENTINEL[col.dbtype]]
            if nn.size == 0:
                return None
            mn, mx = int(nn.min()), int(nn.max())
            offset, card = float(mn), mx - mn + 1
        else:
            return None
        if card > MAX_DENSE_GROUPS:
            return None
        domains.append((offset, card))
        n_groups *= card
    if n_groups > MAX_DENSE_GROUPS:
        return None
    cols: set[str] = set(plan.group_by)
    for c in conjuncts:
        cols |= c.columns()
    for a in plan.aggs:
        if a.expr is not None:
            cols |= a.expr.columns()
    if not cols:
        cols = {table.schema.names[0]}
    return ScanAggSpec(node.table, conjuncts, list(plan.group_by),
                       domains, list(plan.aggs), n_groups, sorted(cols))


SUFFIX_NODES = (OrderByNode, LimitNode, ProjectNode, FilterNode)


def find_scan_agg_core(plan: PlanNode, catalog
                       ) -> tuple[Optional[AggregateNode],
                                  Optional[PlanNode]]:
    """Locate the scan-agg core under a chain of order/limit/project/filter
    suffix operators.  Returns ``(core, suffix)`` where ``core`` is the
    topmost AggregateNode reachable from the root through suffix nodes (or
    None), and ``suffix`` re-applies those nodes over a scan of the core's
    materialized result (``AGG_RESULT_NAME``), or None when the core IS the
    root.  The suffix runs on the host over the (tiny) assembled aggregate,
    which is what lets ORDER BY / LIMIT / HAVING queries keep their
    scan-agg core on the device tier."""
    path = []
    node = plan
    while isinstance(node, SUFFIX_NODES):
        path.append(node)
        node = node.children[0]
    if not isinstance(node, AggregateNode):
        return None, None
    if not path:
        return node, None
    suffix: PlanNode = ScanNode(AGG_RESULT_NAME,
                                tuple(node.output_columns(catalog)))
    for n in reversed(path):
        suffix = n.with_children((suffix,))
    return node, suffix


# ---------------------------------------------------------------------------
# join-agg pattern (the device JOIN tier's shape)
# ---------------------------------------------------------------------------


@dataclass
class DeviceBuild:
    """One build side of a device join: a filtered base scan whose unique
    dense-domain key becomes the row index of a (card, 1 + n_payload)
    scatter-add matrix in HBM.  Column 0 is the presence count (== 1 for a
    unique key, verified at runtime); the payload columns are the build's
    group-key contributions, recovered at assembly time by gathering the
    matrix at the surviving key codes."""
    table: str
    conjuncts: list                      # filters on this table's columns
    key: str                             # build-side join key column
    domain: tuple[float, int]            # (offset, cardinality), dense ints
    payload: list                        # build columns consumed at assembly
    probe_edges: list                    # [(earlier build idx, local col)]
    columns: list                        # all referenced columns

    @property
    def table_bytes(self) -> int:
        return self.domain[1] * (1 + len(self.payload)) * 8


@dataclass
class JoinAggSpec:
    """Aggregate over an inner-equi-join tree rooted at one probe (fact)
    table, every other table a ``DeviceBuild``.  Execution: build matrices
    bottom-up (each build's stream probes its children's matrices), then
    stream probe batches — gather presence, mask, segment-sum partials by
    the probe-side key code.  Soundness of the single-key gid: every group
    key is either the probe↔group-build join key itself or a column of the
    group build, and a *unique* build key functionally determines those —
    one code, one group."""
    probe_table: str
    probe_conjuncts: list
    probe_edges: list                    # [(build idx, probe-side column)]
    builds: list                         # bottom-up build order
    group_build: Optional[int]           # index of B*, None for global aggs
    group_keys: list
    group_sources: list                  # per key: ("key",) | ("payload", j)
    aggs: list
    n_groups: int
    key_domain: tuple[float, int]        # domain of the group build's key
    columns: list                        # probe-side referenced columns

    # ScanAggSpec-compatible views for the shared partial-matrix layout /
    # fragment machinery: the probe phase IS a scan-agg over the probe
    # table grouped by the (single) join-key code.
    @property
    def table(self) -> str:
        return self.probe_table

    @property
    def conjuncts(self) -> list:
        return self.probe_conjuncts

    def probe_spec(self) -> "ScanAggSpec":
        keys = [self.probe_key] if self.group_build is not None else []
        doms = [self.key_domain] if self.group_build is not None else []
        return ScanAggSpec(self.probe_table, list(self.probe_conjuncts),
                           keys, doms, list(self.aggs), self.n_groups,
                           list(self.columns))

    @property
    def probe_key(self) -> Optional[str]:
        if self.group_build is None:
            return None
        for bidx, col in self.probe_edges:
            if bidx == self.group_build:
                return col
        return None

    def state_bytes(self) -> int:
        k = len(partial_layout(self.probe_spec()).kinds)
        return self.n_groups * k * 8 \
            + sum(b.table_bytes for b in self.builds)


def _flatten_join_tree(node: PlanNode):
    """Flatten Filter*/Join/Scan shapes into (tables, edges, loose) where
    ``tables`` maps each base table to its own-column conjuncts, ``edges``
    are single-key inner equi-join pairs and ``loose`` are conjuncts found
    above a join (attributed to a table by column ownership later).  None
    when any node breaks the shape (outer joins, multi-key joins,
    self-joins, non-scan leaves)."""
    tables: dict = {}
    edges: list = []
    loose: list = []

    def walk(n: PlanNode) -> bool:
        conjs: list = []
        while isinstance(n, FilterNode):
            conjs = split_conjuncts(n.predicate) + conjs
            n = n.child
        if isinstance(n, ScanNode):
            if n.table in tables:
                return False                      # self-join: host tier
            tables[n.table] = conjs
            return True
        if isinstance(n, JoinNode):
            if n.how != "inner" or len(n.left_keys) != 1:
                return False
            loose.extend(conjs)
            edges.append((n.left_keys[0], n.right_keys[0]))
            return walk(n.left) and walk(n.right)
        return False

    if not walk(node):
        return None
    return tables, edges, loose


def _dense_int_domain(col) -> Optional[tuple[float, int]]:
    v = np.asarray(col.data)
    nn = v[v != NULL_SENTINEL[col.dbtype]]
    if nn.size == 0:
        return None
    mn, mx = int(nn.min()), int(nn.max())
    return float(mn), mx - mn + 1


def match_join_agg(plan: PlanNode, catalog) -> Optional[JoinAggSpec]:
    """Aggregate( Filter* ( Join tree of filtered base scans ) ) where the
    join graph is a tree rooted at the probe table (the one the aggregate
    expressions read), every build key has a dense integer domain, and all
    group keys are functionally dependent on ONE probe-adjacent build."""
    if not isinstance(plan, AggregateNode):
        return None
    if any(a.fn not in SUPPORTED_DEVICE_AGGS for a in plan.aggs):
        return None
    flat = _flatten_join_tree(plan.child)
    if flat is None or len(flat[0]) < 2:
        return None
    tables, edges, loose = flat

    # column ownership: every referenced column must belong to exactly one
    # of the joined tables (TPC-H-style prefixed names)
    owner: dict = {}
    cats: dict = {}
    for t in tables:
        try:
            cats[t] = catalog.table(t)
        except Exception:
            return None
        for name in cats[t].schema.names:
            if name in owner:
                owner[name] = None                # ambiguous
            else:
                owner[name] = t

    def owner_of(cols) -> Optional[str]:
        owners = {owner.get(c) for c in cols}
        if len(owners) != 1 or None in owners:
            return None
        return owners.pop()

    for conj in loose:
        t = owner_of(conj.columns())
        if t is None:
            return None
        tables[t].append(conj)

    # the probe table: where the aggregate expressions read from
    agg_cols: set = set()
    for a in plan.aggs:
        if a.expr is not None:
            agg_cols |= a.expr.columns()
    if agg_cols:
        probe = owner_of(agg_cols)
        if probe is None:
            return None
    else:
        probe = max(tables, key=lambda t: cats[t].num_rows)

    # join graph must be a tree spanning all tables, rooted at the probe
    if len(edges) != len(tables) - 1:
        return None
    adj: dict = {t: [] for t in tables}
    for ca, cb in edges:
        ta, tb = owner.get(ca), owner.get(cb)
        if ta is None or tb is None or ta == tb:
            return None
        adj[ta].append((tb, cb, ca))
        adj[tb].append((ta, ca, cb))
    order = [probe]
    parent_edge: dict = {}                   # table -> (parent, key, pcol)
    seen = {probe}
    i = 0
    while i < len(order):
        t = order[i]
        i += 1
        for (other, okey, tcol) in adj[t]:
            if other in seen:
                continue
            seen.add(other)
            parent_edge[other] = (t, okey, tcol)
            order.append(other)
    if len(seen) != len(tables):
        return None                          # disconnected (cross join)

    # bottom-up build order: children before the builds that probe them
    build_tables = list(reversed(order[1:]))
    bidx = {t: i for i, t in enumerate(build_tables)}

    # group keys: all must resolve to ONE probe-adjacent build (B*)
    group_build: Optional[str] = None
    for g in plan.group_by:
        t = owner.get(g)
        if t is None:
            return None
        if t == probe:
            cand = [other for other, okey, tcol in adj[probe] if tcol == g]
            if len(cand) != 1:
                return None
            t = cand[0]
        if group_build is None:
            group_build = t
        elif group_build != t:
            return None
    if group_build is not None:
        if parent_edge[group_build][0] != probe:
            return None                      # FD chain only one hop deep

    builds = []
    for t in build_tables:
        par, key, pcol = parent_edge[t]
        col = cats[t].column(key)
        if col.dbtype not in DEVICE_JOIN_KEY_TYPES:
            return None
        dom = _dense_int_domain(col)
        if dom is None or dom[1] > MAX_DEVICE_JOIN_DOMAIN:
            return None
        payload = []
        if t == group_build:
            for g in plan.group_by:
                if owner.get(g) == t and g != key:
                    pc = cats[t].column(g)
                    if pc.dbtype not in DEVICE_JOIN_PAYLOAD_TYPES:
                        return None
                    payload.append(g)
        pedges = [(bidx[other], tcol)
                  for other, okey, tcol in adj[t]
                  if other != par and other in bidx]
        cols = set(payload) | {key} | {c for _, c in pedges}
        for conj in tables[t]:
            cols |= conj.columns()
        builds.append(DeviceBuild(
            t, tables[t], key, dom, payload, pedges, sorted(cols)))

    probe_edges = [(bidx[other], tcol)
                   for other, okey, tcol in adj[probe] if other in bidx]
    if group_build is not None:
        gb = bidx[group_build]
        key_domain = builds[gb].domain
        n_groups = key_domain[1]
        pk = [c for b, c in probe_edges if b == gb][0]
    else:
        gb, key_domain, n_groups, pk = None, (0.0, 1), 1, None
    group_sources: list = []
    for g in plan.group_by:
        t = owner.get(g)
        if t == probe or g == builds[gb].key:
            group_sources.append(("key",))
        else:
            group_sources.append(("payload", builds[gb].payload.index(g)))
    if group_sources and ("key",) not in group_sources:
        # the device groups at build-key granularity; payload-only group
        # keys (e.g. GROUP BY a dimension attribute) are coarser and
        # would need a second merge — leave those to the host join
        return None

    pcols: set = set() if pk is None else {pk}
    pcols |= {c for _, c in probe_edges}
    pcols |= agg_cols
    for conj in tables[probe]:
        pcols |= conj.columns()
    if not pcols:
        pcols = {cats[probe].schema.names[0]}

    return JoinAggSpec(probe, tables[probe], probe_edges, builds, gb,
                       list(plan.group_by), group_sources, list(plan.aggs),
                       n_groups, key_domain, sorted(pcols))


# ---------------------------------------------------------------------------
# physical layout of the device partial-aggregate matrix
# ---------------------------------------------------------------------------


@dataclass
class PartialLayout:
    """Column layout of the raw-partial matrix one device batch step emits.

    Columns ``[0, n_sum)`` combine by addition (cnt_star, then per-agg
    count and — for sum/avg — value-sum slots, in agg order); the remaining
    columns are one min- or max-combining slot per min/max aggregate.
    Ratios and NULL masking are *not* applied on device — partials stay
    mergeable across batches and ``parallel.finalize_partials`` applies
    them once at the end, so the arithmetic is identical no matter how many
    batches the input was split into."""
    n_sum: int
    plans: list                  # (agg_idx, kind, cnt_col, val_col)
    minmax: list                 # (agg_idx, fn, cnt_col, out_col)
    kinds: np.ndarray            # (K,) int8: 0 add / 1 min / 2 max
    init: np.ndarray             # (K,) float64 combine identity per column


def partial_layout(spec: ScanAggSpec) -> PartialLayout:
    plans, minmax = [], []
    n_sum = 1                                   # col 0: cnt_star
    for i, a in enumerate(spec.aggs):
        if a.expr is None:
            plans.append((i, "count_star", 0, 0))
            continue
        cnt = n_sum
        n_sum += 1
        if a.fn in ("sum", "avg"):
            plans.append((i, a.fn, cnt, n_sum))
            n_sum += 1
        elif a.fn == "count":
            plans.append((i, "count", cnt, 0))
        else:
            minmax.append([i, a.fn, cnt, 0])
    k = n_sum
    for mm in minmax:
        mm[3] = k
        k += 1
    kinds = np.zeros(k, dtype=np.int8)
    init = np.zeros(k, dtype=np.float64)
    for _, fn, _, c in minmax:
        kinds[c] = 1 if fn == "min" else 2
        init[c] = np.inf if fn == "min" else -np.inf
    return PartialLayout(n_sum, plans, [tuple(m) for m in minmax],
                         kinds, init)


@dataclass
class ScanAggGeometry:
    """Batch decomposition + byte footprint of one device scan-agg.  The
    geometry depends only on (table, shard count, batch_rows config) —
    never on the budget — which is what keeps the budget matrix
    bit-identical."""
    batch_rows: int
    n_batches: int
    row_bytes: int
    carry_nbytes: int
    batch_bytes: int
    resident_bytes: int


def scan_agg_geometry(spec: ScanAggSpec, table, shards: int,
                      batch_rows: Optional[int] = None) -> ScanAggGeometry:
    n_rows = table.num_rows
    m = int(batch_rows or DEVICE_BATCH_ROWS)
    # round up to the shard count, but never pad past the table: a small
    # table gets one table-sized batch instead of a full default batch of
    # mostly padding (which would inflate the byte estimates the tier
    # routing runs on up to ~16x)
    cap = -(-max(1, n_rows) // shards) * shards
    rows = min(-(-m // shards) * shards, cap)
    n_batches = max(1, -(-n_rows // rows))
    row_bytes = 1                                   # valid mask
    for c in spec.columns:
        row_bytes += table.column(c).data.dtype.itemsize
    carry = spec.n_groups * len(partial_layout(spec).kinds) * 8
    return ScanAggGeometry(
        batch_rows=rows, n_batches=n_batches, row_bytes=row_bytes,
        carry_nbytes=carry,
        batch_bytes=rows * row_bytes + carry,
        resident_bytes=n_batches * rows * row_bytes + carry)


@dataclass
class JoinAggGeometry:
    """Batch decomposition + byte footprint of one device join-agg.  The
    probe fields quack like ``ScanAggGeometry``; ``state_bytes`` is the
    HBM-resident working state (build matrices + carry) that stays on
    device for the whole query, and ``working_bytes`` is the streamed
    admission unit: state plus a double-buffered copy of the largest
    single stream batch (build or probe)."""
    batch_rows: int              # probe batch rows
    n_batches: int               # probe batch count
    row_bytes: int               # probe bytes per row
    carry_nbytes: int            # probe partial-matrix bytes
    state_bytes: int             # carry + all build matrices
    max_batch_bytes: int         # largest single batch across all streams
    working_bytes: int           # state + 2 * max batch (streamed unit)
    resident_bytes: int          # every stream fully resident + state
    build_geoms: list            # per-build ScanAggGeometry (stream shape)


def join_agg_geometry(spec: JoinAggSpec, catalog, shards: int,
                      batch_rows: Optional[int] = None) -> JoinAggGeometry:
    pg = scan_agg_geometry(spec.probe_spec(), catalog.table(spec.probe_table),
                           shards, batch_rows)
    state = pg.carry_nbytes + sum(b.table_bytes for b in spec.builds)
    max_batch = pg.batch_rows * pg.row_bytes
    resident = pg.n_batches * pg.batch_rows * pg.row_bytes
    build_geoms = []
    for b in spec.builds:
        bspec = ScanAggSpec(b.table, [], [], [], [], 1, list(b.columns))
        bg = scan_agg_geometry(bspec, catalog.table(b.table), shards,
                               batch_rows)
        build_geoms.append(bg)
        max_batch = max(max_batch, bg.batch_rows * bg.row_bytes)
        resident += bg.n_batches * bg.batch_rows * bg.row_bytes
    return JoinAggGeometry(
        batch_rows=pg.batch_rows, n_batches=pg.n_batches,
        row_bytes=pg.row_bytes, carry_nbytes=pg.carry_nbytes,
        state_bytes=state, max_batch_bytes=max_batch,
        working_bytes=state + 2 * max_batch,
        resident_bytes=resident + state, build_geoms=build_geoms)


def choose_device_join_tier(resident_bytes: float, working_bytes: float,
                            device_budget: Optional[int],
                            host_budget: Optional[int] = None) -> str:
    """Join-tier placement, mirroring ``choose_device_tier``'s semantics:
    ``"resident"`` when every stream fits the device budget at once,
    ``"streamed"`` when the HBM working state plus a double-buffered batch
    does, ``"host"`` otherwise.  The host-budget demotion carries the same
    caveat as the scan-agg tier: streaming only bounds residency through
    eviction, so it needs a real device budget to be a demotion target."""
    streamable = device_budget is not None \
        and working_bytes <= device_budget
    if device_budget is not None and resident_bytes > device_budget:
        return "streamed" if streamable else "host"
    if host_budget is not None and resident_bytes > host_budget:
        return "streamed" if streamable else "host"
    return "resident"


def mesh_shards(mesh) -> int:
    shards = 1
    for ax in mesh.axis_names:
        if ax in ("pod", "data"):
            shards *= mesh.shape[ax]
    return shards


# ---------------------------------------------------------------------------
# device placement (previously optimizer.choose_device_tier)
# ---------------------------------------------------------------------------


def choose_device_tier(resident_bytes: float, batch_bytes: float,
                       device_budget: Optional[int],
                       host_budget: Optional[int] = None,
                       host_bytes: Optional[float] = None,
                       hit_history: int = 0) -> str:
    """Device-tier placement decision (paper optimization level 3, one tier
    up): ``"resident"`` when every block of the input fits the device
    budget at once, ``"streamed"`` when only morsel batches do (double-
    buffered: two batch working sets in flight), ``"host"`` when not even
    one batch fits — the plan stays on the host tier, whose blocking
    operators spill.

    ``host_budget``/``host_bytes`` fold in the *host* memory budget: the
    resident path keeps full device-resident copies (host RAM on CPU
    backends), so an input over the host budget is demoted to streaming —
    but only under a real device budget, because streaming bounds
    residency through *eviction*: with ``device_budget=None`` nothing ever
    evicts, so the demotion would silently retain the whole table and the
    plan goes to the bounded host spill tier instead.

    ``hit_history`` biases admission the way the paper's optimizer uses
    runtime statistics: a *borderline* table — one that fits the budget but
    would occupy more than ``DEVICE_BORDERLINE_FRACTION`` of it, crowding
    out every other table's blocks — is admitted resident only once its
    cumulative device-cache hits (``DeviceBufferManager.hit_history``)
    reach ``DEVICE_PROMOTE_HITS``.  A first query on such a table streams;
    its blocks still land in the cache, so a repeat query observes hits and
    flips to resident."""
    streamable = device_budget is not None \
        and 2 * batch_bytes <= device_budget
    if device_budget is not None and resident_bytes > device_budget:
        return "streamed" if streamable else "host"
    if host_budget is not None and host_bytes is not None \
            and host_bytes > host_budget:
        return "streamed" if streamable else "host"
    if device_budget is not None and streamable \
            and resident_bytes > DEVICE_BORDERLINE_FRACTION * device_budget \
            and hit_history < DEVICE_PROMOTE_HITS:
        return "streamed"
    return "resident"


# ---------------------------------------------------------------------------
# imprint-driven data skipping: plan-time skip-sets (paper §3.1)
# ---------------------------------------------------------------------------


def _simple_range(expr: Expr):
    """Detect `col <cmp> literal` for the imprint fast path.

    Returns (col, lo, hi, lo_strict, hi_strict) with +-inf open ends."""
    if not isinstance(expr, BinOp) \
            or expr.op not in ("<", "<=", ">", ">=", "="):
        return None
    l, r = expr.left, expr.right
    op = expr.op
    if isinstance(r, Col) and isinstance(l, (Lit, DateLit)):
        l, r = r, l
        op = {"<": ">", ">": "<", "<=": ">=", ">=": "<=", "=": "="}[op]
    if not (isinstance(l, Col) and isinstance(r, (Lit, DateLit))):
        return None
    if isinstance(r, DateLit):
        from .types import date_from_string
        v = float(date_from_string(r.text))
    else:
        if isinstance(r.value, str) or r.value is None:
            return None
        v = float(r.value)
    lo, hi = -np.inf, np.inf
    lo_s = hi_s = False
    if op == "=":
        lo = hi = v
    elif op == "<":
        hi, hi_s = v, True
    elif op == "<=":
        hi = v
    elif op == ">":
        lo, lo_s = v, True
    elif op == ">=":
        lo = v
    return l.name, lo, hi, lo_s, hi_s


@dataclass
class SkipSet:
    """Per-scan block-qualification bitmap derived from imprints at plan
    time.

    ``cand[b]`` is True when imprint block ``b`` *may* contain rows
    satisfying every simple-range filter conjunct on the scan — the AND of
    each conjunct's zone-map candidate bitmap, so it is a sound superset of
    the qualifying blocks (a block is dropped only when some conjunct is
    provably unsatisfiable there).  The skip-set is advisory: every tier
    still evaluates the full predicate on the blocks it does read.

    Skip-sets are derived against one table version and re-validated with
    ``valid_for`` at execution time; cache keys carry table versions too,
    so a stale bitmap is never consumed."""
    table: str
    version: int
    block: int                    # rows per imprint block
    n_rows: int
    cand: np.ndarray              # (n_blocks,) bool candidate bitmap
    columns: tuple                # filter columns the bitmap derives from

    @property
    def n_blocks(self) -> int:
        return len(self.cand)

    @property
    def n_skipped(self) -> int:
        return int((~self.cand).sum())

    def valid_for(self, table) -> bool:
        return (getattr(table, "version", None) == self.version
                and table.num_rows == self.n_rows)

    def batch_qualifies(self, s: int, e: int) -> bool:
        """May the row range [s, e) contain a qualifying row?"""
        if e <= s:
            return False
        return bool(self.cand[s // self.block:
                              (e - 1) // self.block + 1].any())

    def candidate_ranges(self):
        """Merged (start_row, end_row) ranges of candidate blocks."""
        out: list[tuple[int, int]] = []
        for b in np.nonzero(self.cand)[0]:
            s = int(b) * self.block
            e = min(self.n_rows, s + self.block)
            if out and out[-1][1] == s:
                out[-1] = (out[-1][0], e)
            else:
                out.append((s, e))
        return out


def derive_skip_sets(plan: PlanNode, db) -> dict:
    """Walk ``Filter(Scan)`` shapes over base tables and intersect each
    simple-range conjunct's imprint candidate bitmap into one ``SkipSet``
    per scan, keyed by ``id(scan_node)`` (plan-cache copies are shallow, so
    the normalized plan objects — and hence the keys — are shared).

    Gated on ``db.data_skipping`` (the forced-off knob the differential
    harness flips) and on the database having an ``IndexManager``; scans
    with no applicable imprint simply get no entry."""
    out: dict[int, SkipSet] = {}
    im = getattr(db, "index_manager", None)
    if im is None or not getattr(db, "data_skipping", True):
        return out

    def visit(node: PlanNode) -> None:
        if isinstance(node, FilterNode) and isinstance(node.child, ScanNode):
            scan = node.child
            try:
                table = db.catalog.table(scan.table)
            except Exception:
                table = None
            if table is not None:
                cand = None
                block = 0
                cols: list[str] = []
                for conj in split_conjuncts(node.predicate):
                    rng = _simple_range(conj)
                    if rng is None:
                        continue
                    cname, lo, hi, lo_s, hi_s = rng
                    info = im.candidate_info(scan.table, cname, lo, hi,
                                             lo_s, hi_s)
                    if info is None:
                        continue
                    c, block, _ = info
                    cand = c.copy() if cand is None else (cand & c)
                    cols.append(cname)
                if cand is not None:
                    out[id(scan)] = SkipSet(
                        scan.table, table.version, block, table.num_rows,
                        cand, tuple(cols))
        for c in node.children:
            visit(c)

    visit(plan)
    return out


# ---------------------------------------------------------------------------
# normalization: SQL and builder plans converge to identical shapes
# ---------------------------------------------------------------------------


def _conjoin(preds: list[Expr]) -> Expr:
    from .expression import BinOp
    out = preds[0]
    for p in preds[1:]:
        out = BinOp("and", out, p)
    return out


def _push_renames_into_agg(proj: ProjectNode, agg: AggregateNode,
                           catalog) -> Optional[AggregateNode]:
    """Project(Aggregate) that only renames — group keys identity-mapped in
    key order, then every aggregate output referenced exactly once, in agg
    order — folds into the aggregate's own output names.  This is the SQL
    front-end's ``__aggN`` rename projection; eliding it is what lets the
    device-tier matcher see SQL aggregates."""
    keys = list(agg.group_by)
    exprs = list(proj.exprs)
    if len(exprs) != len(keys) + len(agg.aggs):
        return None
    if any(not isinstance(e, Col) for e, _ in exprs):
        return None
    for (e, n), k in zip(exprs[:len(keys)], keys):
        if e.name != k or n != k:
            return None
    new_aggs = []
    for (e, n), a in zip(exprs[len(keys):], agg.aggs):
        if e.name != a.name:
            return None
        new_aggs.append(AggSpec(a.fn, a.expr, n))
    names = keys + [a.name for a in new_aggs]
    if len(set(names)) != len(names):
        return None
    return AggregateNode(agg.child, agg.group_by, tuple(new_aggs))


def normalize(plan: PlanNode, catalog) -> PlanNode:
    """Semantics-preserving canonicalization applied after optimization:

    * adjacent FilterNodes merge into one whose conjuncts are sorted by
      their (deterministic, value-based) repr — entry points that emitted
      the same predicates in different order converge, and the compiled
      step caches key on one canonical conjunct sequence;
    * identity projections (bare-Col, same names, same order as the child's
      output) are elided;
    * pure-rename projections over an aggregate fold into the aggregate's
      output names (only when the output column order is preserved — a
      reordering projection stays, since result column order is
      observable through the embedding API)."""
    node = plan.with_children(
        tuple(normalize(c, catalog) for c in plan.children))
    if isinstance(node, FilterNode):
        conjs: list[Expr] = []
        inner: PlanNode = node
        while isinstance(inner, FilterNode):
            conjs.extend(split_conjuncts(inner.predicate))
            inner = inner.child
        conjs.sort(key=repr)
        return FilterNode(inner, _conjoin(conjs))
    if isinstance(node, ProjectNode):
        child = node.child
        if all(isinstance(e, Col) and e.name == n for e, n in node.exprs):
            try:
                if [n for _, n in node.exprs] == \
                        list(child.output_columns(catalog)):
                    return child
            except Exception:
                pass
        if isinstance(child, AggregateNode):
            pushed = _push_renames_into_agg(node, child, catalog)
            if pushed is not None:
                return pushed
    return node


# ---------------------------------------------------------------------------
# the costed tier policy — the ONE home of routing thresholds
# ---------------------------------------------------------------------------


@dataclass
class TierPolicy:
    """Every tier-routing threshold in the engine, as one object.

    Plan-time annotation and runtime refinement both go through these
    methods; the executors hold a policy but contain no routing logic of
    their own.  The byte models mirror what the operators actually pin:
    blocking state per row is the key bytes plus ~16 bytes of
    index/gid/bookkeeping overhead."""

    bufman: object = None                 # host BufferManager (or None)
    devman: object = None                 # DeviceBufferManager (or None)

    @classmethod
    def for_db(cls, db) -> "TierPolicy":
        return cls(bufman=getattr(db, "buffer_manager", None),
                   devman=getattr(db, "device_manager", None))

    # -- budgets --------------------------------------------------------------
    @property
    def host_budget(self) -> Optional[int]:
        return None if self.bufman is None else self.bufman.budget

    @property
    def device_budget(self) -> Optional[int]:
        return None if self.devman is None else self.devman.budget

    def over_budget(self, est_bytes: float) -> bool:
        b = self.host_budget
        return b is not None and est_bytes > b

    # -- blocking-operator state models (bytes the op would pin) --------------
    @staticmethod
    def join_state_bytes(n_left: int, n_right: int, key_bytes: int) -> int:
        return (n_left + n_right) * (key_bytes + 16)

    @staticmethod
    def group_state_bytes(n_rows: int, key_bytes: int) -> int:
        return n_rows * (key_bytes + 16)

    @staticmethod
    def sort_state_bytes(n_rows: int, n_keys: int) -> int:
        return n_rows * 8 * (n_keys + 1)

    # -- runtime tier decisions (actual cardinalities) ------------------------
    def blocking_tier(self, est_bytes: float) -> str:
        return TIER_SPILL if self.over_budget(est_bytes) else TIER_IN_MEMORY

    def spills(self, est_bytes: float) -> bool:
        return self.blocking_tier(est_bytes) == TIER_SPILL

    def group_spills(self, n_rows: int, key_bytes: int,
                     probe_groups: Callable[[], int]) -> bool:
        """Grace-hash only when the input AND the probed grouping state are
        both over budget: a low-cardinality grouping (few distinct keys)
        stays in memory — its blocking state is tiny no matter how large
        the input, and partitioning by key could never split the dominant
        groups.  ``probe_groups`` samples actual rows (level-3 runtime
        statistics) and is only paid when the cheap input test trips."""
        if not self.over_budget(self.group_state_bytes(n_rows, key_bytes)):
            return False
        return self.over_budget(
            self.group_state_bytes(probe_groups(), key_bytes))

    def result_spills(self, total_bytes: int) -> bool:
        """Budgeted result materialization: over-budget final tables stream
        to memmapped columns instead of a second RAM materialization."""
        return self.bufman is not None and self.over_budget(total_bytes)

    # -- volcano row-spool estimate (was volcano._spool_estimate) -------------
    def row_spool_estimate(self, node: AggregateNode,
                           catalog) -> Optional[int]:
        """Input-size estimate when a volcano aggregate should spool, else
        None (one plan walk decides *and* sizes the partition fan-out).
        Volcano rows hold *decoded* values: a VARCHAR cell is the full
        string, not an 8-byte code, so string columns carry their average
        decoded heap width on top of ``estimate_bytes``' flat rate."""
        if self.host_budget is None or not node.group_by:
            return None
        est = estimate_bytes(node.child, catalog) \
            + _varchar_row_surcharge(node.child, catalog)
        return int(est) if est > self.host_budget else None

    # -- device placement -----------------------------------------------------
    def device_tier(self, geom: ScanAggGeometry, table: str) -> str:
        hits = 0 if self.devman is None else self.devman.hit_history(table)
        return choose_device_tier(
            geom.resident_bytes, geom.batch_bytes, self.device_budget,
            host_budget=self.host_budget, host_bytes=geom.resident_bytes,
            hit_history=hits)

    def device_join_tier(self, geom: JoinAggGeometry) -> str:
        return choose_device_join_tier(
            geom.resident_bytes, geom.working_bytes,
            self.device_budget, self.host_budget)


def _varchar_row_surcharge(node: PlanNode, catalog) -> float:
    if isinstance(node, ScanNode):
        extra = 0.0
        t = catalog.table(node.table)
        for name in (node.columns or t.schema.names):
            col = t.columns[name]
            if col.dbtype == DBType.VARCHAR and len(col.heap):
                extra += len(col) * (col.heap.nbytes() / len(col.heap))
        return extra
    extra = sum(_varchar_row_surcharge(c, catalog) for c in node.children)
    if isinstance(node, FilterNode) and extra:
        # scale by the filter's estimated selectivity, mirroring how
        # estimate_bytes scales its flat per-column rate by estimate_rows
        rows_in = estimate_rows(node.child, catalog)
        rows_out = estimate_rows(node, catalog)
        extra *= rows_out / max(1.0, rows_in)
    return extra


# ---------------------------------------------------------------------------
# the physical plan
# ---------------------------------------------------------------------------


@dataclass
class PhysicalOp:
    """One operator's tier annotation: the decision, the byte estimate it
    was made from, and the budget reservation the tier implies (what the
    operator expects to pin — the whole state in memory, at most the
    budget when spilling, the double-buffered batch working set when
    streaming devices)."""
    node: PlanNode
    tier: str
    est_bytes: int = 0
    reservation: int = 0
    detail: str = ""
    children: tuple = ()

    def lines(self, indent: int = 0) -> list[str]:
        pad = "  " * indent
        extra = f" {self.detail}" if self.detail else ""
        out = [f"{pad}{node_line(self.node)}"
               f" :: {self.tier}"
               f" [est={self.est_bytes}B reserve={self.reservation}B]"
               f"{extra}"]
        for c in self.children:
            out.extend(c.lines(indent + 1))
        return out


@dataclass
class PhysicalPlan:
    """The lowering result every executor consumes."""
    plan: PlanNode                        # normalized logical plan
    policy: TierPolicy
    catalog: object
    scan_agg: Optional[ScanAggSpec] = None
    agg_core: Optional[AggregateNode] = None
    agg_tier: Optional[str] = None        # device-*/parallel-host when set
    suffix_plan: Optional[PlanNode] = None
    geometry: Optional[ScanAggGeometry] = None
    # device join tier: the matched join-agg core and its geometry.  The
    # join runs in one of two modes ("resident"/"streamed"); both annotate
    # as TIER_DEVICE_JOIN — the mode shows in the operator detail.
    join_agg: Optional[JoinAggSpec] = None
    join_geometry: Optional[JoinAggGeometry] = None
    join_mode: Optional[str] = None
    # device sort tier: the ORDER BY suffix node fused onto a device core
    # (sort keys computed + lexsorted in HBM; only the top rows fetched)
    sort_node: Optional[OrderByNode] = None
    sort_on_device: bool = False
    distributed: bool = False
    # observed group cardinality from a previous execution of this plan
    # shape (serving.PlanCache feedback) — refines the aggregate's
    # annotation with what the runtime actually saw instead of the
    # level-1 row estimate.  Only set when the plan has exactly one
    # aggregate (otherwise the observation is ambiguous).
    group_card_hint: Optional[int] = None
    # imprint-derived skip-sets keyed by id(scan node) — shared by shallow
    # plan-cache copies because the normalized plan objects are shared
    skip_sets: dict = field(default_factory=dict)
    _reservations: Optional[tuple] = None   # cached total_reservations()

    # -- queries --------------------------------------------------------------
    def device_tier(self) -> bool:
        return self.agg_tier in DEVICE_TIERS

    def demote_device(self, reason: str = "runtime fallback") -> None:
        """A device attempt failed at runtime (lowering gap, placement
        race): the core re-routes to the host program.  The annotation is
        updated so EXPLAIN output reflects what actually ran.  A fused
        device sort demotes with its core — the host suffix re-sorts."""
        self.agg_tier = TIER_PARALLEL_HOST
        self.sort_on_device = False
        self._demote_reason = reason

    def total_reservations(self) -> tuple[int, int]:
        """Summed per-operator budget reservations as ``(host_bytes,
        device_bytes)`` — what the admission gate reserves before this plan
        executes.  Each side is capped at its budget: a plan whose
        reservations sum past the budget is exactly what the spill/stream
        tiers bound at runtime, and it must be admissible when alone.
        Computed once and cached (shallow plan-cache copies share it)."""
        if self._reservations is None:
            host = device = 0

            def visit(op: PhysicalOp):
                nonlocal host, device
                if op.tier in DEVICE_TIERS:
                    device += op.reservation
                else:
                    host += op.reservation
                for c in op.children:
                    visit(c)

            visit(self.annotate())
            hb = self.policy.host_budget
            db = self.policy.device_budget
            if hb is not None:
                host = min(host, hb)
            if db is not None:
                device = min(device, db)
            self._reservations = (int(host), int(device))
        return self._reservations

    def skip_set_for(self, node: PlanNode) -> Optional[SkipSet]:
        return self.skip_sets.get(id(node))

    def core_skip_set(self) -> Optional[SkipSet]:
        """The skip-set attached to the scan-agg core's base scan, if any
        (what ``DistributedScanAgg`` intersects with its batch geometry)."""
        node: Optional[PlanNode] = self.agg_core
        while node is not None:
            if isinstance(node, ScanNode):
                return self.skip_sets.get(id(node))
            node = node.children[0] if node.children else None
        return None

    def skip_set_for_table(self, name: str) -> Optional[SkipSet]:
        """The skip-set attached to the (unique, by the join matcher's
        no-self-join rule) base scan of ``name`` — what the per-table
        streams of a device join consult on the probe and build sides."""
        for n in _walk_nodes(self.plan):
            if isinstance(n, ScanNode) and n.table == name:
                ss = self.skip_sets.get(id(n))
                if ss is not None:
                    return ss
        return None

    def _skip_note(self, node: PlanNode) -> str:
        ss = self.skip_sets.get(id(node))
        if ss is None:
            return ""
        return f"(skip: {ss.n_skipped}/{ss.n_blocks} blocks)"

    def _delta_note(self, node: PlanNode) -> str:
        """Merge-on-read visibility in EXPLAIN: a base-table scan whose
        table carries an uncompacted delta tail says how many rows it will
        merge on read."""
        if not isinstance(node, ScanNode):
            return ""
        t = self.catalog.tables.get(node.table) \
            if hasattr(self.catalog, "tables") else None
        if t is None or not t.delta_rows:
            return ""
        return f"(delta: {t.delta_rows} rows)"

    # -- annotation -----------------------------------------------------------
    def annotate(self) -> PhysicalOp:
        return self._annotate(self.plan)

    def _annotate(self, node: PlanNode) -> PhysicalOp:
        if node is self.agg_core and self.agg_tier in (
                TIER_DEVICE_RESIDENT, TIER_DEVICE_STREAMED,
                TIER_DEVICE_JOIN):
            return self._annotate_core(node)
        if node is self.sort_node and self.sort_on_device:
            children = tuple(self._annotate(c) for c in node.children)
            est = int(self.policy.sort_state_bytes(
                self._core_groups(), len(node.keys)))
            return PhysicalOp(node, TIER_DEVICE_SORT, est, est,
                              "(fused onto device core)", children)
        children = tuple(self._annotate(c) for c in node.children)
        policy = self.policy
        budget = policy.host_budget
        if isinstance(node, JoinNode):
            est = int(policy.join_state_bytes(
                estimate_rows(node.left, self.catalog),
                estimate_rows(node.right, self.catalog),
                8 * len(node.left_keys)))
            tier = policy.blocking_tier(est)
        elif isinstance(node, AggregateNode):
            kb = 8 * max(1, len(node.group_by))
            est = int(policy.group_state_bytes(
                estimate_rows(node.child, self.catalog), kb))
            tier = policy.blocking_tier(est)
            if self.group_card_hint is not None and node.group_by:
                # cardinality feedback (serving.PlanCache): a previous run
                # observed the actual group count, so mirror the runtime
                # rule — spill only when the input state AND the observed
                # grouping state are both over budget.  A low-cardinality
                # grouping annotates in-memory no matter how large the
                # input, exactly as it will execute.
                observed = int(policy.group_state_bytes(
                    self.group_card_hint, kb))
                tier = TIER_SPILL if (policy.over_budget(est)
                                      and policy.over_budget(observed)) \
                    else TIER_IN_MEMORY
                est = observed if tier == TIER_IN_MEMORY else est
        elif isinstance(node, OrderByNode):
            est = int(policy.sort_state_bytes(
                estimate_rows(node.child, self.catalog), len(node.keys)))
            tier = policy.blocking_tier(est)
        else:
            est = int(estimate_rows(node, self.catalog) * 8)
            tier = TIER_IN_MEMORY
        reserve = est if tier == TIER_IN_MEMORY \
            else min(est, budget if budget is not None else est)
        detail = "(runtime-refined)" if tier == TIER_SPILL or (
            isinstance(node, (JoinNode, AggregateNode, OrderByNode))
            and budget is not None) else ""
        if isinstance(node, AggregateNode) and node.group_by \
                and self.group_card_hint is not None:
            detail = f"{detail} (observed groups=" \
                     f"{self.group_card_hint})".strip()
        if node is self.agg_core and self.agg_tier == TIER_PARALLEL_HOST:
            # the core matched a device pattern but runs as an ordinary
            # host program (device declined, or a runtime fallback) —
            # annotate with the HOST byte model like any other aggregate,
            # and record why the device tier was not used
            kind = "join-agg" if self.join_agg is not None else "scan-agg"
            extra = f"{kind} core kept on host"
            if getattr(self, "_demote_reason", None):
                extra += f" ({self._demote_reason})"
            detail = f"{detail} {extra}".strip()
        note = self._skip_note(node)
        if note:
            detail = f"{detail} {note}".strip()
        dnote = self._delta_note(node)
        if dnote:
            detail = f"{detail} {dnote}".strip()
        return PhysicalOp(node, tier, est, reserve, detail, children)

    def _core_groups(self) -> int:
        if self.join_agg is not None:
            return self.join_agg.n_groups
        if self.scan_agg is not None:
            return self.scan_agg.n_groups
        return 1

    def _annotate_core(self, node: PlanNode) -> PhysicalOp:
        """A device-routed scan-agg or join-agg core: one tier decision
        covers the whole fused subtree (filters, scans and — for the join
        tier — the build/probe joins execute inside the jitted steps)."""
        if self.agg_tier == TIER_DEVICE_JOIN:
            g = self.join_geometry
            if self.join_mode == "resident":
                est, reserve = g.resident_bytes, g.resident_bytes
            else:
                est, reserve = g.resident_bytes, g.working_bytes
            detail = f"groups={self.join_agg.n_groups}"
            detail += f" builds={len(self.join_agg.builds)}"
            detail += f" mode={self.join_mode}"
            detail += f" batches={g.n_batches}x{g.batch_rows}rows"
        else:
            g = self.geometry
            if self.agg_tier == TIER_DEVICE_RESIDENT:
                est, reserve = g.resident_bytes, g.resident_bytes
            else:
                est, reserve = g.resident_bytes, 2 * g.batch_bytes
            detail = f"groups={self.scan_agg.n_groups}"
            detail += f" batches={g.n_batches}x{g.batch_rows}rows"

        def fused(n: PlanNode) -> PhysicalOp:
            d = "(fused)"
            note = self._skip_note(n)
            if note:
                d = f"{d} {note}"
            dnote = self._delta_note(n)
            if dnote:
                d = f"{d} {dnote}"
            return PhysicalOp(
                n, self.agg_tier, 0, 0, d,
                tuple(fused(c) for c in n.children))

        return PhysicalOp(node, self.agg_tier, int(est), int(reserve),
                          detail, tuple(fused(c) for c in node.children))

    # -- rendering ------------------------------------------------------------
    def render(self) -> str:
        head = "physical plan"
        if self.distributed:
            head += " [distributed]"
        b = self.policy.host_budget
        d = self.policy.device_budget
        head += f" memory_budget={b if b is not None else 'unlimited'}"
        head += f" device_budget={d if d is not None else 'unlimited'}"
        return "\n".join([head] + self.annotate().lines())

    def tier_summary(self) -> list[tuple[str, str]]:
        """(operator kind, tier) pairs in pre-order, skipping projections —
        the shape two entry points must agree on even when one carries a
        residual (trivial, reordering) projection the other lacks."""
        out: list[tuple[str, str]] = []

        def walk(op: PhysicalOp):
            if not isinstance(op.node, ProjectNode):
                out.append((type(op.node).__name__, op.tier))
            for c in op.children:
                walk(c)

        walk(self.annotate())
        return out


# ---------------------------------------------------------------------------
# the lowering pass
# ---------------------------------------------------------------------------


def _walk_nodes(node: PlanNode):
    yield node
    for c in node.children:
        yield from _walk_nodes(c)


def plan_physical(plan: PlanNode, db, *, do_optimize: bool = True,
                  distributed: bool = False, mesh=None,
                  group_card_hint: Optional[int] = None) -> PhysicalPlan:
    """Lower one logical plan to its physical plan: optimize (level 1),
    normalize (entry-point convergence), find the scan-agg core + suffix,
    and annotate tiers.  ``distributed`` enables the device tiers and — if
    no ``mesh`` is given — derives the default mesh from ``jax.devices()``
    (the only path that touches the accelerator runtime; plain host
    planning never imports jax).  ``group_card_hint`` is an observed group
    cardinality from a previous run of the same plan shape
    (``serving.PlanCache`` feedback); it refines the aggregate annotation
    and only applies when the plan has exactly one aggregate."""
    catalog = db.catalog
    if do_optimize:
        plan = optimize(plan, catalog)
    plan = normalize(plan, catalog)
    policy = TierPolicy.for_db(db)
    phys = PhysicalPlan(plan, policy, catalog, distributed=distributed)
    if group_card_hint is not None:
        n_aggs = sum(isinstance(n, AggregateNode)
                     for n in _walk_nodes(plan))
        if n_aggs == 1:
            phys.group_card_hint = int(group_card_hint)
    # imprint-driven data skipping (paper §3.1): every tier — device batch
    # streams, host morsels, volcano rows — consumes the same plan-time
    # skip-sets, so derivation happens before the host-only early return
    phys.skip_sets = derive_skip_sets(plan, db)
    if not distributed:
        # the sequential host path never consumes the scan-agg spec, and
        # matching is not free (dense-domain detection scans each group
        # key's min/max) — only the distributed lowering pays for it
        return phys

    core, suffix = find_scan_agg_core(plan, catalog)
    if core is None:
        return phys
    spec = match_scan_agg(core, catalog)
    jspec = match_join_agg(core, catalog) if spec is None else None
    if spec is None and jspec is None:
        return phys
    phys.agg_core = core
    phys.suffix_plan = suffix
    shard_table = catalog.table(spec.table if spec is not None
                                else jspec.probe_table)
    if spec is not None:
        phys.scan_agg = spec
    else:
        phys.join_agg = jspec
    if shard_table.num_rows < MIN_ROWS_TO_SHARD:
        return phys
    if mesh is None:
        import jax
        from jax.sharding import Mesh
        mesh = Mesh(np.array(jax.devices()).reshape(-1), ("data",))
    shards = mesh_shards(mesh)
    batch_rows = getattr(db, "device_batch_rows", None)
    if spec is not None:
        geom = scan_agg_geometry(spec, shard_table, shards, batch_rows)
        phys.geometry = geom
        tier = policy.device_tier(geom, spec.table)
        phys.agg_tier = {"resident": TIER_DEVICE_RESIDENT,
                         "streamed": TIER_DEVICE_STREAMED,
                         "host": TIER_PARALLEL_HOST}[tier]
    else:
        jgeom = join_agg_geometry(jspec, catalog, shards, batch_rows)
        phys.join_geometry = jgeom
        mode = policy.device_join_tier(jgeom)
        phys.join_mode = None if mode == "host" else mode
        phys.agg_tier = TIER_PARALLEL_HOST if mode == "host" \
            else TIER_DEVICE_JOIN
    # ORDER BY directly over a device-routed core fuses onto the device:
    # sort keys are computed and lexsorted in HBM, only the surviving rows
    # come back.  Any deeper suffix (projection, HAVING) keeps the host
    # suffix path — the assembled aggregate is tiny there anyway.
    if phys.agg_tier in DEVICE_TIERS and isinstance(plan, OrderByNode) \
            and plan.children[0] is core:
        try:
            outputs = set(core.output_columns(catalog))
        except Exception:
            outputs = set()
        if outputs and all(col in outputs for col, _ in plan.keys):
            phys.sort_node = plan
            phys.sort_on_device = True
    return phys
