"""Zero-copy + lazy data exchange (paper §3.3, adapted per DESIGN.md §3).

Three transfer paths between the engine and the embedding analytical code:

* **zero_copy_view(col)** — a read-only numpy view over the engine's own
  packed buffer.  No bytes move; the read-only flag is the functional
  equivalent of the paper's mprotect write-trap, and ``copy_for_write``
  gives the copy-on-write escape hatch.
* **to_device(col)** — the engine's device tier handed to JAX; on the host
  platform this aliases through dlpack when bit-compatible (the zero-copy
  condition of §3.3), otherwise it is the one explicit conversion.
* **LazyFrame** — the lazy-conversion path (paper Fig. 4): a query result
  whose columns are *thunks*; decode work (dict decode, date decode, NULL
  rewrite) happens on first access per column, never for untouched columns.
  ``conversions`` counts materializations so tests/benchmarks can assert
  SELECT * + touch-one-column converts exactly one column.

Header forgery has no TPU-side analogue to forge (DESIGN.md §3): a
``jax.Array``/numpy view already separates the header object from the
buffer, so metadata prepending is free; the invariant we keep from the
paper is *O(1) transfer cost, independent of data size* — asserted in
benchmarks/bench_export.py.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from .column import Column
from .table import Table
from .types import DBType, is_float


def zero_copy_view(col: Column) -> np.ndarray:
    """Read-only view of the packed storage array (no copy, O(1))."""
    v = np.asarray(col.data)
    view = v.view()
    view.flags.writeable = False
    return view


def copy_for_write(col: Column) -> np.ndarray:
    """Copy-on-write escape hatch: a private, writable copy."""
    return np.array(col.data, copy=True)


def is_zero_copy_eligible(col: Column) -> bool:
    """Bit-compatibility rule of §3.3: numeric fixed-width columns share
    their buffer; VARCHAR/DECIMAL/BOOL/DATE need decoding."""
    return col.dbtype in (DBType.INT32, DBType.INT64,
                          DBType.FLOAT32, DBType.FLOAT64) \
        and (is_float(col.dbtype) or not col.has_nulls())


def to_device(col: Column):
    """Engine column -> jax.Array (device tier). Cached on the column."""
    return col.device()


class LazyFrame:
    """Lazily-converted result set (paper's 'dummy arrays' + fault handler,
    restated as thunks)."""

    def __init__(self, table: Table):
        self._table = table
        self._cache: dict[str, np.ndarray] = {}
        self.conversions = 0
        self.zero_copies = 0

    @property
    def columns(self) -> list[str]:
        return list(self._table.schema.names)

    @property
    def num_rows(self) -> int:
        return self._table.num_rows

    def __getitem__(self, name: str) -> np.ndarray:
        if name not in self._cache:
            col = self._table.columns[name]
            if is_zero_copy_eligible(col):
                self._cache[name] = zero_copy_view(col)
                self.zero_copies += 1
            else:
                self._cache[name] = col.to_numpy()
                self.conversions += 1
        return self._cache[name]

    def touched(self) -> list[str]:
        return list(self._cache)


def export_table(table: Table, lazy: bool = True):
    """The dbReadTable path (paper Fig. 6): lazy by default."""
    if lazy:
        return LazyFrame(table)
    return table.to_pydict()


def import_arrays(name: str, data: dict[str, np.ndarray],
                  types: Optional[dict] = None) -> Table:
    """The dbWriteTable path (paper Fig. 5): bulk columnar ingest.  Numeric
    numpy arrays are adopted without copy (the engine stores the same
    buffer); only strings/objects are encoded."""
    return Table.from_dict(name, data, types)
