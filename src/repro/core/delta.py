"""Delta store: O(delta-rows) appends over the immutable columnar base.

The paper's ACID story installs a whole new table version per append; that
makes a hot append O(table) (every column rewritten on checkpoint) and a
giant bulk load fully resident.  Following the delta-store design from
"Mainlining Databases" (PAPERS.md), an append now installs an immutable
*delta chunk* next to the untouched base version:

* **write side** — ``delta_append`` returns a ``DeltaTable`` sharing the
  same base object, so commit cost and WAL traffic are O(delta rows).
* **read side** — merge-on-read: ``DeltaTable.columns`` materializes the
  concatenated (base ++ chunks) columns lazily, once, so every executor
  (sequential, device, volcano) consumes one stream bit-identical to the
  eager-append layout.
* **compaction** — ``compact`` folds the tail back into a plain base table
  once it exceeds a configurable fraction (threshold checked by the
  transaction manager under the commit lock).  The fold is content- and
  version-identical, so version-fenced consumers (skip-sets, imprints,
  optimistic validation) survive the swap unchanged.

Keeping the base blocks immutable is what lets the device block cache and
the imprints stay valid for the base portion across appends — the
lakehouse argument from "The Data Lakehouse" (PAPERS.md).
"""

from __future__ import annotations

import threading
from typing import Optional

import numpy as np

from .column import Column, heaps_equal
from .table import Table
from .types import DBType

# Fallback compaction granularity when no memory budget is configured
# (matches storage.MORSEL_ROWS; not imported to keep this module cycle-free).
_MORSEL_ROWS = 1 << 16


class DeltaTable(Table):
    """Immutable base version + an ordered tail of append chunks.

    Readers see one logical table: the ``columns`` property merges
    (base ++ chunks) lazily under ``_merge_lock``.  Writers never touch the
    base — ``delta_append`` returns a new ``DeltaTable`` sharing the same
    base object, so an append costs O(delta rows) regardless of table size.

    VARCHAR invariant: every chunk's codes are already expressed in the
    *base* column's heap (``delta_append`` recodes on the way in, and falls
    back to a full rebase when a novel value would re-sort the heap), so
    the merge is a plain concatenate for every type.

    ``version`` advances by one per chunk — exactly the sequence the eager
    ``append_table`` path would have produced — so optimistic conflict
    detection, skip-set fencing, and imprint keys are unchanged.
    """

    def __init__(self, base: Table, chunks: tuple):
        # Deliberately not calling the dataclass __init__: ``columns`` is a
        # read-only merging property here, not a stored field.
        self.schema = base.schema
        self.base = base
        self.chunks = tuple(chunks)
        self.version = base.version + len(self.chunks)
        self._tail_rows = int(sum(c.num_rows for c in self.chunks))
        self._merge_lock = threading.Lock()
        self._merged = None

    # -- delta geometry ------------------------------------------------------
    @property
    def base_version(self) -> int:
        return self.base.version

    @property
    def delta_epoch(self) -> int:
        return len(self.chunks)

    @property
    def base_rows(self) -> int:
        return self.base.num_rows

    @property
    def delta_rows(self) -> int:
        return self._tail_rows

    @property
    def num_rows(self) -> int:
        # Never materializes the merge: the planner asks for row counts far
        # more often than anyone scans.
        return self.base.num_rows + self._tail_rows

    # -- merge-on-read -------------------------------------------------------
    @property
    def columns(self) -> dict[str, Column]:
        with self._merge_lock:
            if self._merged is None:
                self._merged = {
                    cs.name: _concat_column(
                        [self.base.columns[cs.name]]
                        + [c.columns[cs.name] for c in self.chunks])
                    for cs in self.schema.columns}
            return self._merged

    def column_pieces(self, name: str) -> list[Column]:
        """The physical pieces (base column first) without merging."""
        return ([self.base.columns[name]]
                + [c.columns[name] for c in self.chunks])

    def tail_array(self, name: str, start: int) -> np.ndarray:
        """Raw storage values of rows ``[start:]`` without materializing the
        merge — O(rows returned) when ``start >= base_rows`` (the incremental
        imprint-extension path)."""
        pieces, off = [], 0
        for col in self.column_pieces(name):
            n = len(col)
            s = max(start - off, 0)
            if s < n:
                pieces.append(np.asarray(col.data)[s:n])
            off += n
        if not pieces:
            return np.empty(0, dtype=self.base.columns[name].data.dtype)
        return pieces[0] if len(pieces) == 1 else np.concatenate(pieces)

    def __repr__(self) -> str:
        return (f"DeltaTable({self.schema.name!r}, version={self.version}, "
                f"base_rows={self.base_rows}, delta_rows={self.delta_rows})")


def _concat_column(pieces: list) -> Column:
    head = pieces[0]
    if len(pieces) == 1:
        return head
    data = np.concatenate([np.asarray(p.data) for p in pieces])
    return Column(head.dbtype, data, heap=head.heap, scale=head.scale)


def _recode_to_base(base: Table, chunk: Table) -> Optional[Table]:
    """Re-express ``chunk`` in the base's column heaps.

    Returns None when a VARCHAR chunk carries a value absent from the base
    heap: order preservation would re-sort the heap and recode the *base*
    codes (prefix instability), so the caller must rebase instead.
    """
    cols: dict[str, Column] = {}
    for cs in base.schema.columns:
        c = chunk.columns[cs.name]
        bcol = base.columns[cs.name]
        if c.dbtype != bcol.dbtype:
            raise TypeError(
                f"append type mismatch {bcol.dbtype} vs {c.dbtype}")
        if c.dbtype != DBType.VARCHAR:
            cols[cs.name] = c
            continue
        if heaps_equal(c.heap, bcol.heap):
            cols[cs.name] = Column(DBType.VARCHAR, np.asarray(c.data),
                                   heap=bcol.heap)
            continue
        strings = [None if code == 0 else str(c.heap.values[code])
                   for code in c.data]
        heap, _recode, new_codes = bcol.heap.merge(strings)
        if heap is not bcol.heap:     # novel value: heap re-sorted
            return None
        cols[cs.name] = Column(DBType.VARCHAR, new_codes, heap=bcol.heap)
    return Table(base.schema, cols)


def delta_append(t: Table, chunk: Table) -> Table:
    """Append ``chunk`` to ``t`` as an immutable delta chunk when possible.

    Returns a ``DeltaTable`` sharing ``t``'s base (an O(delta) install).
    Falls back to the eager ``append_table`` copy — a *rebase* — when a
    VARCHAR chunk would force a heap re-sort; either way the result's
    ``version`` is ``t.version + 1``.
    """
    names = {cs.name for cs in t.schema.columns}
    if set(chunk.columns) != names:
        raise ValueError("append schema mismatch")
    base = t.base if isinstance(t, DeltaTable) else t
    recoded = _recode_to_base(base, chunk)
    if recoded is None:
        return t.append_table(chunk)
    chunks = (t.chunks + (recoded,)) if isinstance(t, DeltaTable) \
        else (recoded,)
    return DeltaTable(base, chunks)


def should_compact(t: Table, fraction: Optional[float],
                   memory_budget: Optional[int] = None) -> bool:
    """Threshold policy: fold the tail once it exceeds ``fraction`` of the
    memory budget (bytes) — or, unbudgeted, ``fraction`` of the base rows
    (at least one morsel, so tiny tables don't compact on every append)."""
    if not isinstance(t, DeltaTable) or not t.delta_rows or not fraction:
        return False
    if memory_budget:
        tail_bytes = sum(c.nbytes for c in t.chunks)
        return tail_bytes > fraction * memory_budget
    return t.delta_rows > fraction * max(t.base_rows, _MORSEL_ROWS)


def compact(t: DeltaTable, storage=None, bufman=None) -> Table:
    """Fold the delta tail into a plain base table.

    The fold is content- and version-identical to the ``DeltaTable`` it
    replaces (a pure representation change), so skip-sets, imprints, and
    optimistic version checks remain valid across the swap.  With a
    persistent ``storage``, each column streams morsel-wise into its
    versioned column file and the result adopts the memmap — compaction
    peak memory is O(morsel), not O(table).
    """
    cols: dict[str, Column] = {}
    for cs in t.schema.columns:
        pieces = [np.asarray(p.data) for p in t.column_pieces(cs.name)]
        head = t.base.columns[cs.name]
        if storage is not None:
            data = storage.write_column_pieces(
                t.schema.name, cs.name, t.version, pieces, bufman=bufman)
        else:
            data = np.concatenate(pieces)
        cols[cs.name] = Column(head.dbtype, data, heap=head.heap,
                               scale=head.scale)
    return Table(t.schema, cols, version=t.version)
