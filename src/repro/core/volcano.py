"""Row-at-a-time volcano baseline engine.

The paper benchmarks MonetDBLite against row-store, tuple-at-a-time systems
(SQLite/PostgreSQL/MariaDB §4) and attributes their poor analytical
performance to (a) row-wise storage forcing whole-table scans and (b)
per-tuple interpretation overhead.  Per the "implement the baseline too"
rule, this module is that comparator: the same logical plans interpreted
through Python-level row iterators with per-row expression evaluation.
Benchmarks run identical queries through both engines (bench_tpch.py).

It materializes rows as dicts — intentionally; the point of the baseline is
the processing *model*, not an optimized row engine.
"""

from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

from .expression import (BinOp, Case, Cast, Col, DateLit, Expr, Func, InList,
                         IsNull, Like, Lit, Not)
from .relalg import (AggregateNode, FilterNode, JoinNode, LimitNode,
                     OrderByNode, PlanNode, ProjectNode, ScanNode)
from .types import DBType

Row = dict


def _eval_row(e: Expr, row: Row):
    """Scalar (per-tuple) expression interpreter — the volcano way."""
    if isinstance(e, Col):
        return row[e.name]
    if isinstance(e, Lit):
        return e.value
    if isinstance(e, DateLit):
        from .types import date_from_string
        return int(date_from_string(e.text))
    if isinstance(e, BinOp):
        l = _eval_row(e.left, row)
        r = _eval_row(e.right, row)
        if e.op == "and":
            return bool(l) and bool(r) if l is not None and r is not None else False
        if e.op == "or":
            return bool(l) or bool(r)
        if l is None or r is None:
            return None if e.op in ("+", "-", "*", "/", "%") else False
        return {"+": lambda: l + r, "-": lambda: l - r, "*": lambda: l * r,
                "/": lambda: l / r if r != 0 else None,
                "%": lambda: l % r if r != 0 else None,
                "=": lambda: l == r, "<>": lambda: l != r,
                "<": lambda: l < r, "<=": lambda: l <= r,
                ">": lambda: l > r, ">=": lambda: l >= r}[e.op]()
    if isinstance(e, Not):
        v = _eval_row(e.child, row)
        return not bool(v)
    if isinstance(e, IsNull):
        v = _eval_row(e.child, row)
        isnull = v is None or (isinstance(v, float) and np.isnan(v))
        return (not isnull) if e.negate else isnull
    if isinstance(e, InList):
        v = _eval_row(e.child, row)
        return v in e.values
    if isinstance(e, Like):
        import fnmatch
        v = _eval_row(e.child, row)
        if v is None:
            return False
        pat = e.pattern.replace("%", "*").replace("_", "?")
        return fnmatch.fnmatchcase(str(v), pat)
    if isinstance(e, Func):
        a = _eval_row(e.args[0], row)
        if a is None:
            return None
        import math
        if e.name.lower() == "year":
            from .types import date_year
            return int(date_year(np.asarray([a]))[0])
        return {"sqrt": lambda: math.sqrt(max(a, 0.0)),
                "abs": lambda: abs(a), "floor": lambda: math.floor(a),
                "ceil": lambda: math.ceil(a), "log": lambda: math.log(a),
                "exp": lambda: math.exp(a),
                "round": lambda: round(a, int(e.args[1].value)
                                       if len(e.args) > 1 else 0)}[e.name.lower()]()
    if isinstance(e, Case):
        for c, v in e.branches:
            if _eval_row(c, row):
                return _eval_row(v, row)
        return _eval_row(e.default, row)
    if isinstance(e, Cast):
        v = _eval_row(e.child, row)
        if v is None:
            return None
        if e.to in (DBType.INT32, DBType.INT64):
            return int(v)
        return float(v)
    raise TypeError(f"volcano cannot evaluate {type(e).__name__}")


class VolcanoExecutor:
    """Pull-based iterator interpreter (open/next/close model)."""

    def __init__(self, database):
        self.db = database
        from .physplan import TierPolicy
        self.policy = TierPolicy.for_db(database)

    def execute(self, plan: PlanNode) -> list[Row]:
        return list(self._iter(plan))

    def _iter(self, node: PlanNode) -> Iterator[Row]:
        if isinstance(node, ScanNode):
            # row-store emulation: decode EVERY column per row (the paper's
            # point about row stores scanning entire tables)
            t = self.db.catalog.table(node.table)
            self._note_delta(t)
            decoded = {n: t.columns[n].to_numpy() for n in t.schema.names}
            names = list(t.schema.names)
            for i in range(t.num_rows):
                yield {n: _denull(decoded[n][i]) for n in names}
        elif isinstance(node, FilterNode):
            if isinstance(node.child, ScanNode):
                yield from self._iter_filtered_scan(node)
            else:
                for row in self._iter(node.child):
                    if _eval_row(node.predicate, row):
                        yield row
        elif isinstance(node, ProjectNode):
            for row in self._iter(node.child):
                yield {n: _eval_row(e, row) for e, n in node.exprs}
        elif isinstance(node, JoinNode):
            # per-tuple hash join: build dict, probe row by row
            build: dict = {}
            for rrow in self._iter(node.right):
                k = tuple(rrow[c] for c in node.right_keys)
                build.setdefault(k, []).append(rrow)
            for lrow in self._iter(node.left):
                k = tuple(lrow[c] for c in node.left_keys)
                matches = build.get(k, [])
                if node.how == "semi":
                    if matches:
                        yield lrow
                elif node.how == "anti":
                    if not matches:
                        yield lrow
                elif node.how == "left" and not matches:
                    out = dict(lrow)
                    rcols = node.right.output_columns(self.db.catalog)
                    for c in rcols:
                        out.setdefault(c, None)
                    yield out
                else:
                    for rrow in matches:
                        out = dict(lrow)
                        for c, v in rrow.items():
                            out.setdefault(c, v)
                        yield out
        elif isinstance(node, AggregateNode):
            yield from self._iter_aggregate(node)
        elif isinstance(node, OrderByNode):
            rows = list(self._iter(node.child))
            for name, desc in reversed(node.keys):
                rows.sort(key=lambda r: _sort_key(r[name]),
                          reverse=desc)
            if node.limit is not None:
                rows = rows[:node.limit]
            yield from rows
        elif isinstance(node, LimitNode):
            for i, row in enumerate(self._iter(node.child)):
                if i >= node.n:
                    break
                yield row
        else:
            raise TypeError(f"volcano cannot run {type(node).__name__}")


    def _note_delta(self, t) -> None:
        """Merge-on-read visibility: count delta-tail rows the scan had to
        merge (the row baseline pays the same concatenation the columnar
        engine does, so the counter is engine-agnostic)."""
        dr = t.delta_rows
        if dr:
            bm = getattr(self.db, "buffer_manager", None)
            if bm is not None:
                bm.bump(delta_rows=dr)

    def _iter_filtered_scan(self, node: FilterNode) -> Iterator[Row]:
        """Filter directly over a base-table scan: consult the imprints
        (physplan.derive_skip_sets, re-derived here at execution time so
        the bitmap is inherently fresh) and only materialize rows of
        candidate blocks.  Every materialized row still evaluates the full
        predicate, so skipping stays advisory — blocks are dropped only
        when the zone maps prove no row can qualify.  Even the row-store
        baseline honors the paper's §3.1 claim this way."""
        scan = node.child
        from .physplan import derive_skip_sets
        ss = derive_skip_sets(node, self.db).get(id(scan))
        t = self.db.catalog.table(scan.table)
        self._note_delta(t)
        decoded = {n: t.columns[n].to_numpy() for n in t.schema.names}
        names = list(t.schema.names)
        if ss is None or not ss.n_skipped:
            ranges = [(0, t.num_rows)]
        else:
            ranges = ss.candidate_ranges()
            bm = getattr(self.db, "buffer_manager", None)
            if bm is not None:
                skipped_rows = t.num_rows - sum(e - s for s, e in ranges)
                row_width = sum(decoded[n].dtype.itemsize for n in names)
                bm.bump(blocks_skipped=ss.n_skipped,
                        bytes_skipped_spill=skipped_rows * row_width)
        for s, e in ranges:
            for i in range(s, e):
                row = {n: _denull(decoded[n][i]) for n in names}
                if _eval_row(node.predicate, row):
                    yield row

    # -- aggregation (in-memory + spooled out-of-core variants) --------------
    def _iter_aggregate(self, node: AggregateNode) -> Iterator[Row]:
        keyf = lambda row: tuple(row[c] for c in node.group_by)
        est = self._spool_estimate(node)
        if est is not None:
            # grace-style row grouping: rows spool to hash partitions on
            # disk; each group aggregates and frees before the next loads.
            # The fan-out follows the input estimate + budget, so a huge
            # input gets enough partitions for each to fit the budget.
            from .spill import spooled_row_groups
            bm = self.db.buffer_manager
            results = [(k, _agg_group(node, k, rows)) for k, rows in
                       spooled_row_groups(self._iter(node.child), keyf, bm,
                                          est_bytes=est)]
            bm.bump(spilled_ops=1)
        else:
            groups: dict[tuple, list[Row]] = {}
            for row in self._iter(node.child):
                groups.setdefault(keyf(row), []).append(row)
            results = [(k, _agg_group(node, k, rows))
                       for k, rows in groups.items()]
        if not results and not node.group_by:
            results = [((), _agg_group(node, (), []))]
        for _, out in sorted(results, key=lambda kv: tuple(
                (v is None, v) for v in kv[0])):
            yield out

    def _spool_estimate(self, node: AggregateNode) -> Optional[int]:
        """Input-size estimate when the aggregate should spool, else None
        (one plan walk decides *and* sizes the partition fan-out).  The
        estimate — including the decoded-VARCHAR surcharge volcano rows
        incur — lives in the unified tier policy (physplan.TierPolicy);
        this interpreter just consumes the decision."""
        return self.policy.row_spool_estimate(node, self.db.catalog)


def _agg_group(node: AggregateNode, k: tuple, rows: list[Row]) -> Row:
    out = dict(zip(node.group_by, k))
    for spec in node.aggs:
        out[spec.name] = _agg_rows(spec, rows)
    return out


def _sort_key(v):
    return (v is None or (isinstance(v, float) and np.isnan(v)), v)


def _denull(v):
    if isinstance(v, float) and np.isnan(v):
        return None
    return v


def _agg_rows(spec, rows: list[Row]):
    if spec.fn == "count" and spec.expr is None:
        return len(rows)
    vals = [_eval_row(spec.expr, r) for r in rows]
    vals = [v for v in vals
            if v is not None and not (isinstance(v, float) and np.isnan(v))]
    if spec.fn == "count":
        return len(vals)
    if spec.fn == "count_distinct":
        return len(set(vals))
    if not vals:
        return None
    if spec.fn == "sum":
        return sum(vals)
    if spec.fn == "avg":
        return sum(vals) / len(vals)
    if spec.fn == "min":
        return min(vals)
    if spec.fn == "max":
        return max(vals)
    if spec.fn == "median":
        s = sorted(vals)
        m = len(s)
        return 0.5 * (s[(m - 1) // 2] + s[m // 2])
    if spec.fn == "first":
        return vals[0]
    if spec.fn in ("var", "std"):
        mu = sum(vals) / len(vals)
        var = sum((v - mu) ** 2 for v in vals) / len(vals)
        return var ** 0.5 if spec.fn == "std" else var
    raise ValueError(spec.fn)
