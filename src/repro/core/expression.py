"""Scalar expression AST, evaluated column-at-a-time.

Expressions are compiled per-column (whole-column vector ops), matching the
MAL execution model: one ``eval`` call processes the full column before the
next operator runs.  Evaluation is backend-agnostic — the context carries the
array module (``numpy`` on the host tier, ``jax.numpy`` inside jit'd /
shard_map'd query fragments), and all null handling is expressed with
``where`` (branch-free, TPU-friendly) rather than item assignment.

SQL three-valued logic: every result carries an optional boolean null mask;
comparisons yield NULL when either side is NULL; ``Filter`` later treats
NULL as false.  VARCHAR predicates run on dictionary codes (order-preserving
heap, column.py), including LIKE which is evaluated once per *heap entry*
then mapped through the codes — the dictionary fast path MonetDB uses.
"""

from __future__ import annotations

import fnmatch
from dataclasses import dataclass
from typing import Any, Optional, Sequence

import numpy as np

from .column import StringHeap
from .types import DBType, NULL_SENTINEL, common_type, is_float

# ---------------------------------------------------------------------------
# evaluation result + context
# ---------------------------------------------------------------------------


@dataclass
class ExprResult:
    values: Any                       # np / jnp array (storage repr)
    dbtype: DBType
    null: Any = None                  # bool array or None (= no nulls)
    heap: Optional[StringHeap] = None
    scale: int = 0

    def null_or_false(self, xp):
        return self.null if self.null is not None else xp.zeros(
            self.values.shape, dtype=bool)

    def as_float(self, xp):
        """Numeric decode to float64 (DECIMAL -> scaled float)."""
        v = self.values
        if self.dbtype == DBType.DECIMAL:
            return v.astype(xp.float64) / (10 ** self.scale)
        return v.astype(xp.float64)


class EvalContext:
    """Resolves column references against a chunk of columns.

    ``arrays``: {name: array} storage-repr values.
    ``meta``:   {name: (DBType, heap, scale)}.
    ``xp``:     numpy or jax.numpy.
    """

    def __init__(self, arrays: dict, meta: dict, xp=np):
        self.arrays = arrays
        self.meta = meta
        self.xp = xp
        n = 0
        for a in arrays.values():
            n = a.shape[0]
            break
        self.num_rows = n

    def resolve(self, name: str) -> ExprResult:
        if name not in self.arrays:
            raise KeyError(f"unknown column {name!r}; have {list(self.arrays)}")
        t, heap, scale = self.meta[name]
        v = self.arrays[name]
        xp = self.xp
        if is_float(t):
            nullm = xp.isnan(v)
        else:
            nullm = v == NULL_SENTINEL[t]
        if hasattr(nullm, "any") and self.xp is np and not nullm.any():
            nullm = None
        return ExprResult(v, t, nullm, heap, scale)


# ---------------------------------------------------------------------------
# AST nodes
# ---------------------------------------------------------------------------


class Expr:
    def eval(self, ctx: EvalContext) -> ExprResult:  # pragma: no cover
        raise NotImplementedError

    def columns(self) -> set[str]:
        """Free column references (for projection pushdown)."""
        return set()

    # operator sugar so tests/examples read naturally -----------------------
    def __add__(self, o): return BinOp("+", self, _lit(o))
    def __radd__(self, o): return BinOp("+", _lit(o), self)
    def __sub__(self, o): return BinOp("-", self, _lit(o))
    def __rsub__(self, o): return BinOp("-", _lit(o), self)
    def __mul__(self, o): return BinOp("*", self, _lit(o))
    def __rmul__(self, o): return BinOp("*", _lit(o), self)
    def __truediv__(self, o): return BinOp("/", self, _lit(o))
    def __eq__(self, o): return BinOp("=", self, _lit(o))   # type: ignore
    def __ne__(self, o): return BinOp("<>", self, _lit(o))  # type: ignore
    def __lt__(self, o): return BinOp("<", self, _lit(o))
    def __le__(self, o): return BinOp("<=", self, _lit(o))
    def __gt__(self, o): return BinOp(">", self, _lit(o))
    def __ge__(self, o): return BinOp(">=", self, _lit(o))
    def __and__(self, o): return BinOp("and", self, _lit(o))
    def __or__(self, o): return BinOp("or", self, _lit(o))
    def __invert__(self): return Not(self)
    def __hash__(self):
        return hash(repr(self))

    def isnull(self): return IsNull(self)
    def between(self, lo, hi):
        return BinOp("and", BinOp(">=", self, _lit(lo)),
                     BinOp("<=", self, _lit(hi)))
    def isin(self, values): return InList(self, list(values))
    def like(self, pattern: str): return Like(self, pattern)


def _lit(x) -> Expr:
    return x if isinstance(x, Expr) else Lit(x)


@dataclass(eq=False)
class Col(Expr):
    name: str

    def eval(self, ctx):
        return ctx.resolve(self.name)

    def columns(self):
        return {self.name}

    def __repr__(self):
        return f"Col({self.name})"


@dataclass(eq=False)
class Lit(Expr):
    value: Any
    dbtype: Optional[DBType] = None

    def eval(self, ctx):
        xp = ctx.xp
        v = self.value
        t = self.dbtype
        if t is None:
            if isinstance(v, bool):
                t = DBType.BOOL
            elif isinstance(v, (int, np.integer)):
                t = DBType.INT64
            elif isinstance(v, (float, np.floating)):
                t = DBType.FLOAT64
            elif isinstance(v, str):
                t = DBType.VARCHAR
            elif v is None:
                t = DBType.INT64
            else:
                raise TypeError(f"literal {v!r}")
        if v is None:
            arr = xp.full((ctx.num_rows,), NULL_SENTINEL[t])
            return ExprResult(arr, t, xp.ones((ctx.num_rows,), bool))
        if t == DBType.VARCHAR:
            # scalar string literal: kept as python str; comparisons against
            # a VARCHAR column translate it to heap codes.
            return ExprResult(v, t, None, None)
        if t == DBType.BOOL:
            arr = xp.full((ctx.num_rows,), np.int8(bool(v)))
            return ExprResult(arr, t, None)
        dtype = {DBType.INT64: np.int64, DBType.FLOAT64: np.float64,
                 DBType.INT32: np.int32, DBType.FLOAT32: np.float32,
                 DBType.DATE: np.int32, DBType.DECIMAL: np.int64}[t]
        arr = xp.full((ctx.num_rows,), dtype(v))
        return ExprResult(arr, t, None)

    def __repr__(self):
        return f"Lit({self.value!r})"


@dataclass(eq=False)
class DateLit(Expr):
    """DATE literal from 'YYYY-MM-DD'."""
    text: str

    def eval(self, ctx):
        from .types import date_from_string
        d = int(date_from_string(self.text))
        return ExprResult(ctx.xp.full((ctx.num_rows,), np.int32(d)),
                          DBType.DATE, None)

    def __repr__(self):
        return f"DateLit({self.text})"


_CMP = {"=", "<>", "<", "<=", ">", ">="}
_ARITH = {"+", "-", "*", "/", "%"}
_LOGIC = {"and", "or"}


@dataclass(eq=False)
class BinOp(Expr):
    op: str
    left: Expr
    right: Expr

    def columns(self):
        return self.left.columns() | self.right.columns()

    def eval(self, ctx):
        xp = ctx.xp
        l = self.left.eval(ctx)
        r = self.right.eval(ctx)
        op = self.op

        if op in _LOGIC:
            lv = l.values != 0 if l.dbtype == DBType.BOOL else l.values
            rv = r.values != 0 if r.dbtype == DBType.BOOL else r.values
            ln, rn = l.null_or_false(xp), r.null_or_false(xp)
            lv = xp.asarray(lv, dtype=bool) & ~ln
            rv = xp.asarray(rv, dtype=bool) & ~rn
            if op == "and":
                out = lv & rv
                # NULL only when undetermined: (NULL and TRUE-ish)
                nl = (ln & (rv | rn)) | (rn & (lv | ln))
            else:
                out = lv | rv
                nl = (ln | rn) & ~out
            return ExprResult(out.astype(np.int8), DBType.BOOL,
                              nl if _any(nl) else None)

        # VARCHAR comparisons on dictionary codes --------------------------
        if l.dbtype == DBType.VARCHAR or r.dbtype == DBType.VARCHAR:
            return _varchar_cmp(op, l, r, ctx)

        if op in _CMP:
            lf, rf = l.as_float(xp), r.as_float(xp)
            out = {"=": lf == rf, "<>": lf != rf, "<": lf < rf,
                   "<=": lf <= rf, ">": lf > rf, ">=": lf >= rf}[op]
            nl = l.null_or_false(xp) | r.null_or_false(xp)
            out = out & ~nl
            return ExprResult(out.astype(np.int8), DBType.BOOL,
                              nl if _any(nl) else None)

        if op in _ARITH:
            t = common_type(l.dbtype, r.dbtype)
            nl = l.null_or_false(xp) | r.null_or_false(xp)
            nl = nl if _any(nl) else None
            if t == DBType.DECIMAL or is_float(t) or op == "/":
                lf, rf = l.as_float(xp), r.as_float(xp)
                if op == "/":
                    out = lf / xp.where(rf == 0, 1.0, rf)
                    zero = rf == 0
                    nl2 = zero if nl is None else (nl | zero)
                    return ExprResult(out, DBType.FLOAT64,
                                      nl2 if _any(nl2) else None)
                out = {"+": lf + rf, "-": lf - rf, "*": lf * rf,
                       "%": lf % xp.where(rf == 0, 1.0, rf)}[op]
                return ExprResult(out, DBType.FLOAT64, nl)
            lv = l.values.astype(np.int64)
            rv = r.values.astype(np.int64)
            out = {"+": lv + rv, "-": lv - rv, "*": lv * rv,
                   "%": lv % xp.where(rv == 0, 1, rv)}[op]
            return ExprResult(out, DBType.INT64, nl)

        raise ValueError(f"unknown op {op}")

    def __repr__(self):
        return f"({self.left!r} {self.op} {self.right!r})"


def _any(m) -> bool:
    if m is None:
        return False
    if isinstance(m, np.ndarray):
        return bool(m.any())
    return True  # symbolic (jnp under trace): keep the mask


def _varchar_cmp(op: str, l: ExprResult, r: ExprResult, ctx) -> ExprResult:
    xp = ctx.xp
    # column vs string literal: compare codes via the order-preserving heap
    if isinstance(r.values, str) or isinstance(l.values, str):
        if isinstance(l.values, str):
            # normalize to column-op-literal with flipped op
            flip = {"<": ">", ">": "<", "<=": ">=", ">=": "<=",
                    "=": "=", "<>": "<>"}
            return _varchar_cmp(flip[op], r, l, ctx)
        heap: StringHeap = l.heap
        s = r.values
        codes = l.values
        nl = codes == 0
        if op in ("=", "<>"):
            c = heap.code_of(s)
            out = (codes == c) if op == "=" else ((codes != c) & ~nl)
        elif op == "<":
            out = (codes < heap.lower_bound(s)) & ~nl
        elif op == "<=":
            out = (codes < heap.upper_bound(s)) & ~nl
        elif op == ">":
            out = codes >= heap.upper_bound(s)
        elif op == ">=":
            out = codes >= heap.lower_bound(s)
        else:
            raise ValueError(op)
        out = out & ~nl
        return ExprResult(out.astype(np.int8), DBType.BOOL,
                          nl if _any(nl) else None)
    # column vs column: only valid when they share a heap (same table scan)
    if l.heap is r.heap:
        out = {"=": l.values == r.values, "<>": l.values != r.values,
               "<": l.values < r.values, "<=": l.values <= r.values,
               ">": l.values > r.values, ">=": l.values >= r.values}[op]
        nl = (l.values == 0) | (r.values == 0)
        out = out & ~nl
        return ExprResult(out.astype(np.int8), DBType.BOOL,
                          nl if _any(nl) else None)
    # cross-heap: decode (rare; host path only)
    ls = l.heap.decode(np.asarray(l.values)).astype(str)
    rs = r.heap.decode(np.asarray(r.values)).astype(str)
    out = {"=": ls == rs, "<>": ls != rs, "<": ls < rs, "<=": ls <= rs,
           ">": ls > rs, ">=": ls >= rs}[op]
    nl = (np.asarray(l.values) == 0) | (np.asarray(r.values) == 0)
    return ExprResult((out & ~nl).astype(np.int8), DBType.BOOL,
                      nl if nl.any() else None)


@dataclass(eq=False)
class Not(Expr):
    child: Expr

    def columns(self):
        return self.child.columns()

    def eval(self, ctx):
        c = self.child.eval(ctx)
        out = (c.values == 0).astype(np.int8)
        return ExprResult(out, DBType.BOOL, c.null)

    def __repr__(self):
        return f"Not({self.child!r})"


@dataclass(eq=False)
class IsNull(Expr):
    child: Expr
    negate: bool = False

    def columns(self):
        return self.child.columns()

    def eval(self, ctx):
        c = self.child.eval(ctx)
        m = c.null_or_false(ctx.xp)
        if self.negate:
            m = ~m
        return ExprResult(m.astype(np.int8), DBType.BOOL, None)

    def __repr__(self):
        return f"IsNull({self.child!r}, neg={self.negate})"


@dataclass(eq=False)
class InList(Expr):
    child: Expr
    values: list

    def columns(self):
        return self.child.columns()

    def eval(self, ctx):
        xp = ctx.xp
        c = self.child.eval(ctx)
        if c.dbtype == DBType.VARCHAR:
            codes = [c.heap.code_of(v) for v in self.values]
            out = xp.zeros(c.values.shape, dtype=bool)
            for code in codes:
                out = out | (c.values == code)
            nl = c.values == 0
        else:
            out = xp.zeros(c.values.shape, dtype=bool)
            for v in self.values:
                out = out | (c.as_float(xp) == float(v))
            nl = c.null_or_false(xp)
        out = out & ~nl
        return ExprResult(out.astype(np.int8), DBType.BOOL,
                          nl if _any(nl) else None)

    def __repr__(self):
        return f"InList({self.child!r}, {self.values})"


@dataclass(eq=False)
class Like(Expr):
    """SQL LIKE via the dictionary fast path: evaluate the pattern once per
    distinct heap entry (tiny), then gather through the codes.  This is our
    PCRE-free LIKE (paper §3.4 'Dependencies')."""
    child: Expr
    pattern: str

    def columns(self):
        return self.child.columns()

    def eval(self, ctx):
        c = self.child.eval(ctx)
        if c.dbtype != DBType.VARCHAR:
            raise TypeError("LIKE requires VARCHAR")
        pat = self.pattern.replace("%", "*").replace("_", "?")
        heap_match = np.array(
            [False] + [fnmatch.fnmatchcase(str(v), pat)
                       for v in c.heap.values[1:]], dtype=bool)
        hm = ctx.xp.asarray(heap_match)
        out = hm[c.values]
        nl = c.values == 0
        return ExprResult(out.astype(np.int8), DBType.BOOL,
                          nl if _any(nl) else None)

    def __repr__(self):
        return f"Like({self.child!r}, {self.pattern!r})"


@dataclass(eq=False)
class Func(Expr):
    """Scalar functions: sqrt, abs, year, floor, ceil, round, log, exp."""
    name: str
    args: tuple

    def __init__(self, name: str, *args):
        self.name = name
        self.args = tuple(_lit(a) for a in args)

    def columns(self):
        s = set()
        for a in self.args:
            s |= a.columns()
        return s

    def eval(self, ctx):
        xp = ctx.xp
        name = self.name.lower()
        a = self.args[0].eval(ctx)
        if name == "year":
            if xp is np:
                from .types import date_year
                out = date_year(a.values)
            else:
                # branch-free approximate civil-calendar year (exact for the
                # proleptic Gregorian calendar, days>=0): shift to era days.
                z = a.values.astype(np.int64) + 719468
                era = xp.where(z >= 0, z, z - 146096) // 146097
                doe = z - era * 146097
                yoe = (doe - doe // 1460 + doe // 36524 - doe // 146096) // 365
                y = yoe + era * 400
                doy = doe - (365 * yoe + yoe // 4 - yoe // 100)
                mp = (5 * doy + 2) // 153
                out = (y + (mp >= 10)).astype(np.int32)
            return ExprResult(out, DBType.INT32, a.null)
        v = a.as_float(xp)
        if name == "sqrt":
            out = xp.sqrt(xp.maximum(v, 0.0))
        elif name == "abs":
            out = xp.abs(v)
        elif name == "floor":
            out = xp.floor(v)
        elif name == "ceil":
            out = xp.ceil(v)
        elif name == "round":
            nd = int(self.args[1].value) if len(self.args) > 1 else 0
            out = xp.round(v, nd) if xp is np else xp.round(v * 10**nd) / 10**nd
        elif name == "log":
            out = xp.log(xp.maximum(v, 1e-300))
        elif name == "exp":
            out = xp.exp(v)
        else:
            raise ValueError(f"unknown function {self.name}")
        return ExprResult(out, DBType.FLOAT64, a.null)

    def __repr__(self):
        return f"Func({self.name}, {self.args!r})"


@dataclass(eq=False)
class Case(Expr):
    """CASE WHEN c1 THEN v1 ... ELSE e END"""
    branches: Sequence[tuple[Expr, Expr]]
    default: Expr

    def columns(self):
        s = self.default.columns()
        for c, v in self.branches:
            s |= c.columns() | v.columns()
        return s

    def eval(self, ctx):
        xp = ctx.xp
        out_r = self.default.eval(ctx)
        out = out_r.as_float(xp)
        nl = out_r.null_or_false(xp)
        for cond, val in reversed(list(self.branches)):
            c = cond.eval(ctx)
            v = val.eval(ctx)
            takec = (c.values != 0) & ~c.null_or_false(xp)
            out = xp.where(takec, v.as_float(xp), out)
            nl = xp.where(takec, v.null_or_false(xp), nl)
        return ExprResult(out, DBType.FLOAT64, nl if _any(nl) else None)

    def __repr__(self):
        return f"Case({self.branches!r}, {self.default!r})"


@dataclass(eq=False)
class Cast(Expr):
    child: Expr
    to: DBType

    def columns(self):
        return self.child.columns()

    def eval(self, ctx):
        xp = ctx.xp
        c = self.child.eval(ctx)
        if self.to == c.dbtype:
            return c
        v = c.as_float(xp)
        from .types import STORAGE_DTYPE
        out = v.astype(STORAGE_DTYPE[self.to])
        return ExprResult(out, self.to, c.null)

    def __repr__(self):
        return f"Cast({self.child!r} as {self.to})"
