"""Model configuration covering all assigned architecture families."""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | ssm | moe | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0              # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None     # SWA width (h2o-danube)
    act: str = "swiglu"                      # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_group_tokens: int = 4096          # dispatch group size (scan)
    # --- SSM ---------------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1                     # 1 = Mamba-1, 2 = Mamba-2/SSD
    ssm_head_dim: int = 64                   # Mamba-2 head dim
    ssm_chunk: int = 64                      # chunked-scan length
    # --- hybrid (zamba2): shared attn block every k SSM layers -------------
    attn_every: int = 0
    # --- enc-dec (seamless) --------------------------------------------------
    n_enc_layers: int = 0
    cross_attn: bool = False
    # --- frontend stubs -------------------------------------------------------
    embeds_input: bool = False               # vlm/audio: precomputed embeds
    # --- performance knobs (hillclimbed in §Perf) ----------------------------
    attn_q_chunk: int = 1024                 # blockwise attention q tile
    attn_kv_chunk: int = 2048                # blockwise attention kv tile
    remat: bool = True
    # sharding scheme for GQA TP: "kv" (shard kv-head dim; universal) or
    # "replicate_kv" (replicate kv, shard q heads; needs n_heads % tp == 0)
    attn_shard: str = "kv"
    # where MoE tokens are dispatched: "ep" (experts over model axis)
    moe_shard: str = "ep"
    # TP divisibility padding: sharded head/vocab dims are rounded up to a
    # multiple of tp_pad (pjit requires input dims divisible by the mesh
    # axis).  1 = exact config (tests); launch sets it to the model-axis
    # size.  Padding waste is visible in §Roofline's useful_ratio and is a
    # §Perf hillclimb target.
    tp_pad: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def n_kv_eff(self) -> int:
        k = self.n_kv_heads
        return -(-k // self.tp_pad) * self.tp_pad

    @property
    def n_heads_eff(self) -> int:
        return self.n_kv_eff * self.q_per_kv

    @property
    def vocab_eff(self) -> int:
        return -(-self.vocab // self.tp_pad) * self.tp_pad

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def dt_rank(self) -> int:
        return max(1, -(-self.d_model // 16))

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        return replace(
            self,
            n_layers=2,
            n_enc_layers=2 if self.n_enc_layers else 0,
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_head=16,
            d_ff=128,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 8) if self.ssm_state else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_chunk=8,
            sliding_window=16 if self.sliding_window else None,
            attn_every=2 if self.attn_every else 0,
            attn_q_chunk=16,
            attn_kv_chunk=16,
            router_group_tokens=64,
            dtype="float32",
        )

    def param_count(self) -> int:
        """Analytic parameter count (for 6·N·D roofline bookkeeping)."""
        d, ff, V, L = self.d_model, self.d_ff, self.vocab, self.n_layers
        h = self.head_dim
        nq, nkv = self.n_heads, self.n_kv_heads
        attn = d * nq * h + 2 * d * nkv * h + nq * h * d
        if self.qkv_bias:
            attn += (nq + 2 * nkv) * h
        mlp_dense = 3 * d * ff if self.act == "swiglu" else 2 * d * ff
        total = 0
        if self.family == "ssm" or (self.family == "hybrid"):
            di, N = self.d_inner, self.ssm_state
            if self.ssm_version == 1:
                ssm = (d * 2 * di + di * self.ssm_conv
                       + di * (self.dt_rank + 2 * N) + self.dt_rank * di
                       + di * N + di + di * d)
            else:
                H = self.ssm_heads
                ssm = (d * 2 * di + di * self.ssm_conv
                       + d * 2 * N + d * H + 2 * H + di + di * d)
            if self.family == "ssm":
                total += L * ssm
            else:
                total += L * ssm
                n_shared = L // max(self.attn_every, 1)
                total += attn + mlp_dense      # one shared block
        elif self.family == "moe":
            E = self.n_experts
            total += L * (attn + d * E + E * 3 * d * ff)
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp_dense)
            dec = L * (2 * attn + mlp_dense)
            total += enc + dec
        else:
            total += L * (attn + mlp_dense)
        total += V * d * (1 if self.tie_embeddings else 2)
        total += (L + 2) * d                    # norms (approx)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of E experts)."""
        if self.family != "moe" or not self.n_experts:
            return self.param_count()
        d, ff, L = self.d_model, self.d_ff, self.n_layers
        full = self.param_count()
        all_experts = L * self.n_experts * 3 * d * ff
        active = L * self.top_k * 3 * d * ff
        return full - all_experts + active
