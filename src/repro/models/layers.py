"""Shared neural building blocks (pure JAX; dtype-explicit everywhere).

Parameters are plain pytrees (nested dicts of jnp arrays).  Every init
function has a twin ``*_spec`` producing a PartitionSpec pytree of the same
structure, so the launcher can build NamedShardings without touching real
arrays (dry-run uses jax.eval_shape over init).
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


_HINT_MESH = None


def set_hint_mesh(mesh):
    """Register the mesh used by activation sharding hints.  Called by the
    launcher/dry-run before tracing; None disables hints (unit tests)."""
    global _HINT_MESH
    _HINT_MESH = mesh


def _ambient_mesh():
    return _HINT_MESH


def act_hint(x):
    """Activation sharding constraint at block boundaries: batch over
    (pod, data), d_model over model.  This is what keeps GSPMD from
    resolving FSDP-sharded-weight einsums by all-gathering the *batch*
    (measured: dbrx-132b train went 375 GB/dev -> fits; EXPERIMENTS.md
    §Perf).  No-op outside a mesh context (unit tests, single host)."""
    mesh = _ambient_mesh()
    if mesh is None or x.ndim < 2:
        return x
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    if not batch_axes:
        return x
    n = 1
    for a in batch_axes:
        n *= mesh.shape[a]
    entries = [None] * x.ndim
    if x.shape[0] % n == 0 and x.shape[0] > 0:
        entries[0] = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    if "model" in mesh.axis_names and x.ndim >= 3 \
            and x.shape[-1] % mesh.shape["model"] == 0:
        entries[-1] = "model"
    if all(e is None for e in entries):
        return x
    from jax.sharding import NamedSharding
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., T, ..., h) with time axis at -3 or given by positions shape.

    Convention here: x is (B, T, K, h) or (B, T, K, G, h); positions (B, T).
    """
    h = x.shape[-1]
    half = h // 2
    freqs = rope_frequencies(h, theta)                       # (half,)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (B, T, half)
    cos = jnp.cos(ang)
    sin = jnp.sin(ang)
    extra = x.ndim - 3                                       # head axes count
    for _ in range(extra):
        cos = cos[..., None, :]
        sin = sin[..., None, :]
    x1 = x[..., :half].astype(jnp.float32)
    x2 = x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, d_model, d_ff, act, dtype, stack: int = 0):
    ks = jax.random.split(key, 3)
    sh = (lambda *s: ((stack,) + s) if stack else s)
    p = {"w1": dense_init(ks[0], sh(d_model, d_ff), dtype)}
    if act == "swiglu":
        p["w3"] = dense_init(ks[1], sh(d_model, d_ff), dtype)
    p["w2"] = dense_init(ks[2], sh(d_ff, d_model), dtype)
    return p


def mlp_spec(act, stack: bool = False):
    l = (None,) if stack else ()
    p = {"w1": P(*l, None, "model"), "w2": P(*l, "model", None)}
    if act == "swiglu":
        p["w3"] = P(*l, None, "model")
    return p


def mlp_apply(p, x, act: str):
    h = jnp.einsum("btd,df->btf", x, p["w1"])
    if act == "swiglu":
        g = jnp.einsum("btd,df->btf", x, p["w3"])
        h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * g
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("btf,fd->btd", h, p["w2"])


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, vocab, d_model, dtype):
    return {"w": dense_init(key, (vocab, d_model), dtype, scale=1.0)}


def embed_spec():
    return {"w": P("model", None)}


def embed_apply(p, ids):
    return jnp.take(p["w"], ids, axis=0)


def unembed_init(key, d_model, vocab, dtype):
    return {"w": dense_init(key, (d_model, vocab), dtype)}


def unembed_spec():
    return {"w": P(None, "model")}


def unembed_apply(p, x):
    return jnp.einsum("btd,dv->btv", x, p["w"])


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def next_token_loss(logits, labels, mask=None):
    """Cross-entropy over (possibly vocab-sharded) logits.

    logits: (B, T, V); labels: (B, T) int32; mask: (B, T) {0,1}."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
