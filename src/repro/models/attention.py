"""Grouped-query attention: blockwise (flash-style) training path + cached
decode path, with sliding-window support.

Layout convention (the universal GQA-TP scheme, DESIGN.md §7): q is kept as
(B, T, K, G, h) — K = kv heads, G = q-heads-per-kv — and k/v as (B, S, K, h).
Sharding rule: the K axis is sharded over ``model``; each device holds a kv
head *and all of its q group*, so scores/out einsums need no cross-device
attention traffic.  Works for any K (GSPMD pads non-divisible K).  The
alternative "replicate_kv" scheme (kv replicated, q heads sharded) is the
§Perf hillclimb comparator for decode shapes.

The training path is a doubly-blockwise online-softmax attention (q tiles ×
kv tiles under lax.scan) so long-context prefill never materializes the
(T, S) score matrix.  Causal tiles strictly above the diagonal are masked,
not skipped, in the baseline; tile *skipping* is a recorded §Perf change.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------


def attn_init(key, cfg, dtype, stack: int = 0, prefix_dims=()):
    d, K, G, h = cfg.d_model, cfg.n_kv_eff, cfg.q_per_kv, cfg.head_dim
    ks = jax.random.split(key, 4)
    sh = (lambda *s: ((stack,) + s) if stack else s)
    p = {
        "wq": dense_init(ks[0], sh(d, K, G * h), dtype),
        "wk": dense_init(ks[1], sh(d, K, h), dtype),
        "wv": dense_init(ks[2], sh(d, K, h), dtype),
        "wo": dense_init(ks[3], sh(K, G * h, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(sh(K, G * h), dtype)
        p["bk"] = jnp.zeros(sh(K, h), dtype)
        p["bv"] = jnp.zeros(sh(K, h), dtype)
    return p


def attn_spec(cfg, stack: bool = False):
    l = (None,) if stack else ()
    p = {
        "wq": P(*l, None, "model", None),
        "wk": P(*l, None, "model", None),
        "wv": P(*l, None, "model", None),
        "wo": P(*l, "model", None, None),
    }
    if cfg.qkv_bias:
        p["bq"] = P(*l, "model", None)
        p["bk"] = P(*l, "model", None)
        p["bv"] = P(*l, "model", None)
    return p


def _project_qkv(p, x, xkv, cfg):
    """x: (B, T, d) -> q (B,T,K,G,h), k/v (B,S,K,h)."""
    K, G, h = cfg.n_kv_eff, cfg.q_per_kv, cfg.head_dim
    q = jnp.einsum("btd,dkf->btkf", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", xkv, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", xkv, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(q.shape[0], q.shape[1], K, G, h)
    return q, k, v


def _out_proj(p, o, cfg):
    """o: (B, T, K, G, h) -> (B, T, d)."""
    B, T, K, G, h = o.shape
    return jnp.einsum("btkf,kfd->btd", o.reshape(B, T, K, G * h), p["wo"])


# ---------------------------------------------------------------------------
# blockwise attention core
# ---------------------------------------------------------------------------


def _tile_mask(qpos, kpos, causal: bool, window: Optional[int]):
    """(qc, kc) boolean validity mask."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


def blockwise_attention(q, k, v, *, causal: bool, window: Optional[int],
                        q_chunk: int, kv_chunk: int,
                        q_offset=0, kv_valid: Optional[int] = None,
                        skip_tiles: bool = False):
    """Online-softmax attention.

    q: (B, T, K, G, h); k, v: (B, S, K, h).  q_offset: absolute position of
    q[0] (for decode-with-cache; may be traced).  kv_valid: number of valid
    kv entries (rest masked; may be traced).  Returns (B, T, K, G, h).

    skip_tiles: iterate only kv tiles at-or-below the diagonal per q tile
    (legal only for causal + no cache offset); §Perf change, default off.
    """
    B, T, K, G, h = q.shape
    S = k.shape[1]
    scale = 1.0 / math.sqrt(h)
    qc = min(q_chunk, T)
    kc = min(kv_chunk, S)
    nq = -(-T // qc)
    nk = -(-S // kc)
    Tp, Sp = nq * qc, nk * kc
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0), (0, 0), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0), (0, 0)))
    kv_lim = S if kv_valid is None else kv_valid

    qs = q.reshape(B, nq, qc, K, G, h).transpose(1, 0, 2, 3, 4, 5)
    ks = k.reshape(B, nk, kc, K, h).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, nk, kc, K, h).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qpos = q_offset + iq * qc + jnp.arange(qc)

        def kv_step(carry, kj_and_idx):
            m_prev, l_prev, acc = carry
            (kj, vj), jk = kj_and_idx
            kpos = jk * kc + jnp.arange(kc)
            # f32 math via explicit casts (not preferred_element_type):
            # the cast's VJP returns bf16 cotangents, so the TP dgrad
            # all-reduces upstream move half the bytes (EXPERIMENTS §Perf).
            s = jnp.einsum("bqkgh,bskh->bkgqs", qi.astype(jnp.float32),
                           kj.astype(jnp.float32)) * scale
            mask = _tile_mask(qpos, kpos, causal, window)
            mask &= (kpos < kv_lim)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p, vj.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, K, G, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qc), jnp.float32)
        a0 = jnp.zeros((B, K, G, qc, h), jnp.float32)
        # flash-style backward: recompute the (qc, kc) probability tile in
        # the bwd pass instead of saving it — without this, scan-AD stores
        # the entire tiled (T, S) score matrix (measured 10 GB/device on
        # dbrx train_4k; EXPERIMENTS.md §Perf).
        kv_step = jax.checkpoint(kv_step)
        if skip_tiles and causal and kc == qc:
            # iterate only tiles j <= i via dynamic slice bound: emulate by
            # masking the scan inputs with a where on index (cheap skip):
            def kv_step_skip(carry, kj_and_idx):
                (_, _), jk = kj_and_idx
                new_carry, _ = kv_step(carry, kj_and_idx)
                keep = jk <= iq
                carry = jax.tree.map(
                    lambda n, o: jnp.where(keep, n, o), new_carry, carry)
                return carry, None
            (m, l, acc), _ = jax.lax.scan(
                kv_step_skip, (m0, l0, a0), ((ks, vs), jnp.arange(nk)))
        else:
            (m, l, acc), _ = jax.lax.scan(
                kv_step, (m0, l0, a0), ((ks, vs), jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, out.transpose(0, 3, 1, 2, 4)   # (B, qc, K, G, h)

    _, outs = jax.lax.scan(q_step, None, (qs, jnp.arange(nq)))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tp, K, G, h)
    return out[:, :T].astype(q.dtype)


# ---------------------------------------------------------------------------
# public blocks
# ---------------------------------------------------------------------------


def attention_block(p, x, cfg, positions, *, causal=True, xkv=None,
                    kv_positions=None, use_rope=True, skip_tiles=False):
    """Full attention (training / prefill).  xkv!=None => cross-attention."""
    xkv = x if xkv is None else xkv
    q, k, v = _project_qkv(p, x, xkv, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        kp = positions if kv_positions is None else kv_positions
        k = apply_rope(k, kp, cfg.rope_theta)
    o = blockwise_attention(
        q, k, v, causal=causal, window=cfg.sliding_window,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk,
        skip_tiles=skip_tiles)
    return _out_proj(p, o, cfg)


def init_kv_cache(cfg, batch, max_len, dtype):
    """Ring cache; sliding-window archs only keep ``window`` slots."""
    slots = max_len if cfg.sliding_window is None \
        else min(max_len, cfg.sliding_window)
    K, h = cfg.n_kv_eff, cfg.head_dim
    return {
        "k": jnp.zeros((batch, slots, K, h), dtype),
        "v": jnp.zeros((batch, slots, K, h), dtype),
        "idx": jnp.zeros((), jnp.int32),      # absolute tokens written
    }


def kv_cache_spec(seq_shard: bool = False):
    s = P(("pod", "data") if not seq_shard else None,
          "data" if seq_shard else None, "model", None)
    return {"k": s, "v": s, "idx": P()}


def decode_attention_block(p, x, cfg, cache, *, xkv_cache_only=False):
    """One-token decode: x (B, 1, d); returns (out, new_cache)."""
    B = x.shape[0]
    slots = cache["k"].shape[1]
    pos = cache["idx"]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    slot = jnp.mod(pos, slots)
    zero = jnp.zeros((), slot.dtype)       # dtype-explicit under x64 too
    ck = jax.lax.dynamic_update_slice(cache["k"], k,
                                      (zero, slot, zero, zero))
    cv = jax.lax.dynamic_update_slice(cache["v"], v,
                                      (zero, slot, zero, zero))
    j = jnp.arange(slots)
    # absolute position held by ring slot j after writing at `slot`:
    # j == slot -> pos; j > slot wraps to the previous revolution.
    abs_pos = pos - slot + j - slots * (j > slot)
    valid = (abs_pos >= 0) & (abs_pos <= pos)
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, ck,
                   preferred_element_type=jnp.float32) \
        / math.sqrt(cfg.head_dim)
    ok = valid
    if cfg.sliding_window is not None:
        ok = ok & ((pos - abs_pos) < cfg.sliding_window)
    s = jnp.where(ok[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(cv.dtype), cv)
    out = _out_proj(p, o, cfg)
    return out, {"k": ck, "v": cv, "idx": pos + 1}


def prefill_attention_block(p, x, cfg, positions, cache):
    """Prefill S tokens and fill the cache (assumes S <= cache slots or
    sliding-window archs where only the tail matters)."""
    xkv = x
    q, k, v = _project_qkv(p, x, xkv, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    o = blockwise_attention(
        q, k, v, causal=True, window=cfg.sliding_window,
        q_chunk=cfg.attn_q_chunk, kv_chunk=cfg.attn_kv_chunk)
    out = _out_proj(p, o, cfg)
    S = x.shape[1]
    slots = cache["k"].shape[1]
    if slots >= S:
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v, (0, 0, 0, 0))
    else:   # sliding window: keep the tail, at ring positions (abs % slots)
        ck = jax.lax.dynamic_slice_in_dim(k, S - slots, slots, axis=1)
        cv = jax.lax.dynamic_slice_in_dim(v, S - slots, slots, axis=1)
        shift = (S - slots) % slots
        ck = jnp.roll(ck, shift, axis=1)
        cv = jnp.roll(cv, shift, axis=1)
    return out, {"k": ck, "v": cv,
                 "idx": jnp.asarray(S, jnp.int32)}
