"""Mixture-of-Experts block: GShard-style einsum dispatch, expert-parallel.

Dispatch/combine are the canonical one-hot einsums (GShard,
arXiv:2006.16668) in their batched form: tokens reshape to
(n_groups, group_tokens, d) where the *group* dim inherits the data
sharding (it is a pure reshape of the batch-sharded token stream), and the
expert dim of every expert einsum is sharded over ``model`` (expert
parallelism) — GSPMD lowers the dispatch einsum into the token all-to-all.
No scan: all groups run as one batched einsum chain, so sharding
propagates cleanly (a scan over groups replicates the group computation —
measured 50x flops blowup in the dry-run; see EXPERIMENTS.md §Perf).

Capacity math per group: C = group_tokens * top_k / E * capacity_factor;
tokens over capacity are dropped (standard GShard semantics), with the aux
load-balance loss keeping routing near-uniform.

Covers both assigned MoE archs: dbrx-132b (16e top-4) and
moonshot-v1-16b-a3b (64e top-6, fine-grained).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import dense_init


def moe_init(key, cfg, dtype, stack: int = 0):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    sh = (lambda *s: ((stack,) + s) if stack else s)
    return {
        "router": dense_init(ks[0], sh(d, E), jnp.float32),
        "w1": dense_init(ks[1], sh(E, d, ff), dtype),
        "w3": dense_init(ks[2], sh(E, d, ff), dtype),
        "w2": dense_init(ks[3], sh(E, ff, d), dtype),
    }


def moe_spec(stack: bool = False):
    l = (None,) if stack else ()
    return {
        "router": P(*l, None, None),
        "w1": P(*l, "model", None, None),     # expert parallelism
        "w3": P(*l, "model", None, None),
        "w2": P(*l, "model", None, None),
    }


def _top_k_dispatch(gates, top_k: int, capacity: int):
    """gates: (G, S, E) softmax'd.  Returns combine (G, S, E, C) f32 and
    dispatch (G, S, E, C) bool via k sequential argmax rounds sharing a
    per-(group, expert) position counter (GShard algorithm)."""
    G, S, E = gates.shape
    combine = jnp.zeros((G, S, E, capacity), jnp.float32)
    remaining = gates
    base = jnp.zeros((G, E), jnp.int32)
    for _ in range(top_k):
        eid = jnp.argmax(remaining, axis=-1)                  # (G, S)
        gate = jnp.take_along_axis(remaining, eid[..., None],
                                   axis=-1)[..., 0]
        oh = jax.nn.one_hot(eid, E, dtype=jnp.int32)          # (G, S, E)
        pos = jnp.cumsum(oh, axis=1) - 1 + base[:, None, :]   # (G, S, E)
        base = base + jnp.sum(oh, axis=1)
        slot = jnp.sum(pos * oh, axis=-1)                     # (G, S)
        keep = slot < capacity
        c_oh = jax.nn.one_hot(jnp.where(keep, slot, capacity),
                              capacity, dtype=jnp.float32)    # (G, S, C)
        contrib = (gate * keep)[..., None, None] \
            * oh.astype(jnp.float32)[..., None] * c_oh[:, :, None, :]
        combine = combine + contrib
        remaining = remaining * (1 - oh.astype(gates.dtype))
    dispatch = combine > 0
    return combine, dispatch


def moe_apply(p, x, cfg):
    """x: (B, T, d) -> (B, T, d), plus aux load-balance loss."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    total = B * T
    Gt = min(cfg.router_group_tokens, total)
    ng = -(-total // Gt)
    pad = ng * Gt - total
    tokens = x.reshape(total, d)
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    xg = tokens.reshape(ng, Gt, d)            # group dim: data-sharded
    capacity = max(1, int(Gt * k / E * cfg.capacity_factor))

    # f32 router math without materializing an f32 copy of the tokens
    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    combine, dispatch = _top_k_dispatch(gates, k, capacity)

    # aux loss (Switch): fraction dispatched x mean router prob, per expert
    me = jnp.mean(gates, axis=(0, 1))                          # (E,)
    ce = jnp.mean(jnp.sum(dispatch, axis=(-1,)).astype(jnp.float32),
                  axis=(0, 1))                                 # (E,)
    aux = jnp.sum(me * ce) * E

    buf = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    h1 = jnp.einsum("gecd,edf->gecf", buf, p["w1"])
    h3 = jnp.einsum("gecd,edf->gecf", buf, p["w3"])
    h = jax.nn.silu(h1.astype(jnp.float32)).astype(x.dtype) * h3
    out_e = jnp.einsum("gecf,efd->gecd", h, p["w2"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), out_e)

    y = y.reshape(ng * Gt, d)[:total].reshape(B, T, d)
    return y, aux
