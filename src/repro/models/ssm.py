"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD), both in
chunked form — lax.scan over time chunks with an inter-chunk recurrent
state, so no (T, d_inner, N) tensor is ever materialized and decode is the
chunk==1 special case of the same recurrence.

Shapes follow the papers:
  Mamba-1 (arXiv:2312.00752): per-channel state, h_t = a_t h_{t-1} + b_t,
      a_t = exp(Δ_t A), b_t = Δ_t u_t B_t;  y_t = C_t · h_t + D u_t.
  Mamba-2 / SSD (arXiv:2405.21060): per-head scalar decay; within-chunk
      attention-like quadratic form + inter-chunk state passing.

Sharding: d_inner (Mamba-1 channels / Mamba-2 heads) over ``model``; the
recurrent state is (B, d_inner, N) resp. (B, H, P, N), sharded the same way
— recurrence is purely local to the shard (no collectives inside the scan).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import dense_init


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------


def mamba1_init(key, cfg, dtype, stack: int = 0):
    d, di, N, R = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    ks = jax.random.split(key, 6)
    sh = (lambda *s: ((stack,) + s) if stack else s)
    A = jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (di, 1))
    if stack:
        A = jnp.tile(A[None], (stack, 1, 1))
    return {
        "in_proj": dense_init(ks[0], sh(d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], sh(di, cfg.ssm_conv), dtype, scale=0.5),
        "conv_b": jnp.zeros(sh(di), dtype),
        "x_proj": dense_init(ks[2], sh(di, R + 2 * N), dtype),
        "dt_proj": dense_init(ks[3], sh(R, di), dtype),
        "dt_bias": jnp.zeros(sh(di), jnp.float32),
        "A_log": jnp.log(A),
        "D": jnp.ones(sh(di), jnp.float32),
        "out_proj": dense_init(ks[4], sh(di, d), dtype),
    }


def mamba1_spec(stack: bool = False):
    l = (None,) if stack else ()
    return {
        "in_proj": P(*l, None, "model"),
        "conv_w": P(*l, "model", None),
        "conv_b": P(*l, "model"),
        "x_proj": P(*l, "model", None),
        "dt_proj": P(*l, None, "model"),
        "dt_bias": P(*l, "model"),
        "A_log": P(*l, "model", None),
        "D": P(*l, "model"),
        "out_proj": P(*l, "model", None),
    }


def _causal_conv(u, w, b, state=None):
    """u: (B, T, di); w: (di, k) depthwise causal conv.
    state: (B, k-1, di) carry-in; returns (out, new_state)."""
    B, T, di = u.shape
    k = w.shape[-1]
    if state is None:
        state = jnp.zeros((B, k - 1, di), u.dtype)
    ext = jnp.concatenate([state, u], axis=1)          # (B, T+k-1, di)
    out = jnp.zeros((B, T, di), jnp.float32)
    for i in range(k):                                  # static unroll (k=4)
        out = out + ext[:, i:i + T].astype(jnp.float32) \
            * w[:, i].astype(jnp.float32)[None, None]
    out = out + b.astype(jnp.float32)
    new_state = ext[:, T:]
    return out.astype(u.dtype), new_state


def mamba1_scan(u, dt, Bt, Ct, A, D, h0, chunk: int):
    """Chunked selective scan.
    u, dt: (B, T, di); Bt, Ct: (B, T, N); A: (di, N); h0: (B, di, N).
    Returns (y (B, T, di) f32, hT)."""
    B, T, di = u.shape
    N = Bt.shape[-1]
    c = min(chunk, T)
    nc = -(-T // c)
    pad = nc * c - T
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, pad), (0, 0)))
    uc = u.reshape(B, nc, c, di).transpose(1, 0, 2, 3)
    dtc = dt.reshape(B, nc, c, di).transpose(1, 0, 2, 3)
    Bc = Bt.reshape(B, nc, c, N).transpose(1, 0, 2, 3)
    Cc = Ct.reshape(B, nc, c, N).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        ui, dti, Bi, Ci = inp                          # (B, c, ·)
        # per-step decay and input: a (B,c,di,N), b (B,c,di,N)
        dA = dti[..., None] * A[None, None]            # (B,c,di,N), <= 0
        a = jnp.exp(dA)                                # in (0, 1]: stable
        b = (dti * ui)[..., None] * Bi[:, :, None, :]  # (B,c,di,N)

        # within-chunk linear recurrence h_t = a_t h_{t-1} + b_t via a
        # numerically-stable associative scan on (a, b) pairs (all factors
        # are decays <= 1, so no overflow — unlike the cumsum formulation).
        def comb(l, r):
            al, bl = l
            ar, br = r
            return al * ar, br + ar * bl

        aa, bb = jax.lax.associative_scan(comb, (a, b), axis=1)
        hs = bb + aa * h[:, None]                      # (B,c,di,N)
        y = jnp.einsum("bcdn,bcn->bcd", hs, Ci)
        hT = hs[:, -1]
        return hT, y

    # remat the chunk: without this, scan-AD saves the (B, c, di, N)
    # associative-scan intermediates for every chunk — ~20 GB/device and
    # the dominant HBM term on falcon-mamba train (EXPERIMENTS.md §Perf).
    hT, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                          (uc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, nc * c, di)[:, :T]
    y = y + u[:, :T].astype(jnp.float32) * D[None, None]
    return y, hT


def mamba1_block(p, x, cfg, state=None):
    """x: (B, T, d) -> (B, T, d).  state: None (train) or dict with
    'conv' (B, k-1, di) and 'ssm' (B, di, N) for cached decode."""
    B, T, d = x.shape
    di, N, R = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    proj = jnp.einsum("btd,de->bte", u, p["x_proj"])
    dt_r, Bt, Ct = jnp.split(proj, [R, R + N], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btr,rd->btd", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    h0 = jnp.zeros((B, di, N), jnp.float32) if state is None else state["ssm"]
    y, hT = mamba1_scan(u, dt, Bt.astype(jnp.float32),
                        Ct.astype(jnp.float32), A, p["D"], h0,
                        cfg.ssm_chunk if T > 1 else 1)
    y = y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    new_state = {"conv": new_conv, "ssm": hT}
    return out, new_state


def mamba1_state_init(cfg, batch, dtype):
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
            "ssm": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state),
                             jnp.float32)}


def mamba1_state_spec():
    return {"conv": P(("pod", "data"), None, "model"),
            "ssm": P(("pod", "data"), "model", None)}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD)
# ---------------------------------------------------------------------------


def mamba2_init(key, cfg, dtype, stack: int = 0):
    d, di, N = cfg.d_model, cfg.d_inner, cfg.ssm_state
    H = cfg.ssm_heads
    ks = jax.random.split(key, 5)
    sh = (lambda *s: ((stack,) + s) if stack else s)
    return {
        "in_proj": dense_init(ks[0], sh(d, 2 * di), dtype),
        "conv_w": dense_init(ks[1], sh(di, cfg.ssm_conv), dtype, scale=0.5),
        "conv_b": jnp.zeros(sh(di), dtype),
        "bc_proj": dense_init(ks[2], sh(d, 2 * N), dtype),
        "dt_proj": dense_init(ks[3], sh(d, H), dtype),
        "dt_bias": jnp.zeros(sh(H), jnp.float32),
        "A_log": jnp.zeros(sh(H), jnp.float32),
        "D": jnp.ones(sh(H), jnp.float32),
        "out_proj": dense_init(ks[4], sh(di, d), dtype),
    }


def mamba2_spec(stack: bool = False):
    l = (None,) if stack else ()
    return {
        "in_proj": P(*l, None, "model"),
        "conv_w": P(*l, "model", None),
        "conv_b": P(*l, "model"),
        "bc_proj": P(*l, None, None),
        "dt_proj": P(*l, None, "model"),
        "dt_bias": P(*l, "model"),
        "A_log": P(*l, "model"),
        "D": P(*l, "model"),
        "out_proj": P(*l, "model", None),
    }


def _segsum(x):
    """log-space cumulative decay matrix: L[i, j] = sum_{j<k<=i} x_k
    (lower-triangular), -inf above diagonal.  x: (..., c)."""
    c = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool))
    return jnp.where(mask, diff, -jnp.inf)


def mamba2_ssd(xh, dt, A, Bt, Ct, h0, chunk: int):
    """SSD chunked recurrence.
    xh: (B, T, H, Pd); dt: (B, T, H); A: (H,) negative;
    Bt, Ct: (B, T, N); h0: (B, H, Pd, N).
    Returns (y (B,T,H,Pd) f32, hT)."""
    B, T, H, Pd = xh.shape
    N = Bt.shape[-1]
    c = min(chunk, T)
    nc = -(-T // c)
    pad = nc * c - T
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, pad), (0, 0)))
    xc = xh.reshape(B, nc, c, H, Pd).transpose(1, 0, 2, 3, 4)
    dtc = dt.reshape(B, nc, c, H).transpose(1, 0, 2, 3)
    Bc = Bt.reshape(B, nc, c, N).transpose(1, 0, 2, 3)
    Cc = Ct.reshape(B, nc, c, N).transpose(1, 0, 2, 3)

    def chunk_step(h, inp):
        xi, dti, Bi, Ci = inp
        dA = dti * A[None, None]                       # (B,c,H)  negative
        # intra-chunk: Y_intra = (C_i B_j^T ⊙ L_ij ⊙ dt_j) x_j
        L = jnp.exp(_segsum(dA.transpose(0, 2, 1)))    # (B,H,c,c)
        G = jnp.einsum("bin,bjn->bij", Ci, Bi)         # (B,c,c)
        M = G[:, None] * L * dti.transpose(0, 2, 1)[:, :, None, :]
        y_intra = jnp.einsum("bhij,bjhp->bihp", M, xi)
        # inter-chunk: contribution of h (state at chunk start)
        decay_in = jnp.exp(jnp.cumsum(dA, axis=1))     # (B,c,H)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", Ci, h, decay_in)
        # state update: hT = decay_total * h + sum_j decay_{j->end} dt_j B_j x_j
        total = jnp.exp(jnp.sum(dA, axis=1))           # (B,H)
        decay_out = jnp.exp(jnp.sum(dA, axis=1)[:, None]
                            - jnp.cumsum(dA, axis=1))  # (B,c,H)
        dBx = jnp.einsum("bjh,bjn,bjhp->bhpn", dti * decay_out, Bi, xi)
        hT = total[:, :, None, None] * h + dBx
        return hT, y_intra + y_inter

    hT, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0,
                          (xc, dtc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nc * c, H, Pd)[:, :T]
    return y, hT


def mamba2_block(p, x, cfg, state=None):
    B, T, d = x.shape
    di, N, H, Pd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xz = jnp.einsum("btd,de->bte", x, p["in_proj"])
    u, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
    bc = jnp.einsum("btd,de->bte", x, p["bc_proj"]).astype(jnp.float32)
    Bt, Ct = jnp.split(bc, 2, axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", x, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    xh = u.reshape(B, T, H, Pd)
    h0 = jnp.zeros((B, H, Pd, N), jnp.float32) if state is None \
        else state["ssm"]
    y, hT = mamba2_ssd(xh.astype(jnp.float32), dt, A, Bt, Ct, h0,
                       cfg.ssm_chunk if T > 1 else 1)
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, T, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    return out, {"conv": new_conv, "ssm": hT}


def mamba2_state_init(cfg, batch, dtype):
    return {"conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
            "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                              cfg.ssm_state), jnp.float32)}


def mamba2_state_spec():
    return {"conv": P(("pod", "data"), None, "model"),
            "ssm": P(("pod", "data"), "model", None, None)}
