"""Model assembly for all assigned families, built around scan-over-layers
(compact HLO: one layer body lowered once regardless of depth — essential
for 40-60-layer configs compiled on 512-device meshes).

Families:
  dense  — pre-norm GQA transformer (qwen2.5 / phi3 / danube / deepseek /
           llava backbone)
  moe    — dense attention + MoE FFN (dbrx, moonshot)
  ssm    — Mamba-1 stack (falcon-mamba)
  hybrid — Mamba-2 stack with a weight-shared attention block every
           ``attn_every`` layers (zamba2)
  encdec — bidirectional encoder + causal decoder with cross-attention
           (seamless-m4t; audio frontend is a precomputed-embedding stub)

Three entry points per model: ``forward_train`` (full-sequence logits),
``prefill`` (fill caches, return last logits), ``decode_step`` (one token).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .attention import (attn_init, attn_spec, attention_block,
                        decode_attention_block, init_kv_cache, kv_cache_spec,
                        prefill_attention_block)
from .config import ModelConfig
from .layers import (act_hint, dtype_of, embed_apply, embed_init,
                     embed_spec, mlp_apply, mlp_init, mlp_spec,
                     next_token_loss, rmsnorm, unembed_apply, unembed_init,
                     unembed_spec)
from .moe import moe_apply, moe_init, moe_spec
from .ssm import (mamba1_block, mamba1_init, mamba1_spec, mamba1_state_init,
                  mamba1_state_spec, mamba2_block, mamba2_init, mamba2_spec,
                  mamba2_state_init, mamba2_state_spec)

ACT_SPEC = P(("pod", "data"), None, None)    # (B, T, d) activations
TOK_SPEC = P(("pod", "data"), None)          # (B, T) tokens


# ---------------------------------------------------------------------------
# init + sharding spec
# ---------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig):
    dt = dtype_of(cfg.dtype)
    keys = jax.random.split(key, 8)
    L = cfg.n_layers
    params = {}
    params["embed"] = embed_init(keys[0], cfg.vocab_eff, cfg.d_model, dt)

    def layer_params(k, n, kind):
        ks = jax.random.split(k, 4)
        lp = {"ln1": jnp.ones((n, cfg.d_model), dt)}
        if kind in ("dense", "moe", "enc"):
            lp["attn"] = attn_init(ks[0], cfg, dt, stack=n)
            lp["ln2"] = jnp.ones((n, cfg.d_model), dt)
            if kind == "moe":
                lp["moe"] = moe_init(ks[1], cfg, dt, stack=n)
            else:
                lp["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                                     dt, stack=n)
        elif kind == "dec":
            lp["attn"] = attn_init(ks[0], cfg, dt, stack=n)
            lp["ln_x"] = jnp.ones((n, cfg.d_model), dt)
            lp["xattn"] = attn_init(ks[2], cfg, dt, stack=n)
            lp["ln2"] = jnp.ones((n, cfg.d_model), dt)
            lp["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.act,
                                 dt, stack=n)
        elif kind == "ssm1":
            lp["ssm"] = mamba1_init(ks[0], cfg, dt, stack=n)
        elif kind == "ssm2":
            lp["ssm"] = mamba2_init(ks[0], cfg, dt, stack=n)
        return lp

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        kind = "moe" if fam == "moe" else "dense"
        params["layers"] = layer_params(keys[1], L, kind)
    elif fam == "ssm":
        kind = "ssm1" if cfg.ssm_version == 1 else "ssm2"
        params["layers"] = layer_params(keys[1], L, kind)
    elif fam == "hybrid":
        params["layers"] = layer_params(keys[1], L, "ssm2")
        shared = layer_params(keys[2], 1, "dense")
        params["shared"] = jax.tree.map(lambda a: a[0], shared)
    elif fam == "encdec":
        params["enc_layers"] = layer_params(keys[1], cfg.n_enc_layers, "enc")
        params["layers"] = layer_params(keys[2], L, "dec")
        params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
    else:
        raise ValueError(fam)

    params["final_norm"] = jnp.ones((cfg.d_model,), dt)
    if not cfg.tie_embeddings:
        params["unembed"] = unembed_init(keys[3], cfg.d_model, cfg.vocab_eff, dt)
    return params


def model_spec(cfg: ModelConfig):
    def layer_spec(kind):
        lp = {"ln1": P(None, None)}
        if kind in ("dense", "moe", "enc"):
            lp["attn"] = attn_spec(cfg, stack=True)
            lp["ln2"] = P(None, None)
            if kind == "moe":
                lp["moe"] = moe_spec(stack=True)
            else:
                lp["mlp"] = mlp_spec(cfg.act, stack=True)
        elif kind == "dec":
            lp["attn"] = attn_spec(cfg, stack=True)
            lp["ln_x"] = P(None, None)
            lp["xattn"] = attn_spec(cfg, stack=True)
            lp["ln2"] = P(None, None)
            lp["mlp"] = mlp_spec(cfg.act, stack=True)
        elif kind == "ssm1":
            lp["ssm"] = mamba1_spec(stack=True)
        elif kind == "ssm2":
            lp["ssm"] = mamba2_spec(stack=True)
        return lp

    spec = {"embed": embed_spec(), "final_norm": P(None)}
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        spec["layers"] = layer_spec("moe" if fam == "moe" else "dense")
    elif fam == "ssm":
        spec["layers"] = layer_spec("ssm1" if cfg.ssm_version == 1
                                    else "ssm2")
    elif fam == "hybrid":
        spec["layers"] = layer_spec("ssm2")
        sh = layer_spec("dense")
        spec["shared"] = jax.tree.map(_unstack_spec, sh,
                                      is_leaf=lambda x: isinstance(x, P))
    elif fam == "encdec":
        spec["enc_layers"] = layer_spec("enc")
        spec["layers"] = layer_spec("dec")
        spec["enc_norm"] = P(None)
    if not cfg.tie_embeddings:
        spec["unembed"] = unembed_spec()
    return spec


def _unstack_spec(s: P) -> P:
    return P(*s[1:])


# ---------------------------------------------------------------------------
# training forward
# ---------------------------------------------------------------------------


def _maybe_remat(f, cfg):
    return jax.checkpoint(f) if cfg.remat else f


def _dense_layer(cfg, x, lp, positions, causal=True, skip_tiles=False):
    x = act_hint(x)
    h = attention_block(lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps),
                        cfg, positions, causal=causal,
                        skip_tiles=skip_tiles)
    x = x + h
    x = x + mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps),
                      cfg.act)
    return x


def forward_train(params, cfg: ModelConfig, batch):
    """Returns (logits, aux_loss)."""
    fam = cfg.family
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(dtype_of(cfg.dtype))
    else:
        x = embed_apply(params["embed"], batch["tokens"])
    x = act_hint(x)
    B, T = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    aux = jnp.zeros((), jnp.float32)

    if fam in ("dense", "vlm"):
        def layer(x, lp):
            return _dense_layer(cfg, x, lp, positions), None
        x, _ = jax.lax.scan(_maybe_remat(layer, cfg), x, params["layers"])

    elif fam == "moe":
        def layer(carry, lp):
            x, aux = carry
            x = act_hint(x)
            h = attention_block(lp["attn"],
                                rmsnorm(x, lp["ln1"], cfg.norm_eps),
                                cfg, positions, causal=True)
            x = x + h
            y, a = moe_apply(lp["moe"], rmsnorm(x, lp["ln2"], cfg.norm_eps),
                             cfg)
            return (x + y, aux + a), None
        (x, aux), _ = jax.lax.scan(_maybe_remat(layer, cfg), (x, aux),
                                   params["layers"])

    elif fam == "ssm":
        block = mamba1_block if cfg.ssm_version == 1 else mamba2_block
        def layer(x, lp):
            x = act_hint(x)
            h, _ = block(lp["ssm"], rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg)
            return x + h, None
        x, _ = jax.lax.scan(_maybe_remat(layer, cfg), x, params["layers"])

    elif fam == "hybrid":
        shared = params["shared"]
        k = cfg.attn_every
        def layer(carry, inp):
            x, = carry
            lp, idx = inp
            x = act_hint(x)
            h, _ = mamba2_block(lp["ssm"],
                                rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg)
            x = x + h
            def with_shared(x):
                return _dense_layer(cfg, x, shared, positions)
            x = jax.lax.cond((idx + 1) % k == 0, with_shared,
                             lambda x: x, x)
            return (x,), None
        idxs = jnp.arange(cfg.n_layers)
        (x,), _ = jax.lax.scan(_maybe_remat(layer, cfg), (x,),
                               (params["layers"], idxs))

    elif fam == "encdec":
        mem = encode(params, cfg, batch)
        x = embed_apply(params["embed"], batch["tokens"])
        B, T = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(T, dtype=jnp.int32)[None], (B, T))
        mpos = jnp.broadcast_to(
            jnp.arange(mem.shape[1], dtype=jnp.int32)[None],
            (B, mem.shape[1]))
        def layer(x, lp):
            x = act_hint(x)
            h = attention_block(lp["attn"],
                                rmsnorm(x, lp["ln1"], cfg.norm_eps),
                                cfg, positions, causal=True)
            x = x + h
            h = attention_block(lp["xattn"],
                                rmsnorm(x, lp["ln_x"], cfg.norm_eps),
                                cfg, positions, causal=False, xkv=mem,
                                kv_positions=mpos, use_rope=False)
            x = x + h
            x = x + mlp_apply(lp["mlp"],
                              rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg.act)
            return x, None
        x, _ = jax.lax.scan(_maybe_remat(layer, cfg), x, params["layers"])
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"]["w"])
    else:
        logits = unembed_apply(params["unembed"], x)
    return logits, aux


def encode(params, cfg: ModelConfig, batch):
    """Encoder stack over precomputed frontend embeddings (audio stub)."""
    x = batch["src_embeds"].astype(dtype_of(cfg.dtype))
    B, S = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    def layer(x, lp):
        return _dense_layer(cfg, x, lp, positions, causal=False), None
    x, _ = jax.lax.scan(_maybe_remat(layer, cfg), x, params["enc_layers"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def train_loss(params, cfg: ModelConfig, batch, aux_weight: float = 0.01):
    logits, aux = forward_train(params, cfg, batch)
    loss = next_token_loss(logits, batch["labels"], batch.get("mask"))
    return loss + aux_weight * aux, (loss, aux)


# ---------------------------------------------------------------------------
# decode state
# ---------------------------------------------------------------------------


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      mem_len: int = 0):
    dt = dtype_of(cfg.dtype)
    L = cfg.n_layers
    fam = cfg.family

    def stack_tree(tree, n):
        return jax.tree.map(lambda a: jnp.broadcast_to(a, (n,) + a.shape),
                            tree)

    if fam in ("dense", "moe", "vlm"):
        return {"kv": stack_tree(init_kv_cache(cfg, batch, max_len, dt), L)}
    if fam == "ssm":
        init = mamba1_state_init if cfg.ssm_version == 1 \
            else mamba2_state_init
        return {"ssm": stack_tree(init(cfg, batch, dt), L)}
    if fam == "hybrid":
        win = cfg.sliding_window or min(max_len, 4096)
        import dataclasses
        shared_cfg = dataclasses.replace(cfg, sliding_window=None)
        return {"ssm": stack_tree(mamba2_state_init(cfg, batch, dt), L),
                "shared_kv": init_kv_cache(shared_cfg, batch, max_len, dt)}
    if fam == "encdec":
        return {"kv": stack_tree(init_kv_cache(cfg, batch, max_len, dt), L),
                "mem": jnp.zeros((batch, mem_len, cfg.d_model), dt)}
    raise ValueError(fam)


def decode_state_spec(cfg: ModelConfig, seq_shard: bool = False):
    """seq_shard=True (batch too small to shard, e.g. long_500k B=1):
    replicate the batch dim everywhere and shard KV caches along the
    sequence dim instead."""
    fam = cfg.family

    def fix_batch(tree):
        if not seq_shard:
            return tree
        def f(s):
            if len(s) and s[0] == ("pod", "data"):
                return P(None, *s[1:])
            return s
        return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, P))

    def stack_spec(tree):
        return jax.tree.map(lambda s: P(None, *s), tree,
                            is_leaf=lambda x: isinstance(x, P))

    if fam in ("dense", "moe", "vlm"):
        return {"kv": stack_spec(fix_batch(kv_cache_spec(seq_shard)))}
    if fam == "ssm":
        sp = mamba1_state_spec() if cfg.ssm_version == 1 \
            else mamba2_state_spec()
        return {"ssm": stack_spec(fix_batch(sp))}
    if fam == "hybrid":
        return {"ssm": stack_spec(fix_batch(mamba2_state_spec())),
                "shared_kv": fix_batch(kv_cache_spec(seq_shard))}
    if fam == "encdec":
        return {"kv": stack_spec(fix_batch(kv_cache_spec(seq_shard))),
                "mem": fix_batch(ACT_SPEC) if seq_shard else ACT_SPEC}
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# decode step (one token)
# ---------------------------------------------------------------------------


def decode_step(params, cfg: ModelConfig, state, tokens):
    """tokens: (B, 1) int32.  Returns (logits (B, 1, V), new_state)."""
    fam = cfg.family
    x = embed_apply(params["embed"], tokens)
    B = x.shape[0]

    if fam in ("dense", "moe", "vlm"):
        def layer(x, inp):
            lp, cache = inp
            h, nc = decode_attention_block(
                lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg, cache)
            x = x + h
            nx = rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if fam == "moe":
                y, _ = moe_apply(lp["moe"], nx, cfg)
            else:
                y = mlp_apply(lp["mlp"], nx, cfg.act)
            return x + y, nc
        x, new_kv = jax.lax.scan(layer, x, (params["layers"], state["kv"]))
        new_state = {"kv": new_kv}

    elif fam == "ssm":
        block = mamba1_block if cfg.ssm_version == 1 else mamba2_block
        def layer(x, inp):
            lp, st = inp
            h, ns = block(lp["ssm"], rmsnorm(x, lp["ln1"], cfg.norm_eps),
                          cfg, state=st)
            return x + h, ns
        x, new_ssm = jax.lax.scan(layer, x, (params["layers"], state["ssm"]))
        new_state = {"ssm": new_ssm}

    elif fam == "hybrid":
        shared = params["shared"]
        k = cfg.attn_every
        skv0 = state["shared_kv"]
        # shared attn cache is updated once per shared-block application;
        # we thread it through the scan carry.
        def layer(carry, inp):
            x, skv = carry
            lp, st, idx = inp
            h, ns = mamba2_block(lp["ssm"],
                                 rmsnorm(x, lp["ln1"], cfg.norm_eps),
                                 cfg, state=st)
            x = x + h
            def with_shared(op):
                x, skv = op
                h2, nskv = decode_attention_block(
                    shared["attn"], rmsnorm(x, shared["ln1"], cfg.norm_eps),
                    cfg, skv)
                x = x + h2
                x = x + mlp_apply(shared["mlp"],
                                  rmsnorm(x, shared["ln2"], cfg.norm_eps),
                                  cfg.act)
                return x, nskv
            x, skv = jax.lax.cond((idx + 1) % k == 0, with_shared,
                                  lambda op: op, (x, skv))
            return (x, skv), ns
        idxs = jnp.arange(cfg.n_layers)
        (x, new_skv), new_ssm = jax.lax.scan(
            layer, (x, skv0), (params["layers"], state["ssm"], idxs))
        new_state = {"ssm": new_ssm, "shared_kv": new_skv}

    elif fam == "encdec":
        mem = state["mem"]
        def layer(x, inp):
            lp, cache = inp
            h, nc = decode_attention_block(
                lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg, cache)
            x = x + h
            # cross attention over the fixed encoder memory
            pos = jnp.zeros((B, 1), jnp.int32)
            mpos = jnp.broadcast_to(
                jnp.arange(mem.shape[1], dtype=jnp.int32)[None],
                (B, mem.shape[1]))
            h = attention_block(lp["xattn"],
                                rmsnorm(x, lp["ln_x"], cfg.norm_eps),
                                cfg, pos, causal=False, xkv=mem,
                                kv_positions=mpos, use_rope=False)
            x = x + h
            x = x + mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps),
                              cfg.act)
            return x, nc
        x, new_kv = jax.lax.scan(layer, x, (params["layers"], state["kv"]))
        new_state = {"kv": new_kv, "mem": mem}
    else:
        raise ValueError(fam)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"]["w"])
    else:
        logits = unembed_apply(params["unembed"], x)
    return logits, new_state


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------


def prefill(params, cfg: ModelConfig, state, batch):
    """Run S tokens, fill caches.  Returns (last logits (B, 1, V), state)."""
    fam = cfg.family
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(dtype_of(cfg.dtype))
    else:
        x = embed_apply(params["embed"], batch["tokens"])
    B, T = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    if fam in ("dense", "moe", "vlm"):
        def layer(x, inp):
            lp, cache = inp
            x = act_hint(x)
            h, nc = prefill_attention_block(
                lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg,
                positions, cache)
            x = x + h
            nx = rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if fam == "moe":
                y, _ = moe_apply(lp["moe"], nx, cfg)
            else:
                y = mlp_apply(lp["mlp"], nx, cfg.act)
            return x + y, nc
        x, new_kv = jax.lax.scan(_maybe_remat(layer, cfg), x,
                                 (params["layers"], state["kv"]))
        new_state = {"kv": new_kv}

    elif fam == "ssm":
        block = mamba1_block if cfg.ssm_version == 1 else mamba2_block
        def layer(x, inp):
            lp, st = inp
            h, ns = block(lp["ssm"], rmsnorm(x, lp["ln1"], cfg.norm_eps),
                          cfg, state=st)
            return x + h, ns
        x, new_ssm = jax.lax.scan(_maybe_remat(layer, cfg), x,
                                  (params["layers"], state["ssm"]))
        new_state = {"ssm": new_ssm}

    elif fam == "hybrid":
        shared = params["shared"]
        k = cfg.attn_every
        skv0 = state["shared_kv"]
        def layer(carry, inp):
            x, skv = carry
            lp, st, idx = inp
            h, ns = mamba2_block(lp["ssm"],
                                 rmsnorm(x, lp["ln1"], cfg.norm_eps),
                                 cfg, state=st)
            x = x + h
            def with_shared(op):
                x, skv = op
                h2, nskv = prefill_attention_block(
                    shared["attn"], rmsnorm(x, shared["ln1"], cfg.norm_eps),
                    cfg, positions, skv)
                x = x + h2
                x = x + mlp_apply(shared["mlp"],
                                  rmsnorm(x, shared["ln2"], cfg.norm_eps),
                                  cfg.act)
                return x, nskv
            x, skv = jax.lax.cond((idx + 1) % k == 0, with_shared,
                                  lambda op: op, (x, skv))
            return (x, skv), ns
        idxs = jnp.arange(cfg.n_layers)
        (x, new_skv), new_ssm = jax.lax.scan(
            _maybe_remat(layer, cfg), (x, skv0),
            (params["layers"], state["ssm"], idxs))
        new_state = {"ssm": new_ssm, "shared_kv": new_skv}

    elif fam == "encdec":
        mem = encode(params, cfg, batch)
        def layer(x, inp):
            lp, cache = inp
            h, nc = prefill_attention_block(
                lp["attn"], rmsnorm(x, lp["ln1"], cfg.norm_eps), cfg,
                positions, cache)
            x = x + h
            mpos = jnp.broadcast_to(
                jnp.arange(mem.shape[1], dtype=jnp.int32)[None],
                (B, mem.shape[1]))
            h = attention_block(lp["xattn"],
                                rmsnorm(x, lp["ln_x"], cfg.norm_eps),
                                cfg, positions, causal=False, xkv=mem,
                                kv_positions=mpos, use_rope=False)
            x = x + h
            x = x + mlp_apply(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps),
                              cfg.act)
            return x, nc
        x, new_kv = jax.lax.scan(_maybe_remat(layer, cfg), x,
                                 (params["layers"], state["kv"]))
        new_state = {"kv": new_kv, "mem": mem}
    else:
        raise ValueError(fam)

    x = x[:, -1:, :]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("btd,vd->btv", x, params["embed"]["w"])
    else:
        logits = unembed_apply(params["unembed"], x)
    return logits, new_state
