from .serve_step import generate, make_decode_fn, make_prefill_fn  # noqa
