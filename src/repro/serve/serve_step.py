"""Serving loop: batched prefill + decode with greedy/temperature sampling.

The serve path reuses the model's prefill/decode_step; this module adds the
request-batch plumbing (continuous batching at the step granularity: each
decode step consumes a (B, 1) token frontier; finished sequences are masked
and their slots refilled by the driver in examples/serve_lm.py).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from ..models.transformer import decode_step, init_decode_state, prefill


def make_prefill_fn(cfg):
    @jax.jit
    def run(params, state, batch):
        return prefill(params, cfg, state, batch)
    return run


def make_decode_fn(cfg, temperature: float = 0.0):
    @jax.jit
    def run(params, state, tokens, key):
        logits, state = decode_step(params, cfg, state, tokens)
        logits = logits[:, -1, :].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(key, logits / temperature)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt[:, None].astype(jnp.int32), state
    return run


def generate(params, cfg, prompts, max_new_tokens: int = 16,
             temperature: float = 0.0, eos_id: int | None = None):
    """prompts: (B, S) int32.  Returns (B, max_new_tokens) int32."""
    B, S = prompts.shape
    state = init_decode_state(cfg, B, S + max_new_tokens)
    pf = make_prefill_fn(cfg)
    dec = make_decode_fn(cfg, temperature)
    logits, state = pf(params, state, {"tokens": prompts})
    tok = jnp.argmax(logits[:, -1, :].astype(jnp.float32),
                     axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    key = jax.random.PRNGKey(0)
    done = jnp.zeros((B, 1), bool)
    for i in range(max_new_tokens - 1):
        key, sub = jax.random.split(key)
        tok, state = dec(params, state, tok, sub)
        if eos_id is not None:
            done = done | (tok == eos_id)
            tok = jnp.where(done, eos_id, tok)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
