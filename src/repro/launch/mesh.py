"""Production meshes (dry-run target: TPU v5e pods).

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before first jax init)."""

from __future__ import annotations

import jax

# v5e hardware constants (roofline; see EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: newer jax wants explicit
    ``axis_types`` (Auto) for shard_map-style code; older releases predate
    ``jax.sharding.AxisType`` and reject the kwarg."""
    try:
        axis_types = (jax.sharding.AxisType.Auto,) * len(axes)
    except AttributeError:
        return jax.make_mesh(shape, axes)
    try:
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    except TypeError:
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh():
    """Whatever this host has (tests / examples)."""
    n = len(jax.devices())
    return make_mesh_compat((n, 1), ("data", "model"))


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
