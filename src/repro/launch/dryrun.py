import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and emit memory/cost/roofline records.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices to build the
(16,16) single-pod and (2,16,16) multi-pod meshes.  Never set this globally
(tests/benches want the 1 real device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch dbrx_132b \
        --shape train_4k --mesh single --out experiments/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --engine         # DB cell

Output: one JSON per cell under --out with memory_analysis numbers,
cost_analysis, collective breakdown and the three roofline terms.
"""

import argparse     # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import numpy as np  # noqa: E402

from ..configs.registry import ARCH_IDS, SHAPES, cells, get_config  # noqa: E402
from .hlo_analysis import xla_cost  # noqa: E402
from ..models.transformer import decode_step, prefill, train_loss  # noqa: E402
from ..train.optimizer import AdamWConfig  # noqa: E402
from ..train.train_step import make_train_step  # noqa: E402
from .mesh import make_production_mesh, mesh_chips  # noqa: E402
from .roofline import analyze  # noqa: E402
from .specs import cell_shardings  # noqa: E402


def build_step(cfg, cell, sh):
    """Returns (jitted_fn, arg_structs) for the cell kind."""
    if cell.kind == "train":
        opt_cfg = AdamWConfig()
        step = make_train_step(cfg, opt_cfg)
        fn = jax.jit(
            step,
            in_shardings=(sh["params_shardings"], sh["opt_shardings"],
                          sh["batch_shardings"]),
            out_shardings=(sh["params_shardings"], sh["opt_shardings"],
                           None),
            donate_argnums=(0, 1),
        )
        args = (sh["params_structs"], sh["opt_structs"],
                sh["batch_structs"])
        return fn, args
    if cell.kind == "prefill":
        def step(params, state, batch):
            return prefill(params, cfg, state, batch)
        fn = jax.jit(
            step,
            in_shardings=(sh["params_shardings"], sh["state_shardings"],
                          sh["batch_shardings"]),
            out_shardings=(None, sh["state_shardings"]),
            donate_argnums=(1,),
        )
        args = (sh["params_structs"], sh["state_structs"],
                sh["batch_structs"])
        return fn, args
    # decode
    def step(params, state, batch):
        return decode_step(params, cfg, state, batch["tokens"])
    fn = jax.jit(
        step,
        in_shardings=(sh["params_shardings"], sh["state_shardings"],
                      sh["batch_shardings"]),
        out_shardings=(None, sh["state_shardings"]),
        donate_argnums=(1,),
    )
    args = (sh["params_structs"], sh["state_structs"], sh["batch_structs"])
    return fn, args


def run_cell(arch: str, cell, mesh_name: str, out_dir: str,
             verbose: bool = True, overrides: dict = None) -> dict:
    import dataclasses
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    cfg = dataclasses.replace(get_config(arch),
                              tp_pad=mesh.shape["model"],
                              **(overrides or {}))
    chips = mesh_chips(mesh)
    t0 = time.time()
    rec = {"arch": arch, "shape": cell.name, "mesh": mesh_name,
           "chips": chips, "status": "ok"}
    try:
        from ..models.layers import set_hint_mesh
        set_hint_mesh(mesh)
        with mesh:
            sh = cell_shardings(cfg, cell, mesh)
            fn, args = build_step(cfg, cell, sh)
            lowered = fn.lower(*args)
            t_lower = time.time()
            compiled = lowered.compile()
            t_compile = time.time()
            ma = compiled.memory_analysis()
            cost = xla_cost(compiled)
            hlo = compiled.as_text()
        mem_bytes = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes)
        roof = analyze(arch, cell, mesh_name, chips, cfg, cost,
                       mem_bytes, hlo)
        rec.update(roof.to_json())
        rec["memory_analysis"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        }
        rec["timings"] = {"lower_s": t_lower - t0,
                          "compile_s": t_compile - t_lower}
        rec["fits_hbm"] = mem_bytes < 16e9        # v5e 16 GiB
        if verbose:
            print(f"[{arch} x {cell.name} x {mesh_name}] OK "
                  f"mem/dev={mem_bytes/1e9:.2f}GB "
                  f"compute={rec['compute_s']*1e3:.1f}ms "
                  f"memory={rec['memory_s']*1e3:.1f}ms "
                  f"coll={rec['collective_s']*1e3:.1f}ms "
                  f"bottleneck={rec['bottleneck']} "
                  f"useful={rec['useful_ratio']:.2f} "
                  f"(compile {rec['timings']['compile_s']:.0f}s)",
                  flush=True)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[{arch} x {cell.name} x {mesh_name}] FAIL {rec['error']}",
                  flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir,
                            f"{arch}__{cell.name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def run_engine_cell(mesh_name: str, out_dir: str) -> dict:
    """Bonus cell: the embedded engine's distributed scan-agg fragment
    lowered on the production mesh (the paper's Fig. 2 at pod scale)."""
    from ..core.expression import Col
    from ..core.parallel import ScanAggSpec, build_query_step
    from ..core.relalg import AggSpec
    from ..core.types import DBType
    import jax.numpy as jnp

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh_chips(mesh)
    rows = 1 << 30              # 1B rows sharded over the mesh
    spec = ScanAggSpec(
        table="lineitem",
        conjuncts=[Col("l_quantity") < 24.0,
                   (Col("l_discount") >= 0.05) & (Col("l_discount") <= 0.07)],
        group_keys=["l_returnflag"],
        key_domains=[(0.0, 4)],
        aggs=[AggSpec("sum", Col("l_extendedprice") * Col("l_discount"),
                      "revenue"),
              AggSpec("count", None, "n")],
        n_groups=4,
        columns=["l_discount", "l_extendedprice", "l_quantity",
                 "l_returnflag"],
    )
    meta = {"l_quantity": (DBType.FLOAT64, None, 0),
            "l_discount": (DBType.FLOAT64, None, 0),
            "l_extendedprice": (DBType.FLOAT64, None, 0),
            "l_returnflag": (DBType.VARCHAR, None, 0)}
    rec = {"arch": "engine_scan_agg", "shape": f"rows_{rows}",
           "mesh": mesh_name, "chips": chips, "status": "ok"}
    t0 = time.time()
    try:
        with mesh:
            step = build_query_step(spec, meta, mesh)
            from jax.sharding import NamedSharding, PartitionSpec as P
            axes = tuple(n for n in mesh.axis_names if n in ("pod", "data"))
            rowspec = P(axes if len(axes) > 1 else axes[0])
            s = NamedSharding(mesh, rowspec)
            valid = jax.ShapeDtypeStruct((rows,), jnp.bool_)
            colspecs = [jax.ShapeDtypeStruct(
                (rows,), jnp.float64 if meta[c][0] != DBType.VARCHAR
                else jnp.int32) for c in spec.columns]
            lowered = step.lower(valid, *colspecs)
            compiled = lowered.compile()
            ma = compiled.memory_analysis()
            cost = xla_cost(compiled)
            hlo = compiled.as_text()
        from .roofline import collective_bytes
        coll = collective_bytes(hlo)
        rec["hlo_flops"] = float(cost.get("flops", 0.0))
        rec["hlo_bytes"] = float(cost.get("bytes accessed", 0.0))
        rec["coll_bytes"] = coll["total"]
        rec["memory_s"] = rec["hlo_bytes"] / 819e9
        rec["collective_s"] = rec["coll_bytes"] / 50e9
        rec["bytes_per_device"] = (ma.argument_size_in_bytes
                                   + ma.temp_size_in_bytes)
        rec["compile_s"] = time.time() - t0
        print(f"[engine x {mesh_name}] OK bytes/dev="
              f"{rec['bytes_per_device']/1e9:.2f}GB "
              f"memory_s={rec['memory_s']*1e3:.2f}ms "
              f"coll_s={rec['collective_s']*1e6:.1f}us", flush=True)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        print(f"[engine x {mesh_name}] FAIL {rec['error']}", flush=True)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir,
                               f"engine__scan_agg__{mesh_name}.json"),
                  "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one shape (default all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--engine", action="store_true",
                    help="run the engine scan-agg cell instead")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells whose JSON already exists and is ok")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.engine:
        for m in meshes:
            run_engine_cell(m, args.out)
        return

    archs = [args.arch] if args.arch else ARCH_IDS
    n_fail = 0
    for arch in archs:
        for cell in cells(arch):
            if args.shape and cell.name != args.shape:
                continue
            for m in meshes:
                path = os.path.join(
                    args.out, f"{arch}__{cell.name}__{m}.json")
                if args.skip_done and os.path.exists(path):
                    try:
                        with open(path) as f:
                            if json.load(f).get("status") == "ok":
                                print(f"[{arch} x {cell.name} x {m}] cached",
                                      flush=True)
                                continue
                    except json.JSONDecodeError:
                        pass
                rec = run_cell(arch, cell, m, args.out)
                n_fail += rec["status"] != "ok"
    print(f"dry-run complete; failures: {n_fail}", flush=True)
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
