"""Render EXPERIMENTS.md tables from dry-run JSON records.

    PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""

from __future__ import annotations

import glob
import json
import os
import sys


def load(out_dir: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def dryrun_table(recs) -> str:
    lines = ["| arch | shape | mesh | chips | bytes/dev | fits 16G | "
             "compile s | status |",
             "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["arch"].startswith("engine"):
            continue
        mem = r.get("bytes_per_device", 0) / 1e9
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{mem:.2f} GB | {'yes' if r.get('fits_hbm') else 'NO'} | "
            f"{r.get('timings', {}).get('compile_s', 0):.0f} | "
            f"{r['status']} |")
    return "\n".join(lines)


def roofline_table(recs, mesh: str = "single") -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "bottleneck | MODEL_FLOPS | useful ratio | one-line lever |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["mesh"] != mesh or r["status"] != "ok" \
                or r["arch"].startswith("engine"):
            continue
        lever = _lever(r)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"{r['bottleneck']} | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {lever} |")
    return "\n".join(lines)


def _lever(r) -> str:
    b = r["bottleneck"]
    if b == "collective":
        ar = r["coll_detail"].get("all-reduce", 0)
        ag = r["coll_detail"].get("all-gather", 0)
        if ar > ag:
            return "reduce-scatter the grad all-reduce / overlap DP"
        return "cache FSDP gathers across fwd+bwd (or widen TP)"
    if b == "memory":
        if r["shape"].startswith("decode"):
            return "KV-cache layout/quantization; batch more requests"
        if "mamba" in r["arch"] or "zamba" in r["arch"]:
            return "larger SSM chunk / fused scan kernel"
        return "fuse attention tiles (flash) / chunked loss"
    return "increase per-device batch; reduce padding waste"


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(out_dir)
    ok = [r for r in recs if r["status"] == "ok"]
    print(f"## Dry-run summary: {len(ok)}/{len(recs)} cells ok\n")
    print(dryrun_table(recs))
    print("\n## Roofline (single-pod, 256 chips)\n")
    print(roofline_table(recs, "single"))
    print("\n## Roofline (multi-pod, 512 chips)\n")
    print(roofline_table(recs, "multi"))


if __name__ == "__main__":
    main()
