"""End-to-end training driver (runnable on this host; mesh-portable).

Wires every substrate together: the embedded columnar store feeds batches
(zero-copy cursor slices of an immutable table version), the pjit'd train
step updates sharded params/optimizer state, the checkpoint manager commits
{model, optimizer, data-cursor} atomically, heartbeats + straggler stats
stream to the run directory, and a SIGTERM-safe loop resumes from `latest`
(tested by killing/restarting in tests/test_train.py).

Usage (quickstart numbers: ~15M-param model, a few hundred steps):
    PYTHONPATH=src python -m repro.launch.train --steps 200 --d-model 256
    PYTHONPATH=src python -m repro.launch.train --arch qwen2_5_14b --smoke
"""

from __future__ import annotations

import argparse
import json
import os
import socket
import time

import jax

from ..configs.registry import get_config
from ..core.session import startup
from ..data.pipeline import TokenPipeline, curate, tokenize_corpus
from ..models.config import ModelConfig
from ..models.transformer import init_model
from ..train.checkpoint import (latest_step, restore_checkpoint,
                                save_checkpoint)
from ..train.fault import Heartbeat, StragglerDetector
from ..train.optimizer import AdamWConfig, init_opt_state
from ..train.train_step import make_train_step
from .mesh import make_local_mesh


def small_config(args) -> ModelConfig:
    return ModelConfig(
        name="quickstart-lm", family="dense",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(2, args.d_model // 64),
        n_kv_heads=max(1, args.d_model // 128),
        d_ff=args.d_model * 4, vocab=8192, d_head=64,
        dtype="float32", attn_q_chunk=256, attn_kv_chunk=256,
    )


def run(args) -> dict:
    mesh = make_local_mesh()
    if args.arch:
        cfg = get_config(args.arch)
        if args.smoke:
            cfg = cfg.smoke()
    else:
        cfg = small_config(args)

    # --- embedded store: corpus + curation -------------------------------
    db = startup(args.db_dir if args.db_dir else None)
    need = args.batch * (args.seq_len + 1) * max(args.steps, 64) + 1
    n_tokens = min(need, args.max_tokens)
    if "corpus" not in db.catalog:
        tokenize_corpus(db, n_tokens, cfg.vocab, seed=args.seed)
        curate(db, "corpus", "corpus_clean", drop_token=0)
    pipe = TokenPipeline(db, "corpus_clean", batch=args.batch,
                         seq_len=args.seq_len)

    # --- model + optimizer -------------------------------------------------
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=20,
                          total_steps=args.steps,
                          compress_grads=args.compress_grads)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=args.microbatches))

    start_step = 0
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        params, opt_state, extra, start_step = restore_checkpoint(
            args.ckpt_dir)
        pipe.restore(extra["pipeline"])
        print(f"resumed from step {start_step}")
    else:
        params = init_model(jax.random.PRNGKey(args.seed), cfg)
        opt_state = init_opt_state(params)

    hb = Heartbeat(os.path.join(args.run_dir, "hb"), socket.gethostname())
    strag = StragglerDetector()
    metrics_path = os.path.join(args.run_dir, "metrics.jsonl")
    os.makedirs(args.run_dir, exist_ok=True)

    losses = []
    t_prev = time.time()
    for step in range(start_step, args.steps):
        batch = pipe.next_batch()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        now = time.time()
        strag.record(hb.host, now - t_prev)
        t_prev = now
        hb.beat(step)
        with open(metrics_path, "a") as f:
            f.write(json.dumps({"step": step, "loss": loss,
                                "lr": float(metrics["lr"]),
                                "grad_norm": float(metrics["grad_norm"])})
                    + "\n")
        if args.log_every and step % args.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}", flush=True)
        if args.ckpt_dir and args.ckpt_every \
                and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1, params, opt_state,
                            extra={"pipeline": pipe.state()},
                            async_write=args.async_ckpt)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, params, opt_state,
                        extra={"pipeline": pipe.state()})
    return {"first_loss": losses[0] if losses else None,
            "last_loss": losses[-1] if losses else None,
            "steps": len(losses)}


def build_parser():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--max-tokens", type=int, default=5_000_000)
    ap.add_argument("--db-dir", default=None)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--async-ckpt", action="store_true")
    ap.add_argument("--run-dir", default="runs/default")
    ap.add_argument("--log-every", type=int, default=20)
    return ap


if __name__ == "__main__":
    result = run(build_parser().parse_args())
    print(json.dumps(result))
