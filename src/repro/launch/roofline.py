"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs            / (chips x 197 TFLOP/s bf16)
    memory     = HLO_bytes_accessed   / (chips x 819 GB/s HBM)
    collective = collective_bytes     / (chips x 50 GB/s/link ICI)

FLOPs/bytes come from ``compiled.cost_analysis()``.  collective_bytes is
parsed out of the compiled HLO: we sum the *result-shape* bytes of every
all-gather / all-to-all / collective-permute, operand bytes of every
reduce-scatter, and 2x bytes for all-reduce (reduce + broadcast phases of a
ring).  This counts bytes crossing the ICI fabric once per ring traversal —
a standard first-order model (actual rings move (n-1)/n of it).
"""

from __future__ import annotations

import re
from dataclasses import asdict, dataclass
from typing import Optional

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
    "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum collective bytes by op kind from compiled HLO text."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3)
        b = _shape_bytes(shape_str)
        if kind == "all-reduce":
            b *= 2                      # reduce + broadcast phases
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float              # 6·N·D (active-N for MoE)
    bytes_per_device: float         # peak memory from memory_analysis
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0       # MODEL_FLOPS / HLO_FLOPs
    coll_detail: Optional[dict] = None

    def finalize(self):
        # cost_analysis() on a partitioned module reports PER-DEVICE flops
        # and bytes (calibrated empirically: a 4-way-sharded matmul reports
        # global/4), so each term divides by a single chip's roof — which
        # equals the spec's global/(chips x roof).
        self.compute_s = self.hlo_flops / PEAK_FLOPS_BF16
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.coll_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        global_flops = self.hlo_flops * self.chips
        self.useful_ratio = (self.model_flops / global_flops
                             if global_flops else 0.0)
        return self

    def to_json(self) -> dict:
        return asdict(self)


def model_flops_for_cell(cfg, cell) -> float:
    """6·N·D for training; 2·N·D per generated/processed token at
    inference (forward only)."""
    n_active = cfg.active_param_count()
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    tokens = cell.global_batch * 1
    return 2.0 * n_active * tokens


def analyze(arch: str, cell, mesh_name: str, chips: int, cfg,
            cost: dict, mem_bytes: float, hlo_text: str) -> Roofline:
    """Costs come from the trip-count-aware HLO model (hlo_analysis.py);
    raw cost_analysis() numbers are recorded alongside for reference (they
    undercount scan bodies — calibrated in tests/test_hlo_analysis.py)."""
    from .hlo_analysis import analyze_hlo
    mc = analyze_hlo(hlo_text)
    coll = dict(mc.coll)
    coll["total"] = mc.coll_total
    r = Roofline(
        arch=arch, shape=cell.name, mesh=mesh_name, chips=chips,
        hlo_flops=mc.flops,
        hlo_bytes=mc.hbm_bytes,
        coll_bytes=mc.coll_total,
        model_flops=model_flops_for_cell(cfg, cell),
        bytes_per_device=mem_bytes,
        coll_detail={**coll,
                     "hbm_bytes_upper_unfused": mc.hbm_upper,
                     "xla_cost_flops_per_dev_scanbody_once":
                         float(cost.get("flops", 0.0)),
                     "xla_cost_bytes_per_dev_scanbody_once":
                         float(cost.get("bytes accessed", 0.0)),
                     "notes": mc.notes[:5]},
    )
    return r.finalize()
