"""Trip-count-aware HLO cost model.

``compiled.cost_analysis()`` counts each while-loop body ONCE (calibrated:
an 8-iteration lax.scan of 512³ matmuls reports exactly one matmul's
flops).  Every model here is scan-over-layers, so module totals must weight
each computation by its execution count.  This module parses the compiled
HLO text into computations, costs each instruction locally, resolves while
trip counts from the loop-condition constants, and folds nested loops:

  total(comp) = Σ_instr local_cost
              + Σ while_instr trips x (total(body) + total(cond))
              + Σ fusion/call refs flops+coll(callee)   (bytes NOT added:
                a fusion is one kernel — its body ops are not HBM traffic)

Costed quantities (per device — compiled HLO is the partitioned module):
  * flops       — dot ops: 2 x prod(output dims) x contracted size
  * coll        — collective bytes by kind (all-reduce 2x: reduce+broadcast)
  * hbm_bytes   — Σ over materializing instructions of output bytes +
                  first-operand-group bytes (roofline HBM-traffic proxy)

Validated in tests/test_hlo_analysis.py against cost_analysis() on
loop-free programs (exact for dots) and against hand counts on scans.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPCODE_RE = re.compile(r"[\s)]([a-z][a-z0-9\-]*)\(")
_WHILE_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_WHILE_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_DOT_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def xla_cost(compiled) -> dict:
    """``compiled.cost_analysis()`` across jax versions: newer jax returns
    one properties dict, older returns a one-element list of dicts."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca

# Core traffic model: ops that materialize HBM traffic on TPU even after
# fusion (real kernels).  Elementwise/layout glue (convert, broadcast,
# transpose, reshape, copy, add, multiply, reduce, select, pad, slice)
# fuses into its producer/consumer on TPU, so it goes into the separately
# reported *upper bound* only.
_TRAFFIC_OPS = {
    "fusion", "scatter", "gather", "dynamic-update-slice", "dynamic-slice",
    "custom-call", "convolution", "sort", "dot", "select-and-scatter",
    "reduce-window", "concatenate",
}
_TRAFFIC_OPS_UPPER = _TRAFFIC_OPS | {
    "copy", "convert", "transpose", "reshape", "broadcast", "reduce",
    "pad", "slice", "rng-bit-generator", "add", "multiply", "subtract",
    "divide", "select", "exponential", "tanh", "maximum", "minimum",
}


def _parse_shapes(s: str):
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _shape_bytes(s: str) -> float:
    total = 0
    for dt, dims in _parse_shapes(s):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return float(total)


@dataclass
class CompCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    hbm_upper: float = 0.0
    has_slice: bool = False       # computation slices an operand (fusion
                                  # operands then count as slice-sized)
    slice_traffic: float = 0.0    # bytes actually touched by ds/dus inside
    has_math: bool = False        # any arithmetic op (vs pure layout glue)
    coll: dict = field(default_factory=lambda: dict.fromkeys(COLLECTIVES, 0.0))
    whiles: list = field(default_factory=list)
    calls: list = field(default_factory=list)
    const: int | None = None          # largest integer constant (trip count)


@dataclass
class ModuleCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    hbm_upper: float = 0.0
    coll: dict = field(default_factory=lambda: dict.fromkeys(COLLECTIVES, 0.0))
    coll_total: float = 0.0
    notes: list = field(default_factory=list)


_NAME_RE = re.compile(r"%([\w.\-]+)")


def _operand_names(code: str, op: str) -> list[str]:
    try:
        args = code.split(op + "(", 1)[1].split(")")[0]
    except IndexError:
        return []
    return _NAME_RE.findall(args)


def _dot_flops(result_part: str, code: str, symbols: dict) -> float:
    # operands are referenced by name; resolve via the symbol table
    names = _operand_names(code, "dot")
    lhs = None
    if names and names[0] in symbols:
        shp = _parse_shapes(symbols[names[0]])
        if shp:
            lhs = shp[-1][1]
    out_shapes = _parse_shapes(result_part)
    out_n = 1
    if out_shapes:
        for d in out_shapes[-1][1]:
            out_n *= d
    contracted = 1
    m = _DOT_LHS_CONTRACT_RE.search(code)
    if m and lhs is not None:
        for idx in m.group(1).split(","):
            if idx:
                contracted *= lhs[int(idx)]
    return 2.0 * out_n * contracted


def parse_hlo(text: str):
    comps: dict[str, CompCost] = {}
    symbols: dict[str, str] = {}     # instr name -> result type string
    entry = None
    cur: CompCost | None = None
    for raw in text.splitlines():
        if not raw:
            continue
        if not raw.startswith(" "):
            # possible computation header: [ENTRY] %name (...) -> ... {
            s = raw.strip()
            if s.endswith("{") and ("->" in s) and ("(" in s):
                name = s.split("(")[0].replace("ENTRY", "").strip()
                name = name.lstrip("%").rstrip()
                cur = comps.setdefault(name, CompCost())
                if s.startswith("ENTRY"):
                    entry = name
            elif s == "}":
                cur = None
            continue
        if cur is None or " = " not in raw:
            continue
        raw = raw.replace("ROOT %", "%", 1)
        code = raw.split(", metadata=")[0]
        lhs_name, rhs = code.split(" = ", 1)
        lhs_name = lhs_name.strip().lstrip("%").replace("ROOT ", "")
        if lhs_name.startswith("ROOT"):
            lhs_name = lhs_name[4:].strip().lstrip("%")
        # find opcode: first known-ish token before '('
        op = None
        for m in _OPCODE_RE.finditer(" " + rhs):
            tok = m.group(1)
            if tok in ("tuple", "get-tuple-element", "parameter", "bitcast",
                       "constant", "compare", "add", "subtract", "multiply",
                       "divide", "and", "or", "not", "select", "exponential",
                       "iota", "maximum", "minimum"):
                op = tok
                break
            op = tok
            break
        if op is None:
            if "constant(" in rhs:
                cm = _CONST_RE.search(rhs)
                if cm:
                    v = int(cm.group(1))
                    cur.const = max(cur.const or 0, v)
            continue
        result_part = rhs.split(op + "(")[0]
        symbols[lhs_name] = result_part
        if op in ("add", "subtract", "multiply", "divide", "dot", "reduce",
                  "exponential", "exponential-minus-one", "log", "power",
                  "rsqrt", "sqrt", "tanh", "maximum", "minimum", "compare",
                  "select", "convert", "and", "or", "xor", "negate",
                  "scatter", "iota", "clamp", "sign", "floor", "ceil"):
            cur.has_math = True

        cm = _CONST_RE.search(rhs)
        if cm:
            cur.const = max(cur.const or 0, int(cm.group(1)))

        if op == "while":
            b = _WHILE_BODY_RE.search(code)
            c = _WHILE_COND_RE.search(code)
            if b and c:
                cur.whiles.append((b.group(1), c.group(1)))
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVES:
            byt = _shape_bytes(result_part)
            cur.coll[base] += byt * (2 if base == "all-reduce" else 1)
            cur.hbm_bytes += byt
            cur.hbm_upper += byt
            continue
        if base == "dot":
            cur.flops += _dot_flops(result_part, code, symbols)
            opb = sum(_shape_bytes(symbols.get(n, ""))
                      for n in _operand_names(code, op))
            cur.hbm_bytes += _shape_bytes(result_part) + opb
            cur.hbm_upper += _shape_bytes(result_part) + opb
            continue
        if base in _TRAFFIC_OPS_UPPER:
            # slicing ops touch only the slice, not the whole operand —
            # counting full operands overcounted scan-xs slicing by the
            # trip count (measured 1.4 PB/step on falcon-mamba train).
            names = _operand_names(code, op)
            if base in ("dynamic-slice", "slice", "gather"):
                t = 2 * _shape_bytes(result_part)
            elif base == "dynamic-update-slice":
                upd = _shape_bytes(symbols.get(names[1], "")) \
                    if len(names) > 1 else _shape_bytes(result_part)
                t = 2 * upd
            elif base == "scatter":
                upd = _shape_bytes(symbols.get(names[2], "")) \
                    if len(names) > 2 else _shape_bytes(result_part)
                t = 2 * upd
            elif base == "fusion":
                # a fusion whose body dynamic-slices/updates its parameter
                # (the scan-xs / scan-residual-stacking patterns) touches
                # only the slices, not the whole buffers: in-loop fusions
                # that dus into a stacked residual buffer would otherwise
                # count the full stack once per trip (measured 610 TB on
                # falcon-mamba; real traffic is the 8 MB update per trip).
                res = _shape_bytes(result_part)
                callee = None
                cm2 = _CALLS_RE.search(code)
                if cm2 is not None:
                    callee = comps.get(cm2.group(1))
                if callee is not None and callee.has_slice \
                        and callee.slice_traffic > 0:
                    t = callee.slice_traffic \
                        + sum(min(_shape_bytes(symbols.get(n2, "")),
                                  callee.slice_traffic)
                              for n2 in names)
                elif callee is not None and not callee.has_math:
                    # pure layout glue (copy/transpose/bitcast chains):
                    # loop-state copies that TPU aliases in place — count
                    # in the upper bound only.
                    cur.hbm_upper += res
                    continue
                else:
                    opb = sum(_shape_bytes(symbols.get(n2, ""))
                              for n2 in names)
                    t = res + opb
            else:
                opb = sum(_shape_bytes(symbols.get(n, "")) for n in names)
                t = _shape_bytes(result_part) + opb
            if base in ("dynamic-slice", "slice", "gather",
                        "dynamic-update-slice", "scatter"):
                cur.has_slice = True
                cur.slice_traffic += t
            cur.hbm_upper += t
            if base in _TRAFFIC_OPS:
                cur.hbm_bytes += t
        for mm in _CALLS_RE.finditer(code):
            cur.calls.append(mm.group(1))
        bm = _BRANCH_RE.search(code)
        if bm:
            for b in bm.group(1).split(","):
                cur.calls.append(b.strip().lstrip("%"))
    return comps, entry


def analyze_hlo(text: str) -> ModuleCost:
    comps, entry = parse_hlo(text)
    mc = ModuleCost()
    memo: dict[str, tuple] = {}

    def cond_trips(cond_name: str):
        c = comps.get(cond_name)
        if c is None:
            return None
        if c.const is not None:
            return c.const
        # constant may live in a fused compare computation
        for child in c.calls:
            cc = comps.get(child)
            if cc is not None and cc.const is not None:
                return cc.const
        return None

    def total(name: str, stack=()):
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return (0.0, 0.0, dict.fromkeys(COLLECTIVES, 0.0))
        c = comps[name]
        fl, hb, hu = c.flops, c.hbm_bytes, c.hbm_upper
        co = dict(c.coll)
        for child in c.calls:
            cf, _, _, cc = total(child, stack + (name,))
            fl += cf                       # flops & collectives of fusion
            for k in co:                   # bodies count; bytes do not
                co[k] += cc[k]
        for body, cond in c.whiles:
            trips = cond_trips(cond)
            if trips is None:
                trips = 1
                mc.notes.append(f"unresolved trip count for {body}")
            bf, bh, bu, bc = total(body, stack + (name,))
            fl += trips * bf
            hb += trips * bh
            hu += trips * bu
            for k in co:
                co[k] += trips * bc[k]
        memo[name] = (fl, hb, hu, co)
        return memo[name]

    if entry:
        fl, hb, hu, co = total(entry)
        mc.flops = fl
        mc.hbm_bytes = hb
        mc.hbm_upper = hu
        mc.coll = co
        mc.coll_total = sum(co.values())
    return mc
