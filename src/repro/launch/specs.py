"""Abstract inputs (ShapeDtypeStruct) + shardings per (arch x shape) cell.

``input_specs`` returns weak-type-correct, shardable stand-ins for every
model input — no device allocation; the same pattern serves both the
multi-pod dry-run and the real launcher (which replaces the structs with
device arrays of identical shape/sharding).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.registry import ShapeCell
from ..models.config import ModelConfig
from ..models.transformer import (decode_state_spec, init_decode_state,
                                  init_model, model_spec)
from ..train.optimizer import init_opt_state, opt_state_spec
from .mesh import batch_axes


def _batch_spec(mesh: Mesh, global_batch: int) -> P:
    """Shard batch over (pod, data) when divisible; else replicate (e.g.
    long_500k batch=1, which shards the sequence/state instead)."""
    axes = batch_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if global_batch % n == 0:
        return P(axes if len(axes) > 1 else axes[0])
    return P()


def _src_len(cell: ShapeCell) -> int:
    # encoder memory length for enc-dec cells (audio frontend stub)
    return min(cell.seq_len, 4096)


def abstract(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh):
    """(ShapeDtypeStruct tree, PartitionSpec tree) for the data batch."""
    B, S = cell.global_batch, cell.seq_len
    bspec = _batch_spec(mesh, B)
    embed_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if cell.kind == "train":
        shapes, specs = {}, {}
        if cfg.embeds_input:
            shapes["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                    embed_dt)
            specs["embeds"] = P(*bspec, None, None)
        else:
            shapes["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            specs["tokens"] = P(*bspec, None)
        if cfg.family == "encdec":
            shapes["src_embeds"] = jax.ShapeDtypeStruct(
                (B, _src_len(cell), cfg.d_model), embed_dt)
            specs["src_embeds"] = P(*bspec, None, None)
            shapes["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            specs["tokens"] = P(*bspec, None)
        shapes["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["labels"] = P(*bspec, None)
        return shapes, specs
    if cell.kind == "prefill":
        shapes, specs = {}, {}
        if cfg.embeds_input:
            shapes["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                    embed_dt)
            specs["embeds"] = P(*bspec, None, None)
        else:
            shapes["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            specs["tokens"] = P(*bspec, None)
        if cfg.family == "encdec":
            shapes["src_embeds"] = jax.ShapeDtypeStruct(
                (B, _src_len(cell), cfg.d_model), embed_dt)
            specs["src_embeds"] = P(*bspec, None, None)
            shapes["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
            specs["tokens"] = P(*bspec, None)
        return shapes, specs
    # decode: one token per sequence
    return ({"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)},
            {"tokens": P(*bspec, None)})


def param_structs(cfg: ModelConfig):
    """Abstract params via eval_shape — no allocation."""
    return jax.eval_shape(
        lambda: init_model(jax.random.PRNGKey(0), cfg))


def decode_state_structs(cfg: ModelConfig, cell: ShapeCell):
    B, S = cell.global_batch, cell.seq_len
    return jax.eval_shape(
        lambda: init_decode_state(cfg, B, S, mem_len=_src_len(cell)))


def _shard_free_dim(spec: P, shape: tuple, axis: str, axis_size: int,
                    min_elems: int = 1 << 16) -> P:
    """Add ``axis`` on the last unsharded, divisible dim of a leaf (the
    ZeRO-1/FSDP transform).  Leaves smaller than min_elems stay put."""
    n = 1
    for d in shape:
        n *= d
    if n < min_elems:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    flat = [a for e in entries if e is not None
            for a in (e if isinstance(e, tuple) else (e,))]
    if axis in flat:
        return spec                              # already sharded over axis
    # Prefer the second-to-last dim: in every matmul layout here that is
    # the *contracted* dim, so GSPMD resolves the sharded einsum by
    # all-gathering the (small) weight — true FSDP.  Sharding an output
    # dim instead conflicts with the batch sharding of the activations and
    # GSPMD resolves it by all-gathering the *tokens* (measured: dbrx
    # collective term 32s -> ~2s; EXPERIMENTS.md §Perf).
    ndim = len(shape)
    order = [ndim - 2, ndim - 1] + list(range(ndim - 3, 0, -1))
    for i in order:
        if i <= 0 or i >= ndim:
            continue
        if entries[i] is None and shape[i] % axis_size == 0:
            entries[i] = axis
            return P(*entries)
    return spec


def _apply_zero(spec_tree, struct_tree, mesh: Mesh) -> dict:
    size = mesh.shape.get("data", 1)
    return jax.tree.map(
        lambda s, t: _shard_free_dim(s, t.shape, "data", size),
        spec_tree, struct_tree, is_leaf=lambda x: isinstance(x, P))


def fsdp_wanted(cfg: ModelConfig, mesh: Mesh) -> bool:
    """FSDP the params when bf16 weights per device would exceed ~3 GB
    under pure TP (the dbrx-132b / llava-34b regime)."""
    per_dev = 2 * cfg.param_count() / mesh.shape.get("model", 1)
    return per_dev > 3e9


def cell_shardings(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh,
                   with_opt: bool = True, fsdp: str = "auto"):
    """All shardings for one cell: returns dict with
    params/opt_state/batch/state NamedSharding trees + struct trees.

    Distributed-optimizer policy: optimizer moments always get the ZeRO-1
    transform (sharded over ``data`` on a free dim); params additionally get
    FSDP (same transform) when the arch is too big for pure TP."""
    def fit(s: P) -> P:
        """Drop axes the mesh doesn't have (single-pod mesh has no 'pod')."""
        names = set(mesh.axis_names)
        entries = []
        for e in s:
            if isinstance(e, tuple):
                kept = tuple(a for a in e if a in names)
                entries.append(kept if len(kept) > 1
                               else (kept[0] if kept else None))
            elif e is not None and e not in names:
                entries.append(None)
            else:
                entries.append(e)
        return P(*entries)

    def ns(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, fit(s)), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    pspec = model_spec(cfg)
    pstructs = param_structs(cfg)
    use_fsdp = fsdp_wanted(cfg, mesh) if fsdp == "auto" else (fsdp == "on")
    if use_fsdp:
        pspec = _apply_zero(pspec, pstructs, mesh)
    out = {
        "params_structs": pstructs,
        "params_shardings": ns(pspec),
        "fsdp": use_fsdp,
    }
    bshapes, bspecs = batch_specs(cfg, cell, mesh)
    out["batch_structs"] = bshapes
    out["batch_shardings"] = ns(bspecs)
    if cell.kind == "train" and with_opt:
        out["opt_structs"] = jax.eval_shape(init_opt_state, pstructs)
        ospec = opt_state_spec(pspec)
        ospec["m"] = _apply_zero(ospec["m"], pstructs, mesh)
        ospec["v"] = _apply_zero(ospec["v"], pstructs, mesh)
        out["opt_shardings"] = ns(ospec)
    if cell.kind in ("decode", "prefill"):
        seq_shard = _batch_spec(mesh, cell.global_batch) == P()
        sspec = decode_state_spec(cfg, seq_shard=seq_shard)
        out["state_structs"] = decode_state_structs(cfg, cell)
        out["state_shardings"] = ns(sspec)
    return out
