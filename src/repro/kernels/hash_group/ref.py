"""Pure-jnp oracle for the hash_group kernel (segment-sum semantics)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def hash_group_ref(gid, vals, g_pad):
    """gid: (1, n) int32; vals: (V, n) f32 -> (g_pad, V) f32.

    Equivalent to jax.ops.segment_sum of vals.T by gid."""
    seg = jax.ops.segment_sum(vals.T, gid[0], num_segments=g_pad)
    return seg.astype(jnp.float32)
