"""Jit'd wrapper for hash_group: padding + multi-aggregate assembly.

``grouped_aggregate`` computes sum/count (and via sum-of-ones, mean) for V
value columns over dense group ids in one kernel launch.  min/max fall back
to the executor's segment path (they are not onehot-matmul shaped).
"""

from __future__ import annotations

import numpy as np

from .hash_group import hash_group_call

MAX_DENSE_GROUPS = 4096


def _pad8(x: int) -> int:
    return -(-x // 8) * 8


def grouped_aggregate(gid: np.ndarray, vals: np.ndarray, n_groups: int,
                      mask: np.ndarray | None = None,
                      block_rows: int = 2048, interpret: bool = True,
                      use_pallas: bool = True) -> np.ndarray:
    """gid: (n,) int; vals: (V, n) float; returns (n_groups, V+1) float64 —
    per-group sums for each value column plus the group count in the last
    column."""
    V, n = vals.shape
    g_pad = _pad8(max(n_groups + 1, 8))        # +1 trash group
    n_pad = -(-max(n, 1) // block_rows) * block_rows
    Vp = _pad8(V + 1)                           # +1 ones column for counts

    g = np.full(n_pad, g_pad - 1, dtype=np.int32)
    gg = gid.astype(np.int32)
    if mask is not None:
        gg = np.where(mask, gg, g_pad - 1)
    g[:n] = gg

    v = np.zeros((Vp, n_pad), dtype=np.float32)
    v[:V, :n] = vals.astype(np.float32)
    v[V, :n] = 1.0                              # count column

    if use_pallas:
        import jax.numpy as jnp
        acc = hash_group_call(jnp.asarray(g[None, :]), jnp.asarray(v),
                              g_pad, block_rows=block_rows,
                              interpret=interpret)
        acc = np.asarray(acc, dtype=np.float64)
    else:
        acc = np.zeros((g_pad, Vp), dtype=np.float64)
        np.add.at(acc, g, v.T.astype(np.float64))
    return acc[:n_groups, :V + 1]
