"""Pallas TPU kernel: dense-domain grouped aggregation.

MonetDB auto-builds hash tables for GROUP BY (paper §3.1).  Pointer-chasing
hash tables are hostile to the TPU's vector/matrix units, so the TPU-native
equivalent (DESIGN.md §3) turns grouped aggregation into a *one-hot matmul*:

    acc[g, v] += Σ_rows onehot(gid)[row, g] · vals[row, v]

which the MXU executes as a (G × B) @ (B × V) product per tile — grouped
aggregation at matmul throughput, no scatter.  Valid for dense group ids
with G ≤ ~4096 (beyond that the executor falls back to segment-sum).

Accumulation uses the standard Pallas revisiting-output pattern: every grid
step maps to the same (G, V) output block, initialized at step 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_group_kernel(gid_ref, vals_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    gid = gid_ref[0, :]                                  # (B,) int32
    vals = vals_ref[...]                                 # (V, B) f32
    G = out_ref.shape[0]
    groups = jax.lax.broadcasted_iota(jnp.int32, (G, gid.shape[0]), 0)
    onehot = (groups == gid[None, :]).astype(jnp.float32)   # (G, B)
    out_ref[...] += jnp.dot(onehot, vals.T,
                            preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("g_pad", "block_rows",
                                             "interpret"))
def hash_group_call(gid: jax.Array, vals: jax.Array, g_pad: int, *,
                    block_rows: int = 2048, interpret: bool = True):
    """gid: (1, n) int32 — masked-out rows carry a trash group id that lands
    in a padding row (callers use g_pad - 1); vals: (V, n) f32 with V padded
    to the f32 sublane multiple.  g_pad is the padded group-domain size.
    Returns the (g_pad, V) f32 accumulator."""
    _, n = gid.shape
    V, n2 = vals.shape
    assert n == n2 and n % block_rows == 0, (n, n2, block_rows)
    steps = n // block_rows
    return pl.pallas_call(
        _hash_group_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, block_rows), lambda i: (0, i)),
            pl.BlockSpec((V, block_rows), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((g_pad, V), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((g_pad, V), jnp.float32),
        interpret=interpret,
    )(gid, vals)
