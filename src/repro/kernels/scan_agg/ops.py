"""Jit'd wrapper for scan_agg: padding, dispatch, partial merge.

``fused_filter_agg`` is what the query executor calls for qualifying
filter->aggregate plans (no GROUP BY or dense small groups handled by
hash_group): it pads the columns to tile shape, invokes the kernel, and
merges the per-step partials (the "merge" node of paper Fig. 2).
"""

from __future__ import annotations

import numpy as np

from .scan_agg import scan_agg_pallas

_NEG = np.float32(-3.0e38)
_WIDE = np.float32(3.0e38)


def fused_filter_agg(cols: np.ndarray, ranges: np.ndarray,
                     pairs: tuple[tuple[int, int], ...],
                     block_rows: int = 8192, interpret: bool = True,
                     use_pallas: bool = True) -> np.ndarray:
    """cols: (C, n) float; ranges: (C, 2); returns (P+1,) float64 —
    one sum per pair plus the selected count.

    Layout sent to the kernel: columns padded to the f32 sublane multiple,
    with column C a synthetic *validity column* (1.0 for real rows, -3e38
    for padding rows, range [0, 2]) so row padding can never leak into the
    aggregates regardless of the user's filter ranges."""
    C, n = cols.shape
    Cp = -(-(C + 1) // 8) * 8
    npad = -(-max(n, 1) // block_rows) * block_rows

    cp = np.zeros((Cp, npad), dtype=np.float32)
    cp[:C, :n] = cols.astype(np.float32)
    cp[C, :n] = 1.0                      # validity column
    cp[C, n:] = _NEG

    rp = np.zeros((Cp, 2), dtype=np.float32)
    rp[:, 0], rp[:, 1] = -_WIDE, _WIDE   # pad columns: always in range
    rr = ranges.astype(np.float32)
    rp[:C, 0] = np.maximum(rr[:, 0], -_WIDE)
    rp[:C, 1] = np.minimum(rr[:, 1], _WIDE)
    rp[C] = (0.0, 2.0)                   # validity range
    if use_pallas:
        import jax.numpy as jnp
        parts = scan_agg_pallas(jnp.asarray(cp), jnp.asarray(rp),
                                pairs=tuple(pairs), block_rows=block_rows,
                                interpret=interpret)
        merged = np.asarray(parts, dtype=np.float64).sum(axis=0)
        return merged[:len(pairs) + 1]
    # host mirror (numpy, same math)
    ok = np.all((cp >= rp[:, 0:1]) & (cp <= rp[:, 1:2]), axis=0)
    okf = ok.astype(np.float64)
    outs = []
    for a, b in pairs:
        v = cp[a].astype(np.float64)
        if b >= 0:
            v = v * cp[b].astype(np.float64)
        outs.append(float((v * okf).sum()))
    outs.append(float(okf.sum()))
    return np.asarray(outs)
