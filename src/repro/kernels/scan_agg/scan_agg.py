"""Pallas TPU kernel: fused selection + multi-aggregate scan.

This is the engine's hottest loop (paper Table 1: TPC-H Q1/Q6 are
filter->aggregate scans).  A naive column-at-a-time plan reads each column
from HBM once per operator; this kernel performs the *entire*
filter + k-aggregate pipeline in a single HBM pass, accumulating partials in
VMEM.

Contract (see ops.py for the user-facing wrapper):

  cols:   (C, n) f32 — C input columns, tightly packed (sublane-padded)
  ranges: (C, 2) f32 — per-column [lo, hi] selection range; non-filter
          columns get (-inf, +inf).  Mask = AND over all columns in range.
  pairs:  static tuple of (a, b) column-index pairs; aggregate p sums
          cols[a]*cols[b] over selected rows (b == -1 means cols[a] alone).
  out:    (n_steps, 128) f32 — per-grid-step partials; lane p holds
          aggregate p, lane P holds the selected-row count.  Final reduce is
          a tiny jnp sum in ops.py (the merge step of the paper's Fig. 2).

Tiling: each grid step loads a (C_pad, B) tile; B = 8·1024 rows keeps a
6-column tile at 6·32 KiB = 192 KiB of VMEM.  The multiply-accumulate runs
on the VPU; there is no MXU work, so the kernel is purely HBM-bound — which
is the roofline the fusion is attacking.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _scan_agg_kernel(pairs, cols_ref, ranges_ref, out_ref):
    x = cols_ref[...]                                   # (C, B)
    lo = ranges_ref[:, 0:1]                             # (C, 1)
    hi = ranges_ref[:, 1:2]
    ok = jnp.all((x >= lo) & (x <= hi), axis=0)         # (B,)
    okf = ok.astype(jnp.float32)
    acc = []
    for a, b in pairs:
        v = x[a] if b < 0 else x[a] * x[b]
        acc.append(jnp.sum(v * okf))
    acc.append(jnp.sum(okf))                            # count
    vec = jnp.zeros((LANES,), jnp.float32)
    vec = vec.at[:len(acc)].set(jnp.stack(acc))
    out_ref[0, :] = vec


@functools.partial(jax.jit,
                   static_argnames=("pairs", "block_rows", "interpret"))
def scan_agg_pallas(cols: jax.Array, ranges: jax.Array, *,
                    pairs: tuple[tuple[int, int], ...],
                    block_rows: int = 8192, interpret: bool = True):
    """cols: (C, n) f32 with n % block_rows == 0 and C % 8 == 0 (pre-padded,
    padding rows carry values outside every range).  Returns (n_steps, 128)
    partials."""
    C, n = cols.shape
    assert n % block_rows == 0 and C % 8 == 0
    assert len(pairs) + 1 <= LANES
    steps = n // block_rows
    kern = functools.partial(_scan_agg_kernel, tuple(pairs))
    return pl.pallas_call(
        kern,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((C, block_rows), lambda i: (0, i)),
            pl.BlockSpec((C, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((steps, LANES), jnp.float32),
        interpret=interpret,
    )(cols, ranges)
