"""Pure-jnp oracle for the fused scan_agg kernel."""

from __future__ import annotations

import jax.numpy as jnp


def scan_agg_ref(cols, ranges, *, pairs):
    """cols: (C, n) f32; ranges: (C, 2).  Returns (P+1,) sums + count —
    i.e. the already-merged equivalent of the kernel's per-step partials."""
    lo = ranges[:, 0:1]
    hi = ranges[:, 1:2]
    ok = jnp.all((cols >= lo) & (cols <= hi), axis=0)
    okf = ok.astype(jnp.float32)
    outs = []
    for a, b in pairs:
        v = cols[a] if b < 0 else cols[a] * cols[b]
        outs.append(jnp.sum(v * okf))
    outs.append(jnp.sum(okf))
    return jnp.stack(outs)
