"""Jit'd wrapper + host-tier mirror for the imprint kernel.

``build_zone_maps`` is the engine entry point (indexes.py).  On the host
tier (CPU container) it uses the vectorized numpy mirror; the Pallas path
(`build_zone_maps_pallas`) is the TPU-target implementation, validated in
interpret mode by tests/test_kernels_imprint.py against ref.py.
"""

from __future__ import annotations

import numpy as np

from .imprint import G_BLOCKS, zone_maps_pallas


def _prepare(values: np.ndarray, nulls: np.ndarray, block: int):
    n = len(values)
    n_blocks = max(1, -(-n // block))
    pad_blocks = -(-n_blocks // G_BLOCKS) * G_BLOCKS
    total = pad_blocks * block
    v = np.zeros(total, dtype=np.float32)
    v[:n] = values.astype(np.float32)
    ok = np.zeros(total, dtype=np.float32)
    ok[:n] = (~nulls).astype(np.float32)
    return (v.reshape(pad_blocks, block), ok.reshape(pad_blocks, block),
            n_blocks)


def _range(values: np.ndarray, nulls: np.ndarray, nbins: int):
    ok = ~nulls
    if not ok.any():
        return 0.0, 0.0, 0.0
    lo = float(values[ok].min())
    hi = float(values[ok].max())
    inv = float(nbins / (hi - lo)) if hi > lo else 0.0
    return lo, hi, inv


def build_zone_maps(values: np.ndarray, nulls: np.ndarray,
                    block: int, nbins: int):
    """Host-tier zone maps (numpy mirror of the kernel; bit-identical
    semantics).  Returns (mins, maxs, bitmaps, lo, hi) trimmed to the real
    block count, in float64 for index precision."""
    lo, hi, inv = _range(values, nulls, nbins)
    n = len(values)
    n_blocks = max(1, -(-n // block))
    mins = np.full(n_blocks, np.inf)
    maxs = np.full(n_blocks, -np.inf)
    bitmaps = np.zeros(n_blocks, dtype=np.uint16)
    for b in range(n_blocks):
        s, e = b * block, min((b + 1) * block, n)
        v = values[s:e]
        ok = ~nulls[s:e]
        if ok.any():
            vv = v[ok]
            mins[b] = vv.min()
            maxs[b] = vv.max()
            if inv > 0:
                bins = np.clip(((vv - lo) * inv).astype(np.int64),
                               0, nbins - 1)
                bitmaps[b] = np.bitwise_or.reduce(
                    (1 << bins).astype(np.uint16))
            else:
                bitmaps[b] = 1
    return mins, maxs, bitmaps, lo, hi


def build_zone_maps_pallas(values: np.ndarray, nulls: np.ndarray,
                           block: int, nbins: int, interpret: bool = True):
    """Device-tier zone maps through the Pallas kernel.  Same contract as
    build_zone_maps (float32 bounds; callers widen conservatively)."""
    import jax.numpy as jnp
    lo, hi, inv = _range(values, nulls, nbins)
    v2d, ok2d, n_blocks = _prepare(values, nulls, block)
    rng = jnp.asarray([[lo, inv]], dtype=jnp.float32)
    mins, maxs, bm = zone_maps_pallas(
        jnp.asarray(v2d), jnp.asarray(ok2d), rng,
        block_rows=block, nbins=nbins, interpret=interpret)
    mins = np.asarray(mins)[:n_blocks].astype(np.float64)
    maxs = np.asarray(maxs)[:n_blocks].astype(np.float64)
    bm = np.asarray(bm)[:n_blocks].astype(np.uint16)
    empty = mins > maxs
    mins[empty], maxs[empty] = np.inf, -np.inf
    # float32 rounding could shrink the true bounds: widen by one ulp so the
    # zone test never mis-prunes.
    mins = np.nextafter(mins.astype(np.float32), -np.inf).astype(np.float64)
    maxs = np.nextafter(maxs.astype(np.float32), np.inf).astype(np.float64)
    mins[empty], maxs[empty] = np.inf, -np.inf
    return mins, maxs, bm, lo, hi
