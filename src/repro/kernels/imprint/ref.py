"""Pure-jnp oracle for the imprint (zone map) kernel."""

from __future__ import annotations

import jax.numpy as jnp


def zone_maps_ref(vals, valid, rng, *, nbins: int = 16):
    """vals/valid: (n_blocks, block_rows) f32; rng: (1,2) = (lo, nbins/(hi-lo)).

    Returns (mins, maxs, bitmaps) matching imprint.zone_maps_pallas."""
    ok = valid > 0
    big = jnp.float32(3.4e38)
    mins = jnp.min(jnp.where(ok, vals, big), axis=1)
    maxs = jnp.max(jnp.where(ok, vals, -big), axis=1)
    lo, inv = rng[0, 0], rng[0, 1]
    binned = jnp.clip((vals - lo) * inv, 0, nbins - 1).astype(jnp.int32)
    bm = jnp.zeros(vals.shape[0], dtype=jnp.int32)
    for b in range(nbins):
        present = jnp.any(ok & (binned == b), axis=1)
        bm = bm | (present.astype(jnp.int32) << b)
    return mins, maxs, bm
