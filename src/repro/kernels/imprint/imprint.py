"""Pallas TPU kernel: imprint (zone map) construction.

MonetDB's imprints are per-cache-line min/max bitmaps (Sidirourgos &
Kersten, SIGMOD'13; paper §3.1).  The TPU adaptation builds zone maps at
VMEM-block granularity: for every block of ``block_rows`` rows we emit

    min, max, and a 16-bin presence bitmap over the global value range.

Tiling: each grid step loads a ``(G, block_rows)`` tile of values (plus a
validity tile) into VMEM — G zone blocks per step, laid out so the reduction
runs along lanes.  With G=8 and block_rows=2048 a step works on a
(8, 2048) f32 tile = 64 KiB of VMEM per operand, well inside v5e VMEM, and
the per-step output is an (8,) vector per statistic.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

G_BLOCKS = 8          # zone blocks per grid step (sublane dim, f32 tile = 8)


def _zone_kernel(nbins: int, vals_ref, valid_ref, rng_ref,
                 mins_ref, maxs_ref, bm_ref):
    v = vals_ref[...]                        # (G, B) f32
    ok = valid_ref[...] > 0                  # (G, B)
    big = jnp.float32(3.4e38)
    vmin = jnp.min(jnp.where(ok, v, big), axis=1)       # (G,)
    vmax = jnp.max(jnp.where(ok, v, -big), axis=1)
    mins_ref[...] = vmin
    maxs_ref[...] = vmax
    lo = rng_ref[0, 0]
    inv = rng_ref[0, 1]                       # nbins / (hi - lo), 0 if empty
    binned = jnp.clip((v - lo) * inv, 0, nbins - 1).astype(jnp.int32)
    bm = jnp.zeros((v.shape[0],), dtype=jnp.int32)
    for b in range(nbins):                    # static unroll (nbins = 16)
        present = jnp.any(ok & (binned == b), axis=1)
        bm = bm | (present.astype(jnp.int32) << b)
    bm_ref[...] = bm


@functools.partial(jax.jit, static_argnames=("block_rows", "nbins",
                                             "interpret"))
def zone_maps_pallas(vals: jax.Array, valid: jax.Array, rng: jax.Array,
                     *, block_rows: int = 2048, nbins: int = 16,
                     interpret: bool = True):
    """vals/valid: (n_blocks, block_rows) f32 (pre-padded); rng: (1, 2) f32
    holding (lo, nbins/(hi-lo)).  Returns (mins, maxs, bitmaps)."""
    n_blocks = vals.shape[0]
    assert n_blocks % G_BLOCKS == 0, "pad n_blocks to a multiple of G_BLOCKS"
    grid = (n_blocks // G_BLOCKS,)
    kern = functools.partial(_zone_kernel, nbins)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((G_BLOCKS, block_rows), lambda i: (i, 0)),
            pl.BlockSpec((G_BLOCKS, block_rows), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((G_BLOCKS,), lambda i: (i,)),
            pl.BlockSpec((G_BLOCKS,), lambda i: (i,)),
            pl.BlockSpec((G_BLOCKS,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
            jax.ShapeDtypeStruct((n_blocks,), jnp.int32),
        ],
        interpret=interpret,
    )(vals, valid, rng)
