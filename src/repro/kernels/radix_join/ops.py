"""Dispatch shim for radix_join: partitioning + padding + assembly.

``radix_join`` joins integer probe keys against *unique* integer build keys
and gathers the build payload for every matching probe row.  The radix
partitioning (bucket = low key bits) happens at this layer: each bucket's
local key domain is ``domain / n_buckets``-sized, so the dense partition
tables the Pallas kernels operate on stay VMEM-tileable no matter how large
the global key domain is.  ``use_pallas=False`` runs a numpy mirror of the
identical partition/build/probe plan — the differential tests pin the two
paths against each other and against ``ref.radix_join_ref``.
"""

from __future__ import annotations

import numpy as np

from .radix_join import radix_build_call, radix_probe_call


def _pad8(x: int) -> int:
    return -(-x // 8) * 8


def _pad_to(n: int, block: int) -> int:
    return -(-max(n, 1) // block) * block


def radix_join(build_keys: np.ndarray, build_vals: np.ndarray,
               probe_keys: np.ndarray, *, n_bits: int = 4,
               block_rows: int = 2048, interpret: bool = True,
               use_pallas: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """build_keys: (nb,) int, unique; build_vals: (V, nb) float;
    probe_keys: (np,) int.  Returns ``(matched, gathered)`` where
    ``matched`` is the (np,) bool inner-join bit and ``gathered`` the
    (np, V) float64 build payload (zeros on misses), in probe order."""
    build_keys = np.asarray(build_keys, dtype=np.int64)
    probe_keys = np.asarray(probe_keys, dtype=np.int64)
    V, nb = build_vals.shape
    assert nb == build_keys.shape[0]
    n_parts = 1 << n_bits
    mask = n_parts - 1
    lo = int(min(build_keys.min(initial=0), probe_keys.min(initial=0)))
    bk = build_keys - lo
    pk = probe_keys - lo
    hi = int(max(bk.max(initial=0), pk.max(initial=0)))
    # bucket on the low bits; the local code is the high bits, so every
    # partition's dense domain is domain >> n_bits
    d_local = (hi >> n_bits) + 1
    d_pad = _pad8(d_local + 1)                  # +1 trash row
    Vp = _pad8(V + 1)                           # +1 presence lane

    b_bucket = (bk & mask).astype(np.int64)
    p_bucket = (pk & mask).astype(np.int64)
    b_order = np.argsort(b_bucket, kind="stable")
    p_order = np.argsort(p_bucket, kind="stable")
    b_counts = np.bincount(b_bucket, minlength=n_parts)
    p_counts = np.bincount(p_bucket, minlength=n_parts)
    b_starts = np.concatenate([[0], np.cumsum(b_counts)])
    p_starts = np.concatenate([[0], np.cumsum(p_counts)])

    matched = np.zeros(probe_keys.shape[0], dtype=bool)
    gathered = np.zeros((probe_keys.shape[0], V), dtype=np.float64)
    if use_pallas:
        import jax.numpy as jnp
    for p in range(n_parts):
        bi = b_order[b_starts[p]:b_starts[p + 1]]
        pi = p_order[p_starts[p]:p_starts[p + 1]]
        if pi.size == 0 or bi.size == 0:
            continue
        b_code = (bk[bi] >> n_bits).astype(np.int32)
        p_code = (pk[pi] >> n_bits).astype(np.int32)
        nbp = _pad_to(bi.size, block_rows)
        npp = _pad_to(pi.size, block_rows)
        bc = np.full(nbp, d_pad - 1, dtype=np.int32)
        bc[:bi.size] = b_code
        bv = np.zeros((Vp, nbp), dtype=np.float32)
        bv[0, :bi.size] = 1.0                   # presence lane
        bv[1:V + 1, :bi.size] = build_vals[:, bi].astype(np.float32)
        pc = np.full(npp, d_pad - 1, dtype=np.int32)
        pc[:pi.size] = p_code
        if use_pallas:
            btab = radix_build_call(jnp.asarray(bc[None, :]),
                                    jnp.asarray(bv), d_pad,
                                    block_rows=block_rows,
                                    interpret=interpret)
            btab = np.array(btab)
            btab[d_pad - 1, :] = 0.0            # trash row never matches
            out = radix_probe_call(jnp.asarray(pc[None, :]),
                                   jnp.asarray(btab),
                                   block_rows=block_rows,
                                   interpret=interpret)
            out = np.asarray(out, dtype=np.float64)
        else:
            btab = np.zeros((d_pad, Vp), dtype=np.float64)
            np.add.at(btab, bc, bv.T.astype(np.float64))
            btab[d_pad - 1, :] = 0.0
            out = btab[pc]
        matched[pi] = out[:pi.size, 0] > 0
        gathered[pi] = out[:pi.size, 1:V + 1]
    return matched, gathered
