"""Pure-jnp oracle for the radix_join kernels (dense scatter/gather
semantics, no partitioning)."""

from __future__ import annotations

import jax.numpy as jnp


def radix_join_ref(build_keys, build_vals, probe_keys, domain: int):
    """build_keys: (nb,) int with unique values in [0, domain);
    build_vals: (V, nb) float; probe_keys: (np,) int.  Returns
    ``(matched, gathered)`` — the un-partitioned equivalent of
    ``ops.radix_join``: one dense (domain, V+1) table, scatter then
    gather."""
    V = build_vals.shape[0]
    tab = jnp.zeros((domain + 1, V + 1), dtype=jnp.float64)
    bk = jnp.clip(build_keys, 0, domain)
    row = jnp.concatenate(
        [jnp.ones((1, bk.shape[0])), build_vals.astype(jnp.float64)], axis=0)
    tab = tab.at[bk].add(row.T)
    pk = jnp.clip(probe_keys, 0, domain)
    out = tab[pk]
    ok = (probe_keys >= 0) & (probe_keys < domain)
    matched = (out[:, 0] > 0) & ok
    gathered = jnp.where(matched[:, None], out[:, 1:], 0.0)
    return matched, gathered
