"""Pallas TPU kernels: radix-partitioned hash join.

MonetDB's join auto-builds a hash table on the smaller input and probes it
with the larger one (paper §3.1).  Pointer-chasing hash tables are hostile
to the TPU's vector/matrix units, so the TPU-native restatement (DESIGN.md
§3) follows the same move as ``hash_group``: radix-partition both inputs on
the low key bits so each partition's *local* key domain is small enough to
tile in VMEM, then express the partition-local hash table as a dense
(D, V) matrix and lower both halves of the join to one-hot matmuls:

    build:  btab[d, v]  = Σ_rows onehot(code)[row, d] · payload[row, v]
    probe:  out[row, v] = Σ_d    onehot(code)[row, d] · btab[d, v]

The build is a scatter-by-matmul (identical shape to grouped aggregation —
the MXU executes a (D × B) @ (B × V) product per tile); the probe is a
gather-by-matmul ((B × D) @ (D × V)).  Slot 0 of the payload carries the
build-side presence count, so a probe row's gathered count > 0 *is* the
inner-join match bit and the remaining lanes are the joined build columns —
build + probe of one partition is a fused pair of matmul kernels with no
per-row control flow.

Valid for unique build keys (the engine's device join verifies uniqueness
and falls back otherwise); partitioning keeps D ≈ domain / n_partitions so
a few-thousand-row tile fits VMEM even for large key domains.

Accumulation uses the standard Pallas revisiting-output pattern on the
build side: every grid step maps to the same (D, V) output block,
initialized at step 0.  The probe side writes disjoint (B, V) blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _radix_build_kernel(code_ref, vals_ref, out_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    code = code_ref[0, :]                                # (B,) int32
    vals = vals_ref[...]                                 # (V, B) f32
    D = out_ref.shape[0]
    doms = jax.lax.broadcasted_iota(jnp.int32, (D, code.shape[0]), 0)
    onehot = (doms == code[None, :]).astype(jnp.float32)    # (D, B)
    out_ref[...] += jnp.dot(onehot, vals.T,
                            preferred_element_type=jnp.float32)


def _radix_probe_kernel(code_ref, btab_ref, out_ref):
    code = code_ref[0, :]                                # (B,) int32
    btab = btab_ref[...]                                 # (D, V) f32
    D = btab.shape[0]
    doms = jax.lax.broadcasted_iota(jnp.int32, (code.shape[0], D), 1)
    onehot = (doms == code[:, None]).astype(jnp.float32)    # (B, D)
    out_ref[...] = jnp.dot(onehot, btab,
                           preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("d_pad", "block_rows",
                                             "interpret"))
def radix_build_call(code: jax.Array, vals: jax.Array, d_pad: int, *,
                     block_rows: int = 2048, interpret: bool = True):
    """code: (1, n) int32 partition-local key codes — masked-out rows carry
    a trash code that lands in a padding row (callers use d_pad - 1); vals:
    (V, n) f32 payload with V padded to the f32 sublane multiple and lane 0
    holding the presence indicator.  Returns the (d_pad, V) f32 dense
    partition-local hash table."""
    _, n = code.shape
    V, n2 = vals.shape
    assert n == n2 and n % block_rows == 0, (n, n2, block_rows)
    steps = n // block_rows
    return pl.pallas_call(
        _radix_build_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, block_rows), lambda i: (0, i)),
            pl.BlockSpec((V, block_rows), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((d_pad, V), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((d_pad, V), jnp.float32),
        interpret=interpret,
    )(code, vals)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def radix_probe_call(code: jax.Array, btab: jax.Array, *,
                     block_rows: int = 2048, interpret: bool = True):
    """code: (1, n) int32 partition-local probe codes (trash code = the
    padding row, whose presence count is 0, so padded probes simply miss);
    btab: (D, V) f32 build table from ``radix_build_call``.  Returns the
    (n, V) f32 gathered payload; lane 0 > 0 marks an inner-join match."""
    _, n = code.shape
    D, V = btab.shape
    assert n % block_rows == 0, (n, block_rows)
    steps = n // block_rows
    return pl.pallas_call(
        _radix_probe_kernel,
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((1, block_rows), lambda i: (0, i)),
            pl.BlockSpec((D, V), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, V), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, V), jnp.float32),
        interpret=interpret,
    )(code, btab)
