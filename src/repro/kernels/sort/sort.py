"""Pallas TPU kernel: bitonic sort over a column block.

MonetDB's ORDER BY sorts a column and applies the permutation to the
others.  Comparison sorts with data-dependent control flow don't map to
the TPU's vector units, so the TPU-native restatement (DESIGN.md §3) is
the classic *bitonic network*: a fixed, data-independent sequence of
compare-exchange stages — for a 2^k block, k·(k+1)/2 stages of purely
element-wise min/max/select over lane-aligned halves, every one of which
the VPU executes at full width.  The partner of lane ``i`` at substage
``j`` is ``i ^ j``; because ``j`` is a power of two that exchange is a
reshape + flip, not a gather.

The kernel sorts (key, index) pairs: ties break on the original index,
which makes the network's output *identical* to a stable sort of the keys
— so the host oracle is ``np.argsort(kind="stable")`` and the permutation
can re-order payload columns exactly like MonetDB's tail projection.

One grid step sorts one block; block-local sorts are merged by the ops
shim (or consumed directly for top-N, where only the block prefix
survives).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cswap(k, ix, j: int, ksz: int):
    """One bitonic compare-exchange substage over (key, index) lanes."""
    n = k.shape[0]
    i = jax.lax.broadcasted_iota(jnp.int32, (n,), 0)
    kp = k.reshape(n // (2 * j), 2, j)[:, ::-1, :].reshape(n)
    ip = ix.reshape(n // (2 * j), 2, j)[:, ::-1, :].reshape(n)
    # ascending run when bit ksz is clear; lane keeps the smaller pair
    # member when its side matches the run direction
    keep_min = ((i & ksz) == 0) == ((i & j) == 0)
    partner_lt = (kp < k) | ((kp == k) & (ip < ix))      # stable tie-break
    take_partner = keep_min == partner_lt
    return (jnp.where(take_partner, kp, k),
            jnp.where(take_partner, ip, ix))


def _bitonic_kernel(keys_ref, idx_ref, out_k_ref, out_i_ref):
    k = keys_ref[0, :]
    ix = idx_ref[0, :]
    n = k.shape[0]
    ksz = 2
    while ksz <= n:                      # static: unrolled at trace time
        j = ksz // 2
        while j >= 1:
            k, ix = _cswap(k, ix, j, ksz)
            j //= 2
        ksz *= 2
    out_k_ref[0, :] = k
    out_i_ref[0, :] = ix


@functools.partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort_call(keys: jax.Array, idx: jax.Array, *,
                      interpret: bool = True):
    """keys: (1, n) f32 with n a power of two (callers pad with +inf);
    idx: (1, n) int32 original positions.  Returns (sorted keys, perm),
    ascending, ties broken by original position (= stable)."""
    _, n = keys.shape
    assert n & (n - 1) == 0, n
    return pl.pallas_call(
        _bitonic_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
        ],
        interpret=interpret,
    )(keys, idx)
