"""Pure-jnp oracle for the bitonic sort kernel (stable argsort
semantics)."""

from __future__ import annotations

import jax.numpy as jnp


def bitonic_sort_ref(keys, idx):
    """keys: (1, n) f32; idx: (1, n) int32 -> (sorted keys, perm).

    Equivalent to a stable ascending sort of (key, original index) pairs —
    what the tie-broken bitonic network computes."""
    perm = jnp.lexsort((idx[0], keys[0]))
    return keys[:, perm], idx[:, perm]
