"""Dispatch shim for the sort kernels: padding + multi-key dispatch.

Two entry points:

* ``sort_block`` — single-key block sort through the Pallas bitonic
  network (``use_pallas=False`` mirrors it with a stable numpy argsort,
  which the tie-broken network is exactly equivalent to);
* ``lexsort_indices`` — the multi-key permutation the engine's
  device-sort tier dispatches: a jitted ``jnp.lexsort`` over float sort
  keys (NULLs pushed to +inf, descending keys negated — the same key
  transform as the host executor's ``_sort_key_float``), optionally
  sliced to a fused top-N.  Jitted closures are memoized per
  (n_keys, limit) so repeated ORDER BY queries don't re-trace.
"""

from __future__ import annotations

import threading

import numpy as np

from .sort import bitonic_sort_call


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def sort_block(keys: np.ndarray, *, interpret: bool = True,
               use_pallas: bool = True) -> tuple[np.ndarray, np.ndarray]:
    """keys: (n,) float.  Returns ``(sorted, perm)`` ascending with NaNs
    last; ``perm`` is the stable argsort permutation."""
    k = np.asarray(keys, dtype=np.float32)
    n = k.shape[0]
    if not use_pallas:
        kk = np.where(np.isnan(k), np.float32(np.inf), k)
        perm = np.argsort(kk, kind="stable")
        return kk[perm], perm
    n_pad = _next_pow2(max(n, 2))
    kp = np.full(n_pad, np.inf, dtype=np.float32)
    kp[:n] = np.where(np.isnan(k), np.float32(np.inf), k)
    ix = np.arange(n_pad, dtype=np.int32)
    import jax.numpy as jnp
    sk, si = bitonic_sort_call(jnp.asarray(kp[None, :]),
                               jnp.asarray(ix[None, :]),
                               interpret=interpret)
    return np.asarray(sk[0, :n]), np.asarray(si[0, :n])


# memoized jitted lexsort closures — shared across queries/threads
_PERM_CACHE: dict = {}
_PERM_CACHE_LOCK = threading.Lock()


def _lexsort_fn(n_keys: int, limit):
    with _PERM_CACHE_LOCK:
        fn = _PERM_CACHE.get((n_keys, limit))
        if fn is None:
            import jax
            import jax.numpy as jnp

            def perm_fn(*fkeys):
                # np.lexsort semantics: the LAST key is primary, so the
                # caller's primary-first order is reversed here
                idx = jnp.lexsort(tuple(reversed(fkeys)))
                return idx if limit is None else idx[:limit]

            fn = jax.jit(perm_fn)
            _PERM_CACHE[(n_keys, limit)] = fn
        return fn


def lexsort_indices(fkeys, limit=None, *, use_device: bool = True):
    """fkeys: primary-first list of (n,) float64 sort keys (already
    NULL-masked/negated).  Returns the (limit or n,) row permutation —
    ``np.lexsort``-identical (both paths are stable lexicographic)."""
    if not use_device:
        idx = np.lexsort(tuple(reversed([np.asarray(k) for k in fkeys])))
        return idx if limit is None else idx[:limit]
    fn = _lexsort_fn(len(fkeys), limit)
    return np.asarray(fn(*fkeys))
