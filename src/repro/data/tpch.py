"""TPC-H dbgen-lite: synthetic generator for the paper's benchmark schema.

Generates all 8 TPC-H tables at a given scale factor with dbgen-like
cardinalities and value domains (uniform approximations of dbgen's
distributions — the benchmark exercises the same operator mix).  Used by
benchmarks/bench_tpch.py (paper Table 1), bench_ingest (Fig. 5) and
bench_export (Fig. 6).
"""

from __future__ import annotations

import numpy as np

from ..core.types import DBType, date_from_string

SF_ROWS = {
    "lineitem": 6_000_000,
    "orders": 1_500_000,
    "customer": 150_000,
    "part": 200_000,
    "supplier": 10_000,
    "partsupp": 800_000,
    "nation": 25,
    "region": 5,
}

NATIONS = ["ALGERIA", "ARGENTINA", "BRAZIL", "CANADA", "EGYPT", "ETHIOPIA",
           "FRANCE", "GERMANY", "INDIA", "INDONESIA", "IRAN", "IRAQ",
           "JAPAN", "JORDAN", "KENYA", "MOROCCO", "MOZAMBIQUE", "PERU",
           "CHINA", "ROMANIA", "SAUDI ARABIA", "VIETNAM", "RUSSIA",
           "UNITED KINGDOM", "UNITED STATES"]
REGIONS = ["AFRICA", "AMERICA", "ASIA", "EUROPE", "MIDDLE EAST"]
NATION_REGION = [0, 1, 1, 1, 4, 0, 3, 3, 2, 2, 4, 4, 2, 4, 0, 0, 0, 1, 2,
                 3, 4, 2, 3, 3, 1]
SEGMENTS = ["AUTOMOBILE", "BUILDING", "FURNITURE", "HOUSEHOLD", "MACHINERY"]
PRIORITIES = ["1-URGENT", "2-HIGH", "3-MEDIUM", "4-NOT SPECIFIED", "5-LOW"]
SHIPMODES = ["AIR", "FOB", "MAIL", "RAIL", "REG AIR", "SHIP", "TRUCK"]
INSTRUCTS = ["COLLECT COD", "DELIVER IN PERSON", "NONE", "TAKE BACK RETURN"]
TYPES = [f"{a} {b} {c}" for a in ("ECONOMY", "LARGE", "MEDIUM", "PROMO",
                                  "SMALL", "STANDARD")
         for b in ("ANODIZED", "BRUSHED", "BURNISHED", "PLATED", "POLISHED")
         for c in ("BRASS", "COPPER", "NICKEL", "STEEL", "TIN")]
CONTAINERS = [f"{a} {b}" for a in ("JUMBO", "LG", "MED", "SM", "WRAP")
              for b in ("BAG", "BOX", "CAN", "CASE", "DRUM", "JAR", "PACK",
                        "PKG")]
BRANDS = [f"Brand#{i}{j}" for i in range(1, 6) for j in range(1, 6)]

DATE0 = int(date_from_string("1992-01-01"))
DATE1 = int(date_from_string("1998-08-02"))


def _pick(rng, options, n):
    return np.asarray(options, dtype=object)[rng.integers(0, len(options), n)]


def generate(sf: float = 0.01, seed: int = 7) -> dict[str, dict]:
    """Returns {table: (columns dict, types dict, scales dict)}."""
    rng = np.random.default_rng(seed)
    n_li = max(100, int(SF_ROWS["lineitem"] * sf))
    n_or = max(25, int(SF_ROWS["orders"] * sf))
    n_cu = max(10, int(SF_ROWS["customer"] * sf))
    n_pa = max(10, int(SF_ROWS["part"] * sf))
    n_su = max(5, int(SF_ROWS["supplier"] * sf))
    n_ps = max(20, int(SF_ROWS["partsupp"] * sf))

    D = DBType
    out = {}

    out["region"] = ({
        "r_regionkey": np.arange(5, dtype=np.int64),
        "r_name": np.asarray(REGIONS, dtype=object),
        "r_comment": np.asarray([f"region comment {i}" for i in range(5)],
                                dtype=object),
    }, {"r_regionkey": D.INT64, "r_name": D.VARCHAR, "r_comment": D.VARCHAR},
        {})

    out["nation"] = ({
        "n_nationkey": np.arange(25, dtype=np.int64),
        "n_name": np.asarray(NATIONS, dtype=object),
        "n_regionkey": np.asarray(NATION_REGION, dtype=np.int64),
        "n_comment": np.asarray([f"nation comment {i}" for i in range(25)],
                                dtype=object),
    }, {"n_nationkey": D.INT64, "n_name": D.VARCHAR,
        "n_regionkey": D.INT64, "n_comment": D.VARCHAR}, {})

    out["supplier"] = ({
        "s_suppkey": np.arange(n_su, dtype=np.int64),
        "s_name": np.asarray([f"Supplier#{i:09d}" for i in range(n_su)],
                             dtype=object),
        "s_address": np.asarray([f"addr{i}" for i in range(n_su)],
                                dtype=object),
        "s_nationkey": rng.integers(0, 25, n_su).astype(np.int64),
        "s_phone": np.asarray([f"{rng.integers(10,35)}-{i:07d}"
                               for i in range(n_su)], dtype=object),
        "s_acctbal": np.round(rng.uniform(-999, 9999, n_su), 2),
        "s_comment": _pick(rng, ["reliable", "Customer Complaints pending",
                                 "quick", "slow"], n_su),
    }, {"s_suppkey": D.INT64, "s_name": D.VARCHAR, "s_address": D.VARCHAR,
        "s_nationkey": D.INT64, "s_phone": D.VARCHAR,
        "s_acctbal": D.DECIMAL, "s_comment": D.VARCHAR},
        {"s_acctbal": 2})

    out["customer"] = ({
        "c_custkey": np.arange(n_cu, dtype=np.int64),
        "c_name": np.asarray([f"Customer#{i:09d}" for i in range(n_cu)],
                             dtype=object),
        "c_address": np.asarray([f"caddr{i}" for i in range(n_cu)],
                                dtype=object),
        "c_nationkey": rng.integers(0, 25, n_cu).astype(np.int64),
        "c_phone": np.asarray([f"{rng.integers(10,35)}-{i:07d}"
                               for i in range(n_cu)], dtype=object),
        "c_acctbal": np.round(rng.uniform(-999, 9999, n_cu), 2),
        "c_mktsegment": _pick(rng, SEGMENTS, n_cu),
        "c_comment": _pick(rng, ["loyal", "new", "angry"], n_cu),
    }, {"c_custkey": D.INT64, "c_name": D.VARCHAR, "c_address": D.VARCHAR,
        "c_nationkey": D.INT64, "c_phone": D.VARCHAR,
        "c_acctbal": D.DECIMAL, "c_mktsegment": D.VARCHAR,
        "c_comment": D.VARCHAR}, {"c_acctbal": 2})

    out["part"] = ({
        "p_partkey": np.arange(n_pa, dtype=np.int64),
        "p_name": _pick(rng, ["ivory azure", "blanched chiffon",
                              "forest green", "ghost lavender",
                              "antique metallic"], n_pa),
        "p_mfgr": np.asarray([f"Manufacturer#{rng.integers(1,6)}"
                              for _ in range(n_pa)], dtype=object),
        "p_brand": _pick(rng, BRANDS, n_pa),
        "p_type": _pick(rng, TYPES, n_pa),
        "p_size": rng.integers(1, 51, n_pa).astype(np.int64),
        "p_container": _pick(rng, CONTAINERS, n_pa),
        "p_retailprice": np.round(rng.uniform(900, 2000, n_pa), 2),
        "p_comment": _pick(rng, ["fine", "regular", "special"], n_pa),
    }, {"p_partkey": D.INT64, "p_name": D.VARCHAR, "p_mfgr": D.VARCHAR,
        "p_brand": D.VARCHAR, "p_type": D.VARCHAR, "p_size": D.INT64,
        "p_container": D.VARCHAR, "p_retailprice": D.DECIMAL,
        "p_comment": D.VARCHAR}, {"p_retailprice": 2})

    out["partsupp"] = ({
        "ps_partkey": rng.integers(0, n_pa, n_ps).astype(np.int64),
        "ps_suppkey": rng.integers(0, n_su, n_ps).astype(np.int64),
        "ps_availqty": rng.integers(1, 10000, n_ps).astype(np.int64),
        "ps_supplycost": np.round(rng.uniform(1, 1000, n_ps), 2),
        "ps_comment": _pick(rng, ["stocked", "backordered"], n_ps),
    }, {"ps_partkey": D.INT64, "ps_suppkey": D.INT64,
        "ps_availqty": D.INT64, "ps_supplycost": D.DECIMAL,
        "ps_comment": D.VARCHAR}, {"ps_supplycost": 2})

    odate = rng.integers(DATE0, DATE1 - 151, n_or).astype(np.int32)
    out["orders"] = ({
        "o_orderkey": np.arange(n_or, dtype=np.int64),
        "o_custkey": rng.integers(0, n_cu, n_or).astype(np.int64),
        "o_orderstatus": _pick(rng, ["F", "O", "P"], n_or),
        "o_totalprice": np.round(rng.uniform(850, 500000, n_or), 2),
        "o_orderdate": odate,
        "o_orderpriority": _pick(rng, PRIORITIES, n_or),
        "o_clerk": np.asarray([f"Clerk#{rng.integers(0,1000):09d}"
                               for _ in range(n_or)], dtype=object),
        "o_shippriority": np.zeros(n_or, dtype=np.int64),
        "o_comment": _pick(rng, ["rush", "normal", "special requests"],
                           n_or),
    }, {"o_orderkey": D.INT64, "o_custkey": D.INT64,
        "o_orderstatus": D.VARCHAR, "o_totalprice": D.DECIMAL,
        "o_orderdate": D.DATE, "o_orderpriority": D.VARCHAR,
        "o_clerk": D.VARCHAR, "o_shippriority": D.INT64,
        "o_comment": D.VARCHAR}, {"o_totalprice": 2})

    okey = rng.integers(0, n_or, n_li).astype(np.int64)
    ship = odate[okey] + rng.integers(1, 122, n_li).astype(np.int32)
    commit = ship + rng.integers(-30, 31, n_li).astype(np.int32)
    receipt = ship + rng.integers(1, 31, n_li).astype(np.int32)
    out["lineitem"] = ({
        "l_orderkey": okey,
        "l_partkey": rng.integers(0, n_pa, n_li).astype(np.int64),
        "l_suppkey": rng.integers(0, n_su, n_li).astype(np.int64),
        "l_linenumber": rng.integers(1, 8, n_li).astype(np.int64),
        "l_quantity": rng.integers(1, 51, n_li).astype(np.float64),
        "l_extendedprice": np.round(rng.uniform(900, 105000, n_li), 2),
        "l_discount": np.round(rng.uniform(0.0, 0.10, n_li), 2),
        "l_tax": np.round(rng.uniform(0.0, 0.08, n_li), 2),
        "l_returnflag": _pick(rng, ["A", "N", "R"], n_li),
        "l_linestatus": _pick(rng, ["F", "O"], n_li),
        "l_shipdate": ship,
        "l_commitdate": commit,
        "l_receiptdate": receipt,
        "l_shipinstruct": _pick(rng, INSTRUCTS, n_li),
        "l_shipmode": _pick(rng, SHIPMODES, n_li),
        "l_comment": _pick(rng, ["quick", "slow", "deposits"], n_li),
    }, {"l_orderkey": D.INT64, "l_partkey": D.INT64, "l_suppkey": D.INT64,
        "l_linenumber": D.INT64, "l_quantity": D.FLOAT64,
        "l_extendedprice": D.DECIMAL, "l_discount": D.FLOAT64,
        "l_tax": D.FLOAT64, "l_returnflag": D.VARCHAR,
        "l_linestatus": D.VARCHAR, "l_shipdate": D.DATE,
        "l_commitdate": D.DATE, "l_receiptdate": D.DATE,
        "l_shipinstruct": D.VARCHAR, "l_shipmode": D.VARCHAR,
        "l_comment": D.VARCHAR},
        {"l_extendedprice": 2})
    return out


def load_into(db, sf: float = 0.01, seed: int = 7,
              tables: list[str] | None = None) -> None:
    data = generate(sf, seed)
    for name, (cols, types, scales) in data.items():
        if tables is not None and name not in tables:
            continue
        db.create_table(name, cols, types=types, scales=scales)
