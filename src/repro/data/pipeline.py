"""DB-backed training data pipeline — the paper's pitch, operationalized:
the embedded analytical store IS the storage engine for training data.

* Tokens live in the embedded columnar store (an INT32 column is already
  the training-ready packed array — zero-copy into jnp per §3.3).
* Curation (filtering, dedup, stats) runs as relational queries on the
  same engine *in the trainer process* — no export/import hop.
* Batches are cursor-addressed slices of an immutable table version, so a
  restarted job replays exactly (the snapshot gives exactly-once batches;
  the cursor is checkpointed with the model).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..core.exchange import zero_copy_view
from ..core.session import Database


def tokenize_corpus(db: Database, n_tokens: int, vocab: int,
                    table: str = "corpus", seed: int = 0) -> None:
    """Synthesize a zipf-ish token stream into the store (stand-in for a
    real tokenizer run; the storage path is identical)."""
    rng = np.random.default_rng(seed)
    z = rng.zipf(1.3, size=n_tokens).astype(np.int64)
    tokens = (z % vocab).astype(np.int32)
    db.create_table(table, {"token": tokens},
                    types={"token": __import__(
                        "repro.core.types", fromlist=["DBType"]).DBType.INT32})


def curate(db: Database, src: str = "corpus", dst: str = "corpus_clean",
           drop_token: Optional[int] = None) -> int:
    """Example curation pass: engine-side filtering before training."""
    from ..core.expression import Col
    q = db.scan(src)
    if drop_token is not None:
        q = q.filter(Col("token") != drop_token)
    result = q.execute()
    col = result.columns["token"]
    db.create_table(dst, {"token": np.asarray(col.data)},
                    types={"token": col.dbtype})
    return result.num_rows


@dataclass
class TokenPipeline:
    """Cursor-based batch iterator over an immutable token column."""
    db: Database
    table: str = "corpus"
    column: str = "token"
    batch: int = 8
    seq_len: int = 128
    cursor: int = 0
    _version: int = -1

    def __post_init__(self):
        t = self.db.table(self.table)
        self._version = t.version
        self._view = zero_copy_view(t.column(self.column))  # O(1), no copy

    @property
    def tokens_per_batch(self) -> int:
        return self.batch * (self.seq_len + 1)

    def state(self) -> dict:
        """Checkpointable cursor (exactly-once restart)."""
        return {"cursor": self.cursor, "version": self._version,
                "table": self.table}

    def restore(self, state: dict) -> None:
        assert state["table"] == self.table
        if state["version"] != self._version:
            raise RuntimeError(
                "table version changed; snapshot does not match cursor")
        self.cursor = state["cursor"]

    def next_batch(self) -> dict[str, np.ndarray]:
        n = self.tokens_per_batch
        total = len(self._view)
        if self.cursor + n > total:
            self.cursor = 0                       # epoch wrap
        flat = self._view[self.cursor:self.cursor + n]
        self.cursor += n
        arr = np.asarray(flat).reshape(self.batch, self.seq_len + 1)
        return {"tokens": np.ascontiguousarray(arr[:, :-1]),
                "labels": np.ascontiguousarray(arr[:, 1:])}

    def shard_plan(self, n_hosts: int) -> list[tuple[int, int]]:
        """Static host sharding of the stream (rebalanced by fault.py's
        straggler plan): contiguous [start, end) per host."""
        total = len(self._view)
        per = total // n_hosts
        return [(i * per, (i + 1) * per) for i in range(n_hosts)]
