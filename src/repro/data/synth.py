"""Synthetic datasets: the ACS-like wide survey table (paper §4.3).

The American Community Survey benchmark uses a 274-column mixed-type table
(~millions of census rows).  We synthesize the same shape: person records
with replicate weights, demographic categoricals, and numeric amounts, so
bench_acs.py can run the paper's load + statistics pipeline."""

from __future__ import annotations

import numpy as np

from ..core.types import DBType

N_WEIGHT_REPLICATES = 80       # pwgtp1..80, as in the real ACS
STATES = ["AL", "CA", "NY", "TX", "WA"]


def generate_acs(n_rows: int = 50_000, seed: int = 11):
    """Returns (columns, types, scales) — 274 columns like the ACS PUMS."""
    rng = np.random.default_rng(seed)
    cols: dict = {}
    types: dict = {}
    D = DBType

    def add(name, arr, t, scale=None):
        cols[name] = arr
        types[name] = t

    add("serialno", np.arange(n_rows, dtype=np.int64), D.INT64)
    add("st", np.asarray(STATES, dtype=object)[
        rng.integers(0, len(STATES), n_rows)], D.VARCHAR)
    add("puma", rng.integers(100, 990, n_rows).astype(np.int64), D.INT64)
    add("agep", rng.integers(0, 95, n_rows).astype(np.int64), D.INT64)
    add("sex", rng.integers(1, 3, n_rows).astype(np.int64), D.INT64)
    add("pwgtp", rng.integers(1, 300, n_rows).astype(np.int64), D.INT64)
    # income-ish numerics with NULLs (children have no earnings)
    wage = rng.exponential(30000, n_rows)
    wage[cols["agep"] < 16] = np.nan
    add("wagp", wage, D.FLOAT64)
    add("pincp", np.where(np.isnan(wage), np.nan,
                          wage * rng.uniform(1.0, 1.4, n_rows)), D.FLOAT64)
    add("schl", rng.integers(1, 25, n_rows).astype(np.int64), D.INT64)
    add("esr", rng.integers(0, 7, n_rows).astype(np.int64), D.INT64)
    add("hicov", rng.integers(1, 3, n_rows).astype(np.int64), D.INT64)
    add("mar", rng.integers(1, 6, n_rows).astype(np.int64), D.INT64)
    # 80 replicate weights (the survey-package workload reads these)
    base = cols["pwgtp"]
    for i in range(1, N_WEIGHT_REPLICATES + 1):
        add(f"pwgtp{i}",
            np.maximum(1, base + rng.integers(-40, 41, n_rows)).astype(
                np.int64), D.INT64)
    # filler categoricals/numerics up to 274 columns
    i = 0
    while len(cols) < 274:
        i += 1
        if i % 3 == 0:
            add(f"cat{i}", rng.integers(0, 9, n_rows).astype(np.int64),
                D.INT64)
        elif i % 3 == 1:
            add(f"amt{i}", np.round(rng.uniform(0, 1000, n_rows), 2),
                D.FLOAT64)
        else:
            add(f"flag{i}", rng.integers(0, 2, n_rows).astype(np.int64),
                D.INT64)
    return cols, types, {}


def load_acs(db, n_rows: int = 50_000, seed: int = 11,
             table: str = "acs_pums"):
    cols, types, scales = generate_acs(n_rows, seed)
    db.create_table(table, cols, types=types, scales=scales)
    return db.table(table)
