"""TPC-H queries 1-10 (paper Table 1) against the embedded engine.

Each q<i>(db) returns a relalg Query; queries expressible in our SQL subset
also appear in SQL (used by tests to check parser == builder).  Queries
needing subqueries (Q2) or double table references (Q7-Q9) use the builder
with explicit projections/renames — the same shape VectorWise-style plans
take after decorrelation.
"""

from __future__ import annotations

from ..core.expression import Case, Col, DateLit, Func, Lit
from ..core.relalg import Query


def q1(db) -> Query:
    l = db.scan("lineitem")
    disc_price = Col("l_extendedprice") * (1 - Col("l_discount"))
    charge = disc_price * (1 + Col("l_tax"))
    return (l.filter(Col("l_shipdate") <= DateLit("1998-09-02"))
            .group_by("l_returnflag", "l_linestatus")
            .agg(sum_qty=("sum", Col("l_quantity")),
                 sum_base_price=("sum", Col("l_extendedprice")),
                 sum_disc_price=("sum", disc_price),
                 sum_charge=("sum", charge),
                 avg_qty=("avg", Col("l_quantity")),
                 avg_price=("avg", Col("l_extendedprice")),
                 avg_disc=("avg", Col("l_discount")),
                 count_order=("count", None))
            .order_by("l_returnflag", "l_linestatus"))


Q1_SQL = """
SELECT l_returnflag, l_linestatus,
       sum(l_quantity) AS sum_qty,
       sum(l_extendedprice) AS sum_base_price,
       sum(l_extendedprice * (1 - l_discount)) AS sum_disc_price,
       sum(l_extendedprice * (1 - l_discount) * (1 + l_tax)) AS sum_charge,
       avg(l_quantity) AS avg_qty,
       avg(l_extendedprice) AS avg_price,
       avg(l_discount) AS avg_disc,
       count(*) AS count_order
FROM lineitem
WHERE l_shipdate <= DATE '1998-09-02'
GROUP BY l_returnflag, l_linestatus
ORDER BY l_returnflag, l_linestatus
"""


def _europe_suppliers(db) -> Query:
    return (db.scan("partsupp")
            .join(db.scan("supplier"), left_on="ps_suppkey",
                  right_on="s_suppkey")
            .join(db.scan("nation"), left_on="s_nationkey",
                  right_on="n_nationkey")
            .join(db.scan("region").filter(Col("r_name") == "EUROPE"),
                  left_on="n_regionkey", right_on="r_regionkey"))


def q2(db) -> Query:
    eu = _europe_suppliers(db)
    min_cost = (eu.group_by("ps_partkey")
                .agg(min_cost=("min", Col("ps_supplycost"))))
    parts = db.scan("part").filter(
        (Col("p_size") == 15) & Col("p_type").like("%BRASS"))
    return (eu.join(min_cost, on="ps_partkey")
            .filter(Col("ps_supplycost") == Col("min_cost"))
            .join(parts, left_on="ps_partkey", right_on="p_partkey")
            .select("s_acctbal", "s_name", "n_name", "p_partkey", "p_mfgr",
                    "s_address", "s_phone", "s_comment")
            .order_by(("s_acctbal", True), "n_name", "s_name", "p_partkey",
                      limit=100))


def q3(db) -> Query:
    revenue = Col("l_extendedprice") * (1 - Col("l_discount"))
    return (db.scan("customer").filter(Col("c_mktsegment") == "BUILDING")
            .join(db.scan("orders"), left_on="c_custkey",
                  right_on="o_custkey")
            .filter(Col("o_orderdate") < DateLit("1995-03-15"))
            .join(db.scan("lineitem"), left_on="o_orderkey",
                  right_on="l_orderkey")
            .filter(Col("l_shipdate") > DateLit("1995-03-15"))
            .group_by("l_orderkey", "o_orderdate", "o_shippriority")
            .agg(revenue=("sum", revenue))
            .order_by(("revenue", True), "o_orderdate", limit=10))


Q3_SQL = """
SELECT l_orderkey, sum(l_extendedprice * (1 - l_discount)) AS revenue,
       o_orderdate, o_shippriority
FROM customer, orders, lineitem
WHERE c_mktsegment = 'BUILDING' AND c_custkey = o_custkey
  AND l_orderkey = o_orderkey AND o_orderdate < DATE '1995-03-15'
  AND l_shipdate > DATE '1995-03-15'
GROUP BY l_orderkey, o_orderdate, o_shippriority
ORDER BY revenue DESC, o_orderdate LIMIT 10
"""


def q4(db) -> Query:
    late = db.scan("lineitem").filter(
        Col("l_commitdate") < Col("l_receiptdate"))
    return (db.scan("orders")
            .filter((Col("o_orderdate") >= DateLit("1993-07-01"))
                    & (Col("o_orderdate") < DateLit("1993-10-01")))
            .join(late, left_on="o_orderkey", right_on="l_orderkey",
                  how="semi")
            .group_by("o_orderpriority")
            .agg(order_count=("count", None))
            .order_by("o_orderpriority"))


def q5(db) -> Query:
    revenue = Col("l_extendedprice") * (1 - Col("l_discount"))
    return (db.scan("customer")
            .join(db.scan("orders"), left_on="c_custkey",
                  right_on="o_custkey")
            .filter((Col("o_orderdate") >= DateLit("1994-01-01"))
                    & (Col("o_orderdate") < DateLit("1995-01-01")))
            .join(db.scan("lineitem"), left_on="o_orderkey",
                  right_on="l_orderkey")
            .join(db.scan("supplier"), left_on="l_suppkey",
                  right_on="s_suppkey")
            .filter(Col("c_nationkey") == Col("s_nationkey"))
            .join(db.scan("nation"), left_on="s_nationkey",
                  right_on="n_nationkey")
            .join(db.scan("region").filter(Col("r_name") == "ASIA"),
                  left_on="n_regionkey", right_on="r_regionkey")
            .group_by("n_name")
            .agg(revenue=("sum", revenue))
            .order_by(("revenue", True)))


def q6(db) -> Query:
    return (db.scan("lineitem")
            .filter((Col("l_shipdate") >= DateLit("1994-01-01"))
                    & (Col("l_shipdate") < DateLit("1995-01-01"))
                    & (Col("l_discount") >= 0.05)
                    & (Col("l_discount") <= 0.07)
                    & (Col("l_quantity") < 24))
            .agg(revenue=("sum", Col("l_extendedprice")
                          * Col("l_discount"))))


Q6_SQL = """
SELECT sum(l_extendedprice * l_discount) AS revenue
FROM lineitem
WHERE l_shipdate >= DATE '1994-01-01' AND l_shipdate < DATE '1995-01-01'
  AND l_discount BETWEEN 0.05 AND 0.07 AND l_quantity < 24
"""


def q7(db) -> Query:
    n1 = db.scan("nation").project(supp_nation=Col("n_name"),
                                   n1_key=Col("n_nationkey"))
    n2 = db.scan("nation").project(cust_nation=Col("n_name"),
                                   n2_key=Col("n_nationkey"))
    volume = Col("l_extendedprice") * (1 - Col("l_discount"))
    cross = ((Col("supp_nation") == "FRANCE")
             & (Col("cust_nation") == "GERMANY")) \
        | ((Col("supp_nation") == "GERMANY")
           & (Col("cust_nation") == "FRANCE"))
    return (db.scan("supplier")
            .join(db.scan("lineitem"), left_on="s_suppkey",
                  right_on="l_suppkey")
            .filter((Col("l_shipdate") >= DateLit("1995-01-01"))
                    & (Col("l_shipdate") <= DateLit("1996-12-31")))
            .join(db.scan("orders"), left_on="l_orderkey",
                  right_on="o_orderkey")
            .join(db.scan("customer"), left_on="o_custkey",
                  right_on="c_custkey")
            .join(n1, left_on="s_nationkey", right_on="n1_key")
            .join(n2, left_on="c_nationkey", right_on="n2_key")
            .filter(cross)
            .project(supp_nation=Col("supp_nation"),
                     cust_nation=Col("cust_nation"),
                     l_year=Func("year", Col("l_shipdate")),
                     volume=volume)
            .group_by("supp_nation", "cust_nation", "l_year")
            .agg(revenue=("sum", Col("volume")))
            .order_by("supp_nation", "cust_nation", "l_year"))


def q8(db) -> Query:
    n1 = db.scan("nation").project(n1_key=Col("n_nationkey"),
                                   n1_region=Col("n_regionkey"))
    n2 = db.scan("nation").project(supp_nation=Col("n_name"),
                                   n2_key=Col("n_nationkey"))
    volume = Col("l_extendedprice") * (1 - Col("l_discount"))
    return (db.scan("part")
            .filter(Col("p_type") == "ECONOMY ANODIZED STEEL")
            .join(db.scan("lineitem"), left_on="p_partkey",
                  right_on="l_partkey")
            .join(db.scan("supplier"), left_on="l_suppkey",
                  right_on="s_suppkey")
            .join(db.scan("orders"), left_on="l_orderkey",
                  right_on="o_orderkey")
            .filter((Col("o_orderdate") >= DateLit("1995-01-01"))
                    & (Col("o_orderdate") <= DateLit("1996-12-31")))
            .join(db.scan("customer"), left_on="o_custkey",
                  right_on="c_custkey")
            .join(n1, left_on="c_nationkey", right_on="n1_key")
            .join(db.scan("region").filter(Col("r_name") == "AMERICA"),
                  left_on="n1_region", right_on="r_regionkey")
            .join(n2, left_on="s_nationkey", right_on="n2_key")
            .project(o_year=Func("year", Col("o_orderdate")),
                     volume=volume,
                     brazil_volume=Case(
                         ((Col("supp_nation") == "BRAZIL", volume),),
                         Lit(0.0)))
            .group_by("o_year")
            .agg(mkt_share_num=("sum", Col("brazil_volume")),
                 mkt_share_den=("sum", Col("volume")))
            .project(o_year=Col("o_year"),
                     mkt_share=Col("mkt_share_num") / Col("mkt_share_den"))
            .order_by("o_year"))


def q9(db) -> Query:
    profit = Col("l_extendedprice") * (1 - Col("l_discount")) \
        - Col("ps_supplycost") * Col("l_quantity")
    return (db.scan("part").filter(Col("p_name").like("%green%"))
            .join(db.scan("lineitem"), left_on="p_partkey",
                  right_on="l_partkey")
            .join(db.scan("supplier"), left_on="l_suppkey",
                  right_on="s_suppkey")
            .join(db.scan("partsupp"),
                  left_on=("l_suppkey", "l_partkey"),
                  right_on=("ps_suppkey", "ps_partkey"))
            .join(db.scan("orders"), left_on="l_orderkey",
                  right_on="o_orderkey")
            .join(db.scan("nation"), left_on="s_nationkey",
                  right_on="n_nationkey")
            .project(nation=Col("n_name"),
                     o_year=Func("year", Col("o_orderdate")),
                     amount=profit)
            .group_by("nation", "o_year")
            .agg(sum_profit=("sum", Col("amount")))
            .order_by("nation", ("o_year", True)))


def q10(db) -> Query:
    revenue = Col("l_extendedprice") * (1 - Col("l_discount"))
    return (db.scan("customer")
            .join(db.scan("orders"), left_on="c_custkey",
                  right_on="o_custkey")
            .filter((Col("o_orderdate") >= DateLit("1993-10-01"))
                    & (Col("o_orderdate") < DateLit("1994-01-01")))
            .join(db.scan("lineitem"), left_on="o_orderkey",
                  right_on="l_orderkey")
            .filter(Col("l_returnflag") == "R")
            .join(db.scan("nation"), left_on="c_nationkey",
                  right_on="n_nationkey")
            .group_by("c_custkey", "c_name", "c_acctbal", "c_phone",
                      "n_name", "c_address", "c_comment")
            .agg(revenue=("sum", revenue))
            .order_by(("revenue", True), limit=20))


Q10_SQL = """
SELECT c_custkey, c_name,
       sum(l_extendedprice * (1 - l_discount)) AS revenue,
       c_acctbal, n_name, c_address, c_phone, c_comment
FROM customer, orders, lineitem, nation
WHERE c_custkey = o_custkey AND l_orderkey = o_orderkey
  AND o_orderdate >= DATE '1993-10-01' AND o_orderdate < DATE '1994-01-01'
  AND l_returnflag = 'R' AND c_nationkey = n_nationkey
GROUP BY c_custkey, c_name, c_acctbal, c_phone, n_name, c_address, c_comment
ORDER BY revenue DESC LIMIT 20
"""


ALL_QUERIES = {f"q{i}": fn for i, fn in enumerate(
    [q1, q2, q3, q4, q5, q6, q7, q8, q9, q10], start=1)}
SQL_QUERIES = {"q1": Q1_SQL, "q3": Q3_SQL, "q6": Q6_SQL, "q10": Q10_SQL}
