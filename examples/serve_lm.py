"""Serve a small model with batched requests: prefill + batched greedy
decode through the KV-cache path (the serve_step the dry-run lowers at
32k/500k scale).

    PYTHONPATH=src python examples/serve_lm.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.transformer import init_model
from repro.serve.serve_step import generate

cfg = ModelConfig(
    name="serve-demo", family="dense", n_layers=4, d_model=256,
    n_heads=4, n_kv_heads=2, d_ff=1024, vocab=8192, d_head=64,
    dtype="float32", attn_q_chunk=128, attn_kv_chunk=128, remat=False)

params = init_model(jax.random.PRNGKey(0), cfg)
rng = np.random.default_rng(0)

# a batch of 8 concurrent requests with different prompts
prompts = jnp.asarray(rng.integers(1, cfg.vocab, (8, 32)), jnp.int32)
t0 = time.perf_counter()
out = generate(params, cfg, prompts, max_new_tokens=32, temperature=0.0)
dt = time.perf_counter() - t0
out = np.asarray(out)
print(f"generated {out.size} tokens for {out.shape[0]} requests "
      f"in {dt*1e3:.0f} ms ({out.size/dt:.0f} tok/s incl. compile)")

# greedy decode is deterministic: same prompts -> same continuations
out2 = np.asarray(generate(params, cfg, prompts, max_new_tokens=32))
assert (out == out2).all()
print("deterministic decode OK; sample:", out[0, :10].tolist())

# sampled decoding
out3 = np.asarray(generate(params, cfg, prompts, max_new_tokens=8,
                           temperature=1.0))
print("sampled:", out3[0].tolist())
print("OK")
