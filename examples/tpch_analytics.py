"""TPC-H analytics on the embedded engine (paper Table 1 workload).

Loads dbgen-lite data, runs Q1-Q10, shows plans, index effects, and the
distributed tier.

    PYTHONPATH=src python examples/tpch_analytics.py [--sf 0.01]
"""

import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import startup
from repro.data import tpch
from repro.data.tpch_queries import ALL_QUERIES, Q1_SQL

ap = argparse.ArgumentParser()
ap.add_argument("--sf", type=float, default=0.01)
args = ap.parse_args()

db = startup()
print(f"loading TPC-H sf={args.sf} ...")
tpch.load_into(db, args.sf)
for t in db.table_names():
    print(f"  {t:10s} {db.table(t).num_rows:>9,} rows "
          f"{db.table(t).nbytes/1e6:8.1f} MB")

print("\nQ1 via SQL:")
t0 = time.perf_counter()
res = db.connect().query(Q1_SQL)
print(f"  {res.nrows} groups in {(time.perf_counter()-t0)*1e3:.1f} ms")
for i, name in enumerate(res.names):
    print(f"  {name}: {res.fetch(i)[0][:2]}")

print("\noptimized plan for Q3:")
print(ALL_QUERIES["q3"](db).explain())

print("\nall ten queries:")
total = 0.0
for name, qf in ALL_QUERIES.items():
    t0 = time.perf_counter()
    out = qf(db).execute()
    dt = time.perf_counter() - t0
    total += dt
    print(f"  {name:4s} {dt*1e3:8.2f} ms   {out.num_rows:>6} rows "
          f"(instr={db.last_stats.instructions}, "
          f"index_hits={db.last_stats.index_hits})")
print(f"  total {total*1e3:8.2f} ms")

print("\nQ6 on the distributed tier (shard_map over local mesh):")
t0 = time.perf_counter()
out = ALL_QUERIES["q6"](db).execute(distributed=True)
print(f"  revenue={out.to_pydict()['revenue'][0]:.2f} "
      f"in {(time.perf_counter()-t0)*1e3:.1f} ms (includes compile)")
print("OK")
