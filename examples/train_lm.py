"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps,
fed by the embedded analytical store (the paper's ML-storage-engine pitch).

    PYTHONPATH=src python examples/train_lm.py            # ~100M params
    PYTHONPATH=src python examples/train_lm.py --tiny     # CI-sized
"""

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import build_parser, run

ap = argparse.ArgumentParser()
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--steps", type=int, default=300)
ns, _ = ap.parse_known_args()

if ns.tiny:
    argv = ["--steps", "40", "--batch", "4", "--seq-len", "64",
            "--d-model", "128", "--layers", "2",
            "--run-dir", "runs/train_lm_tiny", "--log-every", "10"]
else:
    # ~100M params: 12 x d768 blocks + 8k vocab
    argv = ["--steps", str(ns.steps), "--batch", "8", "--seq-len", "256",
            "--d-model", "768", "--layers", "12",
            "--ckpt-dir", "runs/train_lm/ckpt", "--ckpt-every", "100",
            "--run-dir", "runs/train_lm", "--log-every", "10"]

result = run(build_parser().parse_args(argv))
print(f"trained {result['steps']} steps: "
      f"loss {result['first_loss']:.3f} -> {result['last_loss']:.3f}")
assert result["last_loss"] < result["first_loss"]
print("OK")
