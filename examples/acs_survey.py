"""ACS survey analysis (paper §4.3): the wide-table workload end to end.

Mirrors the survey-package split the paper benchmarks: load the 274-column
census table into the embedded store, push the SQL-expressible aggregation
into the engine, and do the replicate-weight statistics host-side on
zero-copy exports.

    PYTHONPATH=src python examples/acs_survey.py
"""

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import Col, startup
from repro.core.exchange import export_table
from repro.data.synth import load_acs

db = startup()
t0 = time.perf_counter()
table = load_acs(db, n_rows=50_000)
print(f"loaded {table.num_cols} columns x {table.num_rows:,} rows "
      f"({table.nbytes/1e6:.0f} MB) in {(time.perf_counter()-t0)*1e3:.0f} ms")

# 1) in-engine: weighted population + mean wage by state (SQL path)
res = db.connect().query("""
    SELECT st, sum(pwgtp) AS population, avg(wagp) AS mean_wage,
           count(*) AS n
    FROM acs_pums WHERE agep >= 16 GROUP BY st ORDER BY st
""")
print("\nstate estimates (engine):")
d = res.to_pydict()
for i in range(res.nrows):
    print(f"  {d['st'][i]}: pop={d['population'][i]:>9} "
          f"mean_wage={d['mean_wage'][i]:9.0f} n={d['n'][i]}")

# 2) host-side: replicate-weight standard errors on zero-copy exports
#    (the 'survey package in R' part of the paper's pipeline)
cols = [f"pwgtp{i}" for i in range(1, 81)]
lf = export_table(db.scan("acs_pums").select("pwgtp", *cols).execute())
base = lf["pwgtp"].astype(np.float64)
reps = np.stack([lf[c] for c in cols]).astype(np.float64)
total = base.sum()
rep_totals = reps.sum(axis=1)
se = np.sqrt(4.0 / 80.0 * ((rep_totals - total) ** 2).sum())
print(f"\nweighted population total: {total:,.0f}  (replicate SE {se:,.0f})")
print(f"zero-copy exports: {lf.zero_copies}, conversions: {lf.conversions}")

# 3) engine-side filter + median income for a subgroup
med = (db.scan("acs_pums")
       .filter((Col("agep") >= 25) & (Col("agep") <= 64)
               & Col("wagp").isnull().__invert__())
       .agg(median_wage=("median", "wagp"), n=("count", None))
       .execute().to_pydict())
print(f"\nworking-age median wage: {med['median_wage'][0]:.0f} "
      f"(n={med['n'][0]:,})")
print("OK")
