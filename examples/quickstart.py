"""Quickstart: the embedded analytical database in five minutes.

Mirrors the paper's embedding interface (§3.2): startup -> connect ->
query/append -> zero-copy export, plus persistence and transactions.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import Col, startup
from repro.core.exchange import export_table

# --- in-memory database (monetdb_startup(NULL)) ---------------------------
db = startup()
rng = np.random.default_rng(0)
n = 100_000
db.create_table("trips", {
    "city": np.asarray(["ams", "nyc", "sfo"], dtype=object)[
        rng.integers(0, 3, n)],
    "distance_km": rng.gamma(2.0, 5.0, n),
    "fare": rng.gamma(3.0, 7.0, n),
})

con = db.connect()
res = con.query("""
    SELECT city, count(*) AS trips, avg(fare) AS avg_fare,
           sum(fare) AS revenue
    FROM trips WHERE distance_km > 5 GROUP BY city ORDER BY revenue DESC
""")
print("SQL result:", res.to_pydict())

# --- builder API + zero-copy export ----------------------------------------
top = (db.scan("trips")
       .filter(Col("fare") > 50)
       .group_by("city")
       .agg(p90_candidates=("count", None), m=("median", "fare"))
       .order_by("city")
       .execute())
frame = export_table(top)                 # lazy, zero-copy for numerics
print("medians:", list(frame["m"]))
print("conversions performed:", frame.conversions,
      "| zero-copy columns:", frame.zero_copies)

# --- transactions (optimistic, snapshot isolation) --------------------------
txn_con = db.connect()
txn_con.begin()
txn_con.append("trips", {"city": np.asarray(["ams"], dtype=object),
                         "distance_km": np.array([1.0]),
                         "fare": np.array([4.5])})
print("inside txn:",
      txn_con.query("SELECT count(*) n FROM trips").to_pydict()["n"][0])
txn_con.rollback()
print("after rollback:",
      db.connect().query("SELECT count(*) n FROM trips").to_pydict()["n"][0])

# --- persistent mode --------------------------------------------------------
# Database is a context manager: shutdown (persist + directory-lock
# release) is guaranteed on scope exit, including on exceptions.
with tempfile.TemporaryDirectory() as d:
    with startup(os.path.join(d, "mydb")) as pdb:
        pdb.create_table("t", {"v": np.arange(10, dtype=np.int64)})
    with startup(os.path.join(d, "mydb")) as pdb2:   # reload from disk
        print("persistent rows:", pdb2.table("t").num_rows)

# --- out-of-core execution under a memory budget ----------------------------
# The paper's standard-RDBMS feature the in-memory competitors lack: pass
# memory_budget= (bytes) and blocking operators (join / group-by / sort)
# spill partitioned run files to disk whenever their working state would
# exceed it — results are bit-identical to in-memory execution.  The
# default (no argument) stays zero-config: unlimited, never spills.
#
# Two spill-pipeline knobs (both default to the fast path):
#   spill_codec="for"  — run files are block-encoded with frame-of-reference
#                        + byte-shuffle on integer key/index streams (2-8x
#                        smaller on sorted/clustered keys; floats pass
#                        through raw); "raw" disables encoding.
#   spill_prefetch=True — a background thread loads partition N+1 while
#                        partition N is processed; prefetched bytes stay
#                        pinned, so tracked peak still respects the budget.
small = startup(memory_budget=256 << 10,          # 256 KiB working-state cap
                spill_codec="for", spill_prefetch=True)
small.create_table("trips", {
    "city": np.asarray(["ams", "nyc", "sfo"], dtype=object)[
        rng.integers(0, 3, n)],
    "distance_km": rng.gamma(2.0, 5.0, n),
    "fare": rng.gamma(3.0, 7.0, n),
})
ooc = (small.scan("trips")
       .group_by("city", "fare")                  # state >> budget: spills
       .agg(n=("count", None))
       .order_by(("n", True), limit=5)
       .execute())
stats = small.buffer_manager.stats
print("out-of-core top groups:", ooc.to_pydict()["n"][:3],
      "| ops spilled:", stats.spilled_ops,
      "| peak tracked bytes:", stats.peak,
      "| spill files live:", small.buffer_manager.active_files)
# BufferStats also reports the pipeline-v2 counters: raw (logical) vs
# actually-written spill bytes, partitions served by the async prefetcher,
# and oversized partitions that were recursively re-split.
print("spilled raw -> stored:", stats.bytes_spilled_raw, "->",
      stats.bytes_spilled_compressed,
      "| prefetch hits:", stats.prefetch_hits,
      "| repartitions:", stats.repartitions)

# --- VARCHAR spilling across dictionary heaps -------------------------------
# VARCHAR columns execute as int32 codes into a duplicate-eliminated,
# order-preserving string heap (paper §3.1).  String-keyed joins spill even
# when the two sides were encoded against *different* heaps; the strategy is
# chosen per key from the heap/budget ratio:
#   * content-equal heaps (same object, or equal fingerprints — e.g. two
#     separately-loaded copies of a table): partition on plain codes;
#   * distinct heaps that fit ~budget/4: merge into one shared dictionary
#     (StringHeap.merge) and recode both sides while spooling;
#   * oversized heaps: spill decoded string bytes (offsets+bytes block
#     codec) and hash-partition on those.
# Group-by and sort on VARCHAR keys spill on their codes directly — a key
# column has one heap, and sorted-order code assignment makes code ranges
# string ranges.  `varchar_spills` (BufferStats and per-query ExecStats)
# counts blocking ops that spilled with VARCHAR keys.
sdb = startup(memory_budget=256 << 10)
sdb.create_table("trips", {
    "city": np.asarray(["ams", "nyc", "sfo"], dtype=object)[
        rng.integers(0, 3, n)],
    "fare": rng.gamma(3.0, 7.0, n),
})
sdb.create_table("cities", {          # separate load -> its own heap
    "city": np.asarray(["ams", "bos", "nyc", "sfo"], dtype=object),
    "tz": np.asarray(["CET", "EST", "EST", "PST"], dtype=object),
})
vj = (sdb.scan("trips")
      .join(sdb.scan("cities"), on="city")     # string keys, distinct heaps
      .group_by("tz").agg(rev=("sum", "fare"))
      .execute())
vstats = sdb.buffer_manager.stats
print("varchar join:", vj.to_pydict(),
      "| varchar spills:", vstats.varchar_spills,
      "| per-query:", sdb.last_stats.varchar_spills)

# --- distributed execution (paper Fig. 2 on whatever mesh exists) ----------
dist = (db.scan("trips").filter(Col("distance_km") > 5)
        .group_by("city").agg(rev=("sum", "fare"))
        .execute(distributed=True))
print("distributed result:", dist.to_pydict())

# --- device tier under an HBM budget ----------------------------------------
# The memory-hierarchy trick one level up: device_budget= (bytes) makes HBM
# a budgeted LRU cache over host memory.  Distributed scans whose columns
# fit stay *resident* — a repeated query is served entirely from the
# cross-query block cache (device_cache_hits, zero new host→device bytes).
# Larger tables *stream* morsel batches (device_batch_rows, default 65536)
# through the cache with double-buffered async prefetch and a partial-
# aggregate carry, evicting consumed blocks — so accelerators whose memory
# is smaller than the table still run the query instead of bailing to the
# host tier.  Results are bit-identical across budgets: the batch
# decomposition, never the budget, fixes the arithmetic.  Budgets too small
# for even one batch fall back to the host tier (which spills if the host
# memory_budget demands it).
hbm = startup(device_budget=32 << 20, device_batch_rows=16_384)
hbm.create_table("trips", {
    "city": np.asarray(["ams", "nyc", "sfo"], dtype=object)[
        rng.integers(0, 3, n)],
    "distance_km": rng.gamma(2.0, 5.0, n),
    "fare": rng.gamma(3.0, 7.0, n),
})
dq = (hbm.scan("trips").filter(Col("distance_km") > 5)
      .group_by("city").agg(rev=("sum", "fare"), nt=("count", None)))
cold = dq.execute(distributed=True)
print("device cold: tier:", hbm.last_stats.device_tier,
      "| h2d bytes:", hbm.last_stats.device_bytes_h2d)
hot = dq.execute(distributed=True)
# BufferStats/ExecStats report the device-tier counters alongside the host
# spill counters: device_bytes_peak, device_bytes_h2d, device_cache_hits,
# device_prefetch_hits, device_evictions, device_writebacks.
dstats = hbm.buffer_manager.stats
print("device hot: cache hits:", hbm.last_stats.device_cache_hits,
      "| new h2d bytes:", hbm.last_stats.device_bytes_h2d,
      "| peak device bytes:", dstats.device_bytes_peak,
      "| evictions:", dstats.device_evictions)

# --- EXPLAIN of physical plans ----------------------------------------------
# Every query — SQL or builder — is lowered through ONE physical planner
# (core/physplan.py) before execution.  explain(physical=True) shows the
# normalized plan with per-operator tier decisions and budget reservations:
#
#   * device-resident  — scan-agg core fully cached in device memory
#   * device-streamed  — core streams morsel batches through the HBM cache
#   * parallel-host    — core matched the device pattern but stays on host
#   * spill            — blocking op expected to exceed memory_budget
#   * in-memory        — fits; runs in RAM
#
# Tier decisions are made from data statistics, not the entry point: SQL
# and builder plans normalize to the same shape (the SQL front-end's
# rename projection folds into the aggregate), so both lower identically
# — one planner, many frontends.  Annotations marked (runtime-refined)
# are plan-time predictions; blocking instructions re-check with actual
# cardinalities through the same policy at runtime.  Device admission is
# biased by the cache's hit history: a table that fits the device budget
# but would occupy more than half of it streams on first touch and flips
# to resident once repeat queries produce cache hits.
print(dq.explain(physical=True, distributed=True))
# The same text is recorded per query on ExecStats:
print("last plan was:\n", hbm.last_stats.plan_repr)

# --- device-tier joins and sorts --------------------------------------------
# Aggregates over inner-join trees (the TPC-H Q3 shape) run on the device
# too: each dimension build becomes a dense (key_domain, 1+payload) matrix
# scatter-added in HBM, verified unique at runtime (duplicate build keys
# fall back to the host join), then the fact table streams through a probe
# step that gathers presence + payload per batch.  Assembly stays
# device-resident — finalize, compact to present groups and, when the
# ORDER BY maps onto group keys/aggregates, the lexsort permutation all
# happen in HBM (ExecStats.device_sorted) and only the surviving top-N
# rows are fetched.  EXPLAIN shows the join core as `:: device-join`
# (mode=resident|streamed from the same byte model as scans) and a fused
# sort as `:: device-sort`:
star = startup(device_budget=32 << 20, device_batch_rows=16_384)
star.create_table("dim_city", {
    "c_id": np.arange(64, dtype=np.int64),     # matcher attributes columns
    "c_pop": rng.integers(10_000, 9_000_000, 64),  # by name: keep them
})                                                 # distinct across tables
star.create_table("rides", {
    "city_id": rng.integers(0, 64, n).astype(np.int64),
    "fare": rng.gamma(3.0, 7.0, n),
})
jq = (star.scan("rides")
      .join(star.scan("dim_city"), left_on="city_id", right_on="c_id")
      .group_by("city_id", "c_pop")
      .agg(rev=("sum", "fare"), nt=("count", None))
      .order_by(("rev", True), limit=5))
print(jq.explain(physical=True, distributed=True))
top5 = jq.execute(distributed=True)
print("top cities:", top5.to_pydict())
print("join tier:", star.last_stats.device_tier,        # join-resident
      "| sort fused on device:", star.last_stats.device_sorted,
      "| peak device bytes:", star.last_stats.device_bytes_peak)
star.shutdown()

# --- imprint-driven data skipping -------------------------------------------
# Paper §3.1's column imprints (per-2048-row zone maps: min/max + a 16-bin
# presence bitmap) now feed the planner: plan_physical derives a per-scan
# skip-set from each range conjunct (`col <op> literal`), and every tier
# consumes it — DistributedScanAgg never uploads a batch whose blocks all
# fail the zone maps, the host filter path never evaluates (or spills rows
# of) a non-qualifying block, and the volcano baseline only materializes
# candidate ranges.  Skipping is sound by construction (candidate sets are
# supersets — proven by a hypothesis property test), version-revalidated
# at execution, and bit-identical on vs off: pass data_skipping=False to
# force it off.  On clustered data a selective filter moves proportionally
# fewer bytes (benchmarks/bench_skipping.py: 8x fewer h2d bytes at 1%
# selectivity).  EXPLAIN shows the planning-time decision as
# `(skip: k/N blocks)` on the scan, and the counters land in
# BufferStats/ExecStats: blocks_skipped, bytes_skipped_h2d,
# bytes_skipped_spill.
clustered = startup()
clustered.create_table("events", {
    "day": np.sort(rng.integers(0, 365, 8192)).astype(np.int64),
    "amount": rng.gamma(3.0, 7.0, 8192),
})
sel = (clustered.scan("events").filter(Col("day") < 30)
       .agg(total=("sum", "amount"), n=("count", None)))
print(sel.explain(physical=True))           # ...Scan events (skip: k/N blocks)
sel.execute()
print("blocks skipped:", clustered.last_stats.blocks_skipped,
      "| filter bytes never read:",
      clustered.last_stats.bytes_skipped_spill)
clustered.shutdown()

# --- streaming ingest through the delta store --------------------------------
# Appends don't rewrite the column anymore: db.append installs an immutable
# delta chunk (O(chunk) commit + WAL record), scans merge base + tail on
# read (bit-identical across all executors), and a threshold compaction
# folds the tail back into the base when it exceeds delta_compact_fraction
# of the table.  That makes bulk loading a *streaming* operation:
# db.ingest(name, iterable_of_column_dicts) pins one morsel-sized piece at
# a time inside memory_budget, so a table larger than the budget loads
# with tracked peak <= budget.  Epoch-keyed device caching means an append
# only invalidates the delta tail's device blocks — a repeat scan after an
# append re-uploads the tail, not the table.
ing = startup(memory_budget=256 << 10, delta_compact_fraction=0.5)

def trip_chunks(total, step=8_192):
    for s in range(0, total, step):
        m = min(step, total - s)
        yield {"city": np.asarray(["ams", "nyc", "sfo"], dtype=object)[
                   rng.integers(0, 3, m)],
               "fare": rng.gamma(3.0, 7.0, m)}

loaded = ing.ingest("trips", trip_chunks(200_000))   # table >> budget
istats = ing.buffer_manager.stats
print("ingested rows:", loaded,
      "| tracked peak <= budget:", istats.peak <= 256 << 10,
      "| compactions:", istats.compactions)
ing.append("trips", {"city": np.asarray(["ams"], dtype=object),
                     "fare": np.array([9.9])})
t = ing.catalog.table("trips")
print("delta tail after append:", t.delta_rows, "rows",
      "| epoch:", t.delta_epoch)
# EXPLAIN shows the merge-on-read scan: ...Scan trips (delta: k rows)
print(ing.scan("trips").agg(n=("count", None)).explain(physical=True))
ing.shutdown()

# --- budgeted result materialization ----------------------------------------
# Final tables whose columns would exceed memory_budget stream to
# memmapped columns instead of a second RAM materialization (string heaps
# stay shared in RAM); the backing files are unlinked immediately, so
# nothing leaks.  ExecStats/BufferStats count them as result_spills.
big = (small.scan("trips")
       .project(city=Col("city"), paid=Col("fare") * 1.1)
       .execute())
print("result_spills:", small.last_stats.result_spills,
      "| columns memmapped:", isinstance(big.columns["paid"].data,
                                         np.memmap))

# --- concurrent use ----------------------------------------------------------
# The database is an embedded engine inside YOUR process, and your process
# is probably multi-threaded.  One Database is safe to share across
# threads; the serving layer keeps concurrent queries honest:
#
#   * admission gate — each query's summed per-operator budget
#     reservations (from the physical plan) are reserved atomically
#     against memory_budget/device_budget BEFORE execution; queries that
#     don't fit queue with a bounded wait (AdmissionTimeout after
#     ~30 s) instead of discovering pressure mid-flight.
#   * atomic pins — BufferManager.try_pin reserves-or-fails under the
#     lock, so N threads can never jointly exceed the budget
#     (`peak <= budget` holds for the whole run, not per query).
#   * plan cache — repeated queries skip the optimize→normalize→annotate
#     lowering pass entirely; entries are invalidated by append / DROP /
#     DELETE, and table versions inside the cache key make stale hits
#     impossible either way.  Observed group cardinalities feed back
#     into the next lowering's tier estimates.
#   * shared scans — concurrent cold queries over the same table attach
#     to ONE in-flight host→device upload per block (single-flight), so
#     a repeat-heavy mix does one upload, not one per client.
#
# Concurrency guarantees an embedder can rely on — these are not just
# conventions: each one is encoded as a checked rule in
# src/repro/analysis/ (`python -m repro.analysis.lint src/` in CI) and
# the lock ORDER between managers is verified at runtime by the
# lock-order witness (REPRO_WITNESS=1 turns it on under pytest):
#
#   1. budget accounting is atomic — every read-modify-write of host or
#      device budget state happens under its manager's lock; admission
#      (gate) and reservation (try_pin / put) are single lock-held
#      decisions, never check-then-act races.
#   2. acquisitions pair with releases on ALL paths — pinned bytes,
#      spill files, admission tickets and the storage directory flock
#      are released on exceptions too (finally/except or context
#      manager), so a failing query leaks nothing: a crashed startup()
#      leaves the directory lockable, a builder that raises mid-upload
#      leaves no pinned device block behind.
#   3. device dispatch is serialized — jitted collective steps are
#      built and launched only under the module dispatch lock, so
#      concurrent queries cannot interleave multi-device collectives
#      (the classic SPMD deadlock).
#   4. stats are safe to read while queries run — shared counters
#      mutate only via locked helpers (BufferManager.bump /
#      DeviceBufferManager.bump); db.last_stats is thread-local.
#   5. lock acquisition order is acyclic — the witness records the
#      cross-thread acquisition graph over the concurrent suite and
#      fails CI on any ordering cycle or on a Condition.wait entered
#      while another engine lock is held.
#
# Per-query stats under concurrency: db.last_stats is a THREAD-LOCAL
# view — each thread sees the stats of the last query it ran, never a
# neighbour's.  Connection.query returns them on the result itself
# (Result.stats), which is the concurrency-proof API.
import threading

def worker(out, slot):
    r = (db.scan("trips").filter(Col("distance_km") > 5 + slot)
         .group_by("city").agg(rev=("sum", "fare")).execute())
    out[slot] = (r.to_pydict(), db.last_stats)

outs = [None, None]
ts = [threading.Thread(target=worker, args=(outs, s)) for s in (0, 1)]
for t in ts:
    t.start()
for t in ts:
    t.join()
print("concurrent stats are per-thread:", outs[0][1] is not outs[1][1])
# a repeated query skips lowering entirely — ExecStats says so per query:
(db.scan("trips").filter(Col("distance_km") > 5)
 .group_by("city").agg(rev=("sum", "fare")).execute())
print("repeat was a plan-cache hit:", db.last_stats.plan_cache_hit,
      "| cache hits so far:", db.buffer_manager.stats.plan_cache_hits)
print("OK")
