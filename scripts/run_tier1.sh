#!/usr/bin/env bash
# Tier-1 verification (see ROADMAP.md): the full test suite, fail-fast.
# Extra pytest args pass through, e.g.:
#   scripts/run_tier1.sh -m "not outofcore and not slow"   # quick run
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
