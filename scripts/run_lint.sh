#!/usr/bin/env bash
# Invariant lint (see src/repro/analysis/README.md): the project-specific
# concurrency/resource checkers, plus the ruff real-bug baseline when
# ruff is on PATH (CI installs it; the dev container may not have it).
# Extra paths pass through, e.g.:
#   scripts/run_lint.sh src/repro/core      # lint one subtree
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m repro.analysis.lint "${@:-src/}"
if command -v ruff >/dev/null 2>&1; then
    ruff check src/
else
    echo "run_lint.sh: ruff not installed; skipped ruff baseline" >&2
fi
