"""Serving loop + HLO cost-model calibration."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, xla_cost
from repro.launch.mesh import make_mesh_compat
from repro.models.config import ModelConfig
from repro.models.transformer import init_model
from repro.serve.serve_step import generate


def _cfg():
    return ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab=64, d_head=8,
                       dtype="float32", attn_q_chunk=8, attn_kv_chunk=8,
                       remat=False)


def test_generate_greedy_deterministic(rng):
    cfg = _cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = rng.integers(0, cfg.vocab, (2, 5)).astype(np.int32)
    out1 = np.asarray(generate(params, cfg, jnp.asarray(prompts),
                               max_new_tokens=6))
    out2 = np.asarray(generate(params, cfg, jnp.asarray(prompts),
                               max_new_tokens=6))
    assert out1.shape == (2, 6)
    np.testing.assert_array_equal(out1, out2)
    assert (out1 >= 0).all() and (out1 < cfg.vocab).all()


def test_generate_eos_padding(rng):
    cfg = _cfg()
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompts = rng.integers(0, cfg.vocab, (1, 4)).astype(np.int32)
    out = np.asarray(generate(params, cfg, jnp.asarray(prompts),
                              max_new_tokens=8, eos_id=3))
    hits = np.nonzero(out[0] == 3)[0]
    if hits.size:                      # everything after first EOS is EOS
        assert (out[0, hits[0]:] == 3).all()


# ---- HLO cost model calibration (the scan-body-once fix) -------------------


def test_flops_plain_matmul_matches_xla():
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((256, 256), jnp.float32),
        jax.ShapeDtypeStruct((256, 256), jnp.float32)).compile()
    mc = analyze_hlo(c.as_text())
    assert mc.flops == pytest.approx(2 * 256 ** 3)
    assert mc.flops == pytest.approx(float(xla_cost(c)["flops"]))


def test_flops_scan_multiplies_by_trip_count():
    def f(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)).compile()
    mc = analyze_hlo(c.as_text())
    assert mc.flops == pytest.approx(12 * 2 * 128 ** 3)
    # XLA's own number counts the body once — the very bug we fix
    # (rel tolerance: newer jax adds a handful of loop-bookkeeping flops)
    assert float(xla_cost(c)["flops"]) == pytest.approx(2 * 128 ** 3,
                                                        rel=1e-5)


def test_flops_nested_scan():
    def f(x, w):
        def outer(c, wi):
            return jax.lax.scan(lambda c2, wj: (c2 @ wj, None), c, wi)[0], None
        return jax.lax.scan(outer, x, w)[0]
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)).compile()
    mc = analyze_hlo(c.as_text())
    assert mc.flops == pytest.approx(15 * 2 * 64 ** 3)
    assert not mc.notes                      # all trip counts resolved


def test_collective_bytes_sharded_matmul():
    import os
    from jax.sharding import NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (dry-run process has 512)")
    mesh = make_mesh_compat((len(jax.devices()),), ("model",))
    c = jax.jit(lambda a, b: a @ b,
                in_shardings=(NamedSharding(mesh, P(None, "model")),
                              NamedSharding(mesh, P("model", None))),
                out_shardings=NamedSharding(mesh, P(None, None))).lower(
        jax.ShapeDtypeStruct((128, 128), jnp.float32),
        jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    mc = analyze_hlo(c.as_text())
    assert mc.coll["all-reduce"] == pytest.approx(2 * 128 * 128 * 4)


def test_hbm_traffic_scan_slicing_not_overcounted():
    """dynamic-slice of scan xs must count slice bytes, not full operand."""
    def f(x, w):
        return jax.lax.scan(lambda c, wi: (c + wi, None), x, w)[0]
    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((1024,), jnp.float32),
        jax.ShapeDtypeStruct((100, 1024), jnp.float32)).compile()
    mc = analyze_hlo(c.as_text())
    full = 100 * 1024 * 4
    # traffic should be O(few x full array), never O(trips x full array)
    assert mc.hbm_upper < 20 * full
