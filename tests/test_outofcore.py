"""Out-of-core execution tier (core/buffers.py + core/spill.py).

Contracts under test:

* spill execution is **byte-identical** to in-memory execution for every
  blocking operator (aggregate / all join flavors / sort, with and without
  limit) across a matrix of memory budgets;
* tracked peak buffer usage stays <= the configured budget;
* every spill file is reclaimed by query end (and the spill dir lives under
  the database directory in persistent mode);
* a query whose intermediates exceed the budget completes instead of
  requiring them resident.
"""

import os

import numpy as np
import pytest

from repro.core import Col, startup

N = 40_000
BUDGETS = [None, 50 << 20, 256 << 10, 32 << 10]


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    fact = {
        "k": rng.integers(0, 500, N),
        "k2": rng.integers(0, 7, N),
        "v": rng.normal(size=N),
        "w": rng.integers(-100, 100, N),
    }
    dim = {"dk": np.arange(500, dtype=np.int64),
           "label": rng.integers(0, 3, 500)}
    return fact, dim


def _build(dataset, budget, **kw):
    fact, dim = dataset
    db = startup(memory_budget=budget, **kw)
    db.create_table("t", fact)
    db.create_table("d", dim)
    return db


def _queries(db):
    """One query per blocking-operator shape the spill tier covers."""
    out = {}
    out["agg"] = (db.scan("t").filter(Col("v") > -1.0).group_by("k", "k2")
                  .agg(s=("sum", "v"), c=("count", None), mn=("min", "w"),
                       mx=("max", "w"), a=("avg", "v"), md=("median", "v"),
                       cd=("count_distinct", "w"))
                  .execute().to_pydict())
    out["join"] = (db.scan("t")
                   .join(db.scan("d"), left_on="k", right_on="dk")
                   .group_by("label").agg(s=("sum", "v"), c=("count", None))
                   .execute().to_pydict())
    out["leftjoin"] = (db.scan("d")
                       .join(db.scan("t"), left_on="dk", right_on="k",
                             how="left")
                       .group_by("label").agg(c=("count", "v"))
                       .execute().to_pydict())
    out["semi"] = (db.scan("t")
                   .join(db.scan("d").filter(Col("label") > 0),
                         left_on="k", right_on="dk", how="semi")
                   .agg(c=("count", None)).execute().to_pydict())
    out["anti"] = (db.scan("t")
                   .join(db.scan("d").filter(Col("label") > 0),
                         left_on="k", right_on="dk", how="anti")
                   .agg(c=("count", None)).execute().to_pydict())
    out["topn"] = (db.scan("t").order_by(("v", True), "w", limit=1000)
                   .select("k", "v", "w").execute().to_pydict())
    out["fullsort"] = (db.scan("t").order_by("k2", ("w", True))
                       .select("k2", "w", "v").execute().to_pydict())
    return out


def _assert_identical(a: dict, b: dict, ctx: str):
    assert list(a) == list(b), ctx
    for c in a:
        if a[c].dtype == object:
            assert list(map(str, a[c])) == list(map(str, b[c])), (ctx, c)
        else:
            np.testing.assert_array_equal(a[c], b[c],
                                          err_msg=f"{ctx} col={c}")


@pytest.fixture(scope="module")
def baseline(dataset):
    return _queries(_build(dataset, None))


@pytest.mark.parametrize("budget", BUDGETS)
def test_budget_matrix_byte_identical(dataset, baseline, budget):
    db = _build(dataset, budget)
    got = _queries(db)
    for qn in baseline:
        _assert_identical(baseline[qn], got[qn], f"budget={budget} q={qn}")
    st = db.buffer_manager.stats
    if budget is not None:
        assert st.peak <= budget, (st.peak, budget)
    if budget is not None and budget <= 256 << 10:
        # working sets above these budgets: the spill tier must engage
        assert st.spilled_ops > 0
        assert st.bytes_spilled > 0
    if budget is None or budget >= 50 << 20:
        assert st.spilled_ops == 0       # fitting inputs: no spill overhead
    # spill-file lifecycle: everything reclaimed by query end
    assert db.buffer_manager.active_files == 0


def test_exceeding_budget_completes(dataset):
    """The acceptance query: aggregate-join over data larger than the
    budget completes with spilling and matches the in-memory result."""
    fact, dim = dataset
    budget = 64 << 10
    assert sum(a.nbytes for a in fact.values()) > budget
    db = _build(dataset, budget)
    base = _build(dataset, None)
    q = lambda d: (d.scan("t")
                   .join(d.scan("d"), left_on="k", right_on="dk")
                   .group_by("k", "w")          # high-cardinality state
                   .agg(s=("sum", "v"), c=("count", None))
                   .order_by(("s", True))
                   .execute().to_pydict())
    _assert_identical(q(base), q(db), "agg-join-sort over budget")
    st = db.buffer_manager.stats
    assert st.spilled_ops >= 3          # join, group and sort all spilled
    assert st.peak <= budget
    assert db.buffer_manager.active_files == 0


def test_spill_dir_under_database_directory(tmp_path):
    """Persistent mode: run files live under <dbdir>/spill and are gone
    after the query; shutdown clears the directory."""
    rng = np.random.default_rng(1)
    db = startup(str(tmp_path / "db"), memory_budget=32 << 10)
    db.create_table("t", {"k": rng.integers(0, 1000, 20_000),
                          "v": rng.normal(size=20_000)})
    spill_dir = os.path.join(str(tmp_path / "db"), "spill")

    seen = {"files": 0}
    bm = db.buffer_manager
    orig = bm.new_spill_file

    def counting(hint="run"):
        seen["files"] += 1
        return orig(hint)

    bm.new_spill_file = counting
    res = (db.scan("t").group_by("k").agg(s=("sum", "v"))
           .execute().to_pydict())
    assert len(res["k"]) == 1000
    assert seen["files"] > 0, "expected the query to spill"
    assert os.path.isdir(spill_dir)
    assert os.listdir(spill_dir) == []       # reclaimed at query end
    db.shutdown()
    assert bm.active_files == 0


def test_memory_budget_api():
    db = startup()
    assert db.memory_budget is None and db.buffer_manager.budget is None
    db2 = startup(memory_budget=1 << 20)
    assert db2.memory_budget == 1 << 20
    with pytest.raises(ValueError):
        startup(memory_budget=0)


def test_sql_path_spills_identically(dataset):
    sql = ("SELECT k2, count(*) AS n, sum(v) AS s FROM t "
           "WHERE w > 0 GROUP BY k2, k ORDER BY s DESC")
    a = _build(dataset, None).connect().query(sql).to_pydict()
    db = _build(dataset, 32 << 10)
    b = db.connect().query(sql).to_pydict()
    for c in a:
        np.testing.assert_array_equal(a[c], b[c], err_msg=c)
    assert db.buffer_manager.stats.spilled_ops > 0


def test_volcano_spooled_aggregation(dataset):
    """The row-at-a-time baseline engine also honors the budget: grouping
    spools pickled row partitions and yields identical output."""
    from repro.core.optimizer import optimize
    from repro.core.volcano import VolcanoExecutor
    base = _build(dataset, None)
    db = _build(dataset, 32 << 10)
    plan = (db.scan("t").group_by("k")
            .agg(s=("sum", "v"), c=("count", None)).plan)
    rows_mem = VolcanoExecutor(base).execute(optimize(plan, base.catalog))
    spilled0 = db.buffer_manager.stats.bytes_spilled
    rows_ooc = VolcanoExecutor(db).execute(optimize(plan, db.catalog))
    assert rows_mem == rows_ooc
    assert db.buffer_manager.stats.bytes_spilled > spilled0
    assert db.buffer_manager.active_files == 0


def test_low_cardinality_group_stays_in_memory(dataset):
    """Grouping state for few distinct keys is tiny: the runtime probe must
    keep it in memory even when the *input* exceeds the budget (spilling
    could never split the dominant groups anyway)."""
    db = _build(dataset, 32 << 10)
    base = _build(dataset, None)
    q = lambda d: (d.scan("t").group_by("k2")
                   .agg(s=("sum", "v")).execute().to_pydict())
    _assert_identical(q(base), q(db), "low-card group")
    st = db.buffer_manager.stats
    assert st.spilled_ops == 0


def test_small_budget_peak_contract(dataset):
    """Sub-32KiB budgets must also hold peak <= budget (regression: the
    old 1024-row morsel/run floors pinned 24KiB regardless of budget)."""
    db = _build(dataset, 16 << 10)
    (db.scan("t").group_by("k", "w").agg(s=("sum", "v"))
     .order_by(("s", True)).execute())
    st = db.buffer_manager.stats
    assert st.spilled_ops >= 2
    assert st.peak <= 16 << 10, st.peak


@pytest.mark.outofcore
def test_sort_cascade_merge_bounded_fds():
    """More sort runs than the merge fan-in (regression: the merge once
    opened every run at once and hit EMFILE on large inputs): cascade
    passes must kick in and the result must stay identical."""
    from repro.core import spill
    rng = np.random.default_rng(11)
    n = 150_000
    vals = {"v": rng.normal(size=n), "k": rng.integers(0, 1000, n)}
    base = startup()
    base.create_table("t", vals)
    db = startup(memory_budget=32 << 10)
    db.create_table("t", vals)
    # 32 KiB budget, 16 B/row -> 1024-row runs -> ~147 runs > fan-in of 64
    assert n / ((32 << 10) // 2 // 16) > spill.SORT_MERGE_FAN_IN
    q = lambda d: (d.scan("t").order_by("v", ("k", True))
                   .select("v", "k").execute().to_pydict())
    _assert_identical(q(base), q(db), "cascade sort")
    assert db.buffer_manager.stats.spilled_ops == 1
    assert db.buffer_manager.active_files == 0


@pytest.mark.outofcore
@pytest.mark.slow
def test_stress_much_larger_than_budget():
    """~10 MB of blocking intermediates through a 1 MB budget."""
    rng = np.random.default_rng(7)
    n = 200_000
    fact = {"k": rng.integers(0, 20_000, n), "v": rng.normal(size=n),
            "w": rng.integers(0, 1_000_000, n)}
    budget = 1 << 20
    base = startup()
    base.create_table("t", fact)
    db = startup(memory_budget=budget)
    db.create_table("t", fact)
    q = lambda d: (d.scan("t").group_by("k")
                   .agg(s=("sum", "v"), mx=("max", "w"))
                   .order_by(("s", True), limit=500).execute().to_pydict())
    _assert_identical(q(base), q(db), "stress-agg")
    q2 = lambda d: (d.scan("t").order_by(("v", True), "w", limit=500)
                    .select("k", "v").execute().to_pydict())
    _assert_identical(q2(base), q2(db), "stress-sort")
    st = db.buffer_manager.stats
    assert st.spilled_ops >= 2
    assert st.peak <= budget
    assert db.buffer_manager.active_files == 0


# ---------------------------------------------------------------------------
# spill pipeline v2: codec, prefetch, recursive repartitioning, leak fixes
# ---------------------------------------------------------------------------


def test_spill_codec_roundtrip_bit_exact():
    """FOR + byte-shuffle blocks decode to the identical bit pattern across
    sorted/clustered/random/sentinel/empty integer streams and float
    passthrough (floats never go through FOR)."""
    from repro.core import buffers
    rng = np.random.default_rng(0)
    cases = [
        np.arange(10_000, dtype=np.int64),                        # sorted
        np.arange(10_000, dtype=np.int64) // 7 + 1_000_000,       # clustered
        rng.integers(-2**62, 2**62, 1000),                        # wide random
        np.array([-2**63, 2**63 - 1, 0, -1], dtype=np.int64),     # sentinels
        np.array([2**53 + 1, 2**53 + 3, 2**62 + 5], dtype=np.int64),
        np.array([2**63, 2**64 - 1, 2**63 + 7], dtype=np.uint64),
        np.arange(100, dtype=np.int32) - 50,
        np.zeros(0, dtype=np.int64),
        rng.normal(size=1000),                                    # float raw
    ]
    for a in cases:
        a = np.asarray(a)
        blk = buffers.encode_block(a, buffers.CODEC_FOR)
        out = buffers.decode_stream(blk, a.dtype)
        assert out.dtype == a.dtype
        np.testing.assert_array_equal(out, a)
    # clustered int64 really shrinks: 0..65535 needs 2 of 8 byte planes
    a = np.arange(65536, dtype=np.int64)
    assert len(buffers.encode_block(a, buffers.CODEC_FOR)) < a.nbytes / 2
    # incompressible data falls back to a raw block (never grows past
    # payload + header)
    r = rng.integers(-2**62, 2**62, 4096)
    assert len(buffers.encode_block(r, buffers.CODEC_FOR)) \
        <= r.nbytes + buffers.BLOCK_HEADER_BYTES


def test_sort_run_index_bit_exact_past_2_53():
    """Regression: run files stored the row index as float64, silently
    rounding indexes past 2^53; the index stream is now native int64 and
    must round-trip bit-exactly."""
    from repro.core import spill
    from repro.core.buffers import BufferManager
    bm = BufferManager(budget=1 << 20)
    idx = np.array([0, 2**53 + 1, 2**53 + 3, 2**62 + 12345], dtype=np.int64)
    assert int(np.float64(2**53 + 1)) != 2**53 + 1   # float64 would corrupt
    keys = [np.array([1.0, 2.0, 3.0, 4.0])]
    path = spill._write_sort_run(bm, keys, idx)
    streamed = [t[-1] for t in spill._iter_sort_run(path, 1)]
    assert streamed == idx.tolist()
    np.testing.assert_array_equal(spill._run_index_column(path, 1), idx)
    bm.cleanup()


def test_spool_error_releases_files():
    """Regression (spill-file leak): an input iterator that raises mid-spool
    must leave zero registered run files — not park them until cleanup()."""
    from repro.core.buffers import BufferManager
    from repro.core.spill import spooled_row_groups

    bm = BufferManager(budget=32 << 10)

    def rows():
        for i in range(5000):
            yield {"k": i % 7, "v": float(i)}
        raise RuntimeError("mid-spool failure")

    with pytest.raises(RuntimeError, match="mid-spool"):
        list(spooled_row_groups(rows(), lambda r: r["k"], bm,
                                est_bytes=1 << 20))
    assert bm.active_files == 0
    bm.cleanup()


def test_query_error_releases_spill_files(dataset, monkeypatch):
    """Regression (spill-file leak): an operator raising while partitions
    are being consumed must release every run file and all pinned bytes."""
    import repro.core.executor as ex
    db = _build(dataset, 32 << 10)
    real = ex._factorize
    calls = {"n": 0}

    def boom(results, idx=None):
        calls["n"] += 1
        if calls["n"] > 2:        # fail once partition processing started
            raise RuntimeError("boom")
        return real(results, idx)

    monkeypatch.setattr(ex, "_factorize", boom)
    with pytest.raises(RuntimeError, match="boom"):
        db.scan("t").group_by("k", "k2").agg(s=("sum", "v")).execute()
    assert db.buffer_manager.active_files == 0
    assert db.buffer_manager.stats.pinned == 0


def test_restart_reclaims_stale_spill_files(tmp_path):
    """Persistent mode: a crash (no shutdown()) leaves run files under
    <dbdir>/spill; reopening the directory reclaims them and queries run."""
    import repro.core.session as session
    rng = np.random.default_rng(2)
    p = str(tmp_path / "db")
    db = startup(p, memory_budget=32 << 10)
    db.create_table("t", {"k": rng.integers(0, 1000, 20_000),
                          "v": rng.normal(size=20_000)})
    # simulate dying mid-query: spill files are never released ...
    db.buffer_manager.release_file = lambda path: None
    db.scan("t").group_by("k").agg(s=("sum", "v")).execute()
    spill_dir = os.path.join(p, "spill")
    assert os.listdir(spill_dir), "expected stale run files on disk"
    # ... and both locks die with the process (process death closes the
    # flock'd fd exactly like release_lock does)
    session._open_dirs.pop(os.path.realpath(p))
    db.storage.release_lock()

    db2 = startup(p, memory_budget=32 << 10)
    assert os.listdir(spill_dir) == []           # reclaimed at open
    res = (db2.scan("t").group_by("k").agg(s=("sum", "v"))
           .execute().to_pydict())
    assert len(res["k"]) == 1000
    db2.shutdown()


def test_cleanup_spares_unregistered_files(tmp_path):
    """Regression: cleanup() on a db-owned spill dir used to unlink every
    file in the directory — including a concurrent query's run files.  Only
    files registered with this manager may be deleted."""
    from repro.core.buffers import BufferManager
    d = str(tmp_path / "spill")
    bm = BufferManager(budget=1 << 20, spill_dir=d)
    mine = bm.new_spill_file("mine")
    open(mine, "wb").write(b"x")
    other = os.path.join(bm.spill_dir, "concurrent.run.bin")
    open(other, "wb").write(b"y")
    bm.cleanup()
    assert not os.path.exists(mine)
    assert os.path.exists(other), "cleanup clobbered an unregistered file"


def test_choose_partitions_unlimited_budget():
    """Regression: choose_partitions(est, None) raised TypeError."""
    from repro.core.buffers import choose_partitions
    assert choose_partitions(1 << 30, None) == 2
    assert choose_partitions(0, 1 << 20) == 2


def test_recursive_repartition_on_oversized_partitions():
    """An input so large that even the maximum fan-out leaves every
    partition over budget: partitions must re-partition recursively (never
    fully resident), keep peak <= budget, and stay byte-identical."""
    rng = np.random.default_rng(5)
    n = 120_000
    data = {"a": rng.integers(0, 50_000, n).astype(np.int64),
            "b": rng.integers(0, 1000, n).astype(np.int64),
            "v": rng.normal(size=n)}
    budget = 16 << 10
    base = startup()
    base.create_table("t", data)
    db = startup(memory_budget=budget)
    db.create_table("t", data)
    q = lambda d: (d.scan("t").group_by("a", "b")
                   .agg(s=("sum", "v"), c=("count", None))
                   .execute().to_pydict())
    _assert_identical(q(base), q(db), "recursive repartition")
    st = db.buffer_manager.stats
    assert st.repartitions > 0, "expected oversized partitions to re-split"
    assert st.peak <= budget, (st.peak, budget)
    assert db.buffer_manager.active_files == 0


def test_prefetch_identity_hits_and_budget(dataset, baseline):
    """Double-buffered prefetch: identical results, prefetch_hits > 0, and
    the pinned double buffer never pushes peak past the budget; with
    spill_prefetch=False the pipeline is strictly sequential (zero hits)."""
    budget = 256 << 10
    db_on = _build(dataset, budget)                  # prefetch defaults on
    got_on = _queries(db_on)
    st_on = db_on.buffer_manager.stats
    db_off = _build(dataset, budget, spill_prefetch=False)
    got_off = _queries(db_off)
    st_off = db_off.buffer_manager.stats
    for qn in baseline:
        _assert_identical(baseline[qn], got_on[qn], f"prefetch-on q={qn}")
        _assert_identical(baseline[qn], got_off[qn], f"prefetch-off q={qn}")
    assert st_on.prefetch_hits > 0
    assert st_off.prefetch_hits == 0
    assert st_on.peak <= budget, (st_on.peak, budget)
    assert db_on.buffer_manager.active_files == 0


def test_codec_reduces_spilled_bytes_on_clustered_keys():
    """Acceptance: >=2x reduction in bytes actually written for a budgeted
    group-by over sorted/clustered int64 keys, with identical results; raw
    (logical) bytes are tracked separately in both modes."""
    rng = np.random.default_rng(9)
    n = 120_000
    data = {"k": np.sort(rng.integers(0, 5000, n)).astype(np.int64),
            "v": rng.normal(size=n)}
    out = {}
    for codec in ("raw", "for"):
        db = startup(memory_budget=256 << 10, spill_codec=codec)
        db.create_table("t", data)
        res = (db.scan("t").group_by("k").agg(s=("sum", "v"))
               .execute().to_pydict())
        st = db.buffer_manager.stats
        assert st.spilled_ops > 0
        assert st.bytes_spilled == st.bytes_spilled_compressed
        out[codec] = (res, st.bytes_spilled, st.bytes_spilled_raw)
    _assert_identical(out["raw"][0], out["for"][0], "codec identity")
    assert out["for"][2] == out["raw"][2]            # same logical bytes
    assert 2 * out["for"][1] <= out["raw"][1], \
        (out["for"][1], out["raw"][1])


def test_exec_stats_expose_per_query_spill_deltas(dataset):
    """ExecStats carries per-query spill-pipeline counters (the buffer
    manager's are database-lifetime cumulative)."""
    db = _build(dataset, 256 << 10)
    (db.scan("t").group_by("k", "w").agg(s=("sum", "v")).execute())
    st = db.last_stats
    assert st.spilled_ops > 0
    assert st.bytes_spilled_raw > 0
    assert st.bytes_spilled_compressed > 0
    assert st.prefetch_hits > 0


def test_giant_group_fallback_identity():
    """Heavy skew: one key tuple owns most rows, so its partition stays over
    budget and is unsplittable by key — recursion must detect the single
    distinct tuple (not rewrite the partition in futile passes) and fall
    back to whole-partition processing with identical results."""
    rng = np.random.default_rng(13)
    n = 120_000
    a = rng.integers(0, 50_000, n).astype(np.int64)
    b = rng.integers(0, 1000, n).astype(np.int64)
    a[:int(n * 0.6)] = 123                  # dominant composite key tuple
    b[:int(n * 0.6)] = 5
    data = {"a": a, "b": b, "v": rng.normal(size=n)}
    base = startup()
    base.create_table("t", data)
    db = startup(memory_budget=16 << 10)
    db.create_table("t", data)
    q = lambda d: (d.scan("t").group_by("a", "b")
                   .agg(s=("sum", "v"), c=("count", None))
                   .execute().to_pydict())
    _assert_identical(q(base), q(db), "giant-group fallback")
    st = db.buffer_manager.stats
    assert st.spilled_ops > 0
    assert st.repartitions > 0
    assert db.buffer_manager.active_files == 0


def test_on_disk_lock_blocks_foreign_process(tmp_path):
    """The "database locked" contract must hold on disk, across processes
    (the in-process registry cannot see other processes): while this
    process holds the flock, a second process is refused — so its
    open-time spill reclaim can never destroy our live run files — and
    after shutdown (or owner death, which drops the flock with the fd) the
    directory opens normally."""
    import subprocess
    import sys
    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    p = str(tmp_path / "db")
    code = ("from repro.core import startup\n"
            "from repro.core.session import DatabaseError\n"
            "try:\n"
            f"    startup({p!r}).shutdown()\n"
            "    print('OPENED')\n"
            "except DatabaseError as e:\n"
            "    assert 'locked' in str(e), e\n"
            "    print('REFUSED')\n")
    env = {**os.environ, "PYTHONPATH": src}
    other = lambda: subprocess.run([sys.executable, "-c", code], env=env,
                                   capture_output=True, text=True)

    db = startup(p)
    db.create_table("t", {"v": np.arange(5, dtype=np.int64)})
    out = other()
    assert out.stdout.strip() == "REFUSED", (out.stdout, out.stderr)
    db.shutdown()                            # drops the flock
    out = other()
    assert out.stdout.strip() == "OPENED", (out.stdout, out.stderr)

    # a failed open (bad knob, validated after locking) must not leave the
    # directory locked forever
    with pytest.raises(ValueError):
        startup(p, spill_codec="bogus")
    db3 = startup(p)                         # still openable
    assert db3.table("t").num_rows == 5
    db3.shutdown()


# ---------------------------------------------------------------------------
# VARCHAR spill tier: differential budget matrix, heap strategies, fingerprint
# ---------------------------------------------------------------------------

VARCHAR_BUDGETS = [None, 1 << 20, 64 << 10]    # unlimited / 1 MiB / 64 KiB


def _tpch_varchar_queries(db):
    """The TPC-H queries whose plans carry VARCHAR keys: Q1 groups on
    l_returnflag/l_linestatus, Q3 filters on c_mktsegment and joins."""
    from repro.data.tpch_queries import ALL_QUERIES
    return {qn: ALL_QUERIES[qn](db).execute().to_pydict()
            for qn in ("q1", "q3")}


@pytest.fixture(scope="module")
def tpch_varchar_baseline():
    from repro.data import tpch
    db = startup()
    tpch.load_into(db, sf=0.01, seed=3)
    return _tpch_varchar_queries(db)


@pytest.mark.outofcore
@pytest.mark.parametrize("budget", VARCHAR_BUDGETS)
def test_tpch_varchar_budget_matrix(tpch_varchar_baseline, budget):
    """Differential harness: the VARCHAR-keyed TPC-H queries must be
    bit-identical to the unlimited run at every budget, with the spill path
    provably engaged (spilled_ops > 0) at the tight budgets and the peak
    contract intact."""
    from repro.data import tpch
    db = startup(memory_budget=budget)
    tpch.load_into(db, sf=0.01, seed=3)
    got = _tpch_varchar_queries(db)
    for qn, want in tpch_varchar_baseline.items():
        _assert_identical(want, got[qn], f"budget={budget} {qn}")
    st = db.buffer_manager.stats
    if budget is None:
        assert st.spilled_ops == 0
    else:
        assert st.spilled_ops > 0, f"budget={budget} never spilled"
        assert st.peak <= budget, (st.peak, budget)
    assert db.buffer_manager.active_files == 0


@pytest.fixture(scope="module")
def vdataset():
    """String-keyed fact/dim whose VARCHAR columns are encoded against
    *distinct* heaps (separate loads, different value sets), with NULL keys
    mixed in — the shape the spill tier used to decline."""
    rng = np.random.default_rng(21)
    n = 60_000
    words = [f"{a}{b}{c}" for a in "abcdefghij" for b in "klmnopqrst"
             for c in "uvwxyz0123456789"]          # 1600 distinct strings
    pick = rng.integers(0, len(words), n)
    null_at = rng.random(n) < 0.07
    fact = {"s": [None if null_at[i] else words[pick[i]] for i in range(n)],
            "v": rng.normal(size=n),
            "k": rng.integers(0, 100, n)}
    dim_words = [words[i] for i in rng.permutation(len(words))[:1200]]
    dim = {"s": dim_words,
           "label": np.arange(len(dim_words), dtype=np.int64)}
    return fact, dim


def _vbuild(vdataset, budget):
    fact, dim = vdataset
    db = startup(memory_budget=budget)
    db.create_table("t", fact)
    db.create_table("d", dim)
    return db


def _vqueries(db):
    out = {}
    out["join"] = (db.scan("t").join(db.scan("d"), on="s")
                   .group_by("label").agg(sv=("sum", "v"), c=("count", None))
                   .execute().to_pydict())
    out["semi"] = (db.scan("t").join(db.scan("d"), on="s", how="semi")
                   .agg(c=("count", None)).execute().to_pydict())
    out["anti"] = (db.scan("t").join(db.scan("d"), on="s", how="anti")
                   .agg(c=("count", None)).execute().to_pydict())
    out["group"] = (db.scan("t").group_by("s", "k")
                    .agg(sv=("sum", "v"), c=("count", None))
                    .execute().to_pydict())
    out["sort"] = (db.scan("t").order_by("s", ("v", True), limit=2000)
                   .select("s", "v").execute().to_pydict())
    return out


@pytest.fixture(scope="module")
def vbaseline(vdataset):
    return _vqueries(_vbuild(vdataset, None))


@pytest.mark.parametrize("budget", VARCHAR_BUDGETS)
def test_varchar_distinct_heap_budget_matrix(vdataset, vbaseline, budget):
    """Join / group-by / sort over VARCHAR keys with distinct heaps:
    bit-identical results — values, NULLs, and the decoded (heap) contents
    of VARCHAR output columns — across the budget matrix, with
    ``varchar_spills`` proving the new path ran and ``peak <= budget``."""
    db = _vbuild(vdataset, budget)
    got = _vqueries(db)
    for qn in vbaseline:
        _assert_identical(vbaseline[qn], got[qn], f"budget={budget} q={qn}")
    st = db.buffer_manager.stats
    if budget is None:
        assert st.spilled_ops == 0 and st.varchar_spills == 0
    else:
        assert st.spilled_ops > 0 and st.varchar_spills > 0, \
            (budget, st.spilled_ops, st.varchar_spills)
        assert st.peak <= budget, (st.peak, budget)
    assert db.buffer_manager.active_files == 0


def test_varchar_join_strategy_selection(vdataset):
    """plan_varchar_join picks the strategy from the heap/budget ratio:
    distinct heaps merge into one shared dictionary when they fit the
    budget, fall back to decoded string bytes when they don't — and the
    merged dictionary is an order-preserving superset of both inputs."""
    from repro.core import spill
    from repro.core.expression import ExprResult
    from repro.core.types import DBType

    mk = lambda c: [ExprResult(c.data, DBType.VARCHAR, None, c.heap)]
    big = _vbuild(vdataset, 1 << 20)
    lres = mk(big.table("t").columns["s"])
    rres = mk(big.table("d").columns["s"])
    heap_bytes = lres[0].heap.nbytes() + rres[0].heap.nbytes()
    assert heap_bytes <= (1 << 20) // 4          # merge is affordable here

    plan = spill.plan_varchar_join(lres, rres, big.buffer_manager)
    assert plan[0][0] == "recode"
    merged, lmap, rmap = plan[0][1], plan[0][2], plan[0][3]
    mvals = [str(v) for v in merged.values[1:]]
    assert mvals == sorted(mvals)                # order-preserving codes
    assert set(str(v) for v in lres[0].heap.values[1:]) <= set(mvals)
    assert set(str(v) for v in rres[0].heap.values[1:]) <= set(mvals)
    # recode maps preserve NULL and value identity
    assert lmap[0] == 0 and rmap[0] == 0
    assert [str(merged.values[c]) for c in lmap[1:]] \
        == [str(v) for v in lres[0].heap.values[1:]]
    assert [str(merged.values[c]) for c in rmap[1:]] \
        == [str(v) for v in rres[0].heap.values[1:]]

    tight = _vbuild(vdataset, 64 << 10)
    assert heap_bytes > (64 << 10) // 4          # merge would blow the budget
    plan = spill.plan_varchar_join(lres, rres, tight.buffer_manager)
    assert plan[0] == ("decode",)


def test_separately_loaded_copies_take_code_fast_path(monkeypatch):
    """Regression: the spill tier used to decline VARCHAR joins whenever the
    two heap *objects* differed (``lr.heap is not rr.heap``), so two
    separately-loaded copies of the same table fell back to fully-resident
    execution.  The content fingerprint routes them through the partitioned
    fast path on plain int32 codes — no heap merge, no string decode."""
    from repro.core import spill
    from repro.core.column import StringHeap
    from repro.core.expression import ExprResult
    from repro.core.types import DBType

    rng = np.random.default_rng(31)
    vals = [f"key{i:04d}" for i in range(800)]
    keys = [vals[i] for i in rng.integers(0, 800, 20_000)]
    budget = 48 << 10
    base = startup()
    db = startup(memory_budget=budget)
    for d in (base, db):
        d.create_table("a", {"s": list(keys),
                             "v": np.arange(20_000, dtype=np.int64)})
        d.create_table("b", {"s": list(keys)})
    ca, cb = db.table("a").columns["s"], db.table("b").columns["s"]
    assert ca.heap is not cb.heap                # genuinely separate objects
    assert ca.heap.content_equal(cb.heap)
    mk = lambda c: [ExprResult(c.data, DBType.VARCHAR, None, c.heap)]
    plan = spill.plan_varchar_join(mk(ca), mk(cb), db.buffer_manager)
    assert plan == [("codes",)]

    q = lambda d: (d.scan("a").join(d.scan("b"), on="s", how="semi")
                   .agg(c=("count", None), sv=("sum", "v"))
                   .execute().to_pydict())
    want = q(base)
    monkeypatch.setattr(StringHeap, "merge",
                        lambda *a, **k: pytest.fail("merge on fast path"))
    monkeypatch.setattr(StringHeap, "decode",
                        lambda *a, **k: pytest.fail("decode on fast path"))
    got = q(db)
    _assert_identical(want, got, "separately-loaded copies")
    st = db.buffer_manager.stats
    assert st.spilled_ops > 0 and st.varchar_spills > 0
    assert st.peak <= budget


def test_varchar_join_recursive_repartition():
    """Long string keys make decoded partitions outgrow the budget even at
    the maximum spool fan-out: join partition pairs must re-split
    recursively (re-salted hash) and keep peak <= budget with identical
    results for every join flavor."""
    rng = np.random.default_rng(5)
    n = 40_000
    words = [f"verylongstringkeypayload-{i:06d}-{'x' * 24}"
             for i in range(4000)]
    data = {"s": [words[i] for i in rng.integers(0, 4000, n)],
            "v": rng.normal(size=n)}
    dim = {"s": [words[i] for i in rng.integers(0, 3000, 3000)],
           "m": np.arange(3000, dtype=np.int64)}
    budget = 48 << 10

    def build(b):
        db = startup(memory_budget=b)
        db.create_table("t", data)
        db.create_table("d", dim)
        return db

    def q(db, how):
        qq = db.scan("t").join(db.scan("d"), on="s", how=how)
        if how in ("semi", "anti"):
            return qq.agg(c=("count", None)).execute().to_pydict()
        return (qq.group_by("m").agg(sv=("sum", "v"), c=("count", None))
                .execute().to_pydict())

    base = build(None)
    db = build(budget)
    for how in ("inner", "semi", "anti"):
        _assert_identical(q(base, how), q(db, how), f"repartition {how}")
    st = db.buffer_manager.stats
    assert st.repartitions > 0, "expected join pairs to re-split"
    assert st.varchar_spills > 0
    assert st.peak <= budget, (st.peak, budget)
    assert db.buffer_manager.active_files == 0
    # left-join identity on a fresh db: grouping its unmatched (NULL) rows
    # takes the pre-existing giant-group fallback, exempt from the peak
    # contract
    db2 = build(budget)
    _assert_identical(q(base, "left"), q(db2, "left"), "repartition left")
    assert db2.buffer_manager.active_files == 0


def test_string_block_codec_roundtrip():
    """The offsets+bytes string codec round-trips object arrays through
    files and byte streams: unicode, empty strings, long values, empty
    blocks — mixed with integer blocks in one stream protocol."""
    import io
    from repro.core import buffers
    cases = [
        np.asarray(["", "a", "päper", "日本語テキスト", "x" * 4096, "tab\there"],
                   dtype=object),
        np.asarray(["dup", "dup", "dup"], dtype=object),
        np.empty(0, dtype=object),
    ]
    for arr in cases:
        for codec in (buffers.CODEC_RAW, buffers.CODEC_FOR):
            blk = buffers.encode_block(arr, codec)
            out = buffers.decode_stream(blk, object)
            assert out.dtype == object
            assert list(out) == list(arr)
    # multi-block stream through the file API, with spill accounting
    bm = buffers.BufferManager(budget=1 << 20)
    f = io.BytesIO()
    a = np.asarray([f"s{i}" for i in range(1000)], dtype=object)
    buffers.write_stream_block(f, a[:500], buffers.CODEC_FOR, bm)
    buffers.write_stream_block(f, a[500:], buffers.CODEC_FOR, bm)
    f.seek(0)
    first = buffers.read_stream_block(f, object)
    second = buffers.read_stream_block(f, object)
    assert list(first) + list(second) == list(a)
    assert buffers.read_stream_block(f, object) is None
    assert bm.stats.bytes_spilled_raw == buffers.logical_nbytes(a)
    bm.cleanup()


def test_exec_stats_varchar_spills(vdataset):
    """ExecStats mirrors the varchar spill counter per query, and the
    transaction-scoped connection path threads it to the parent database."""
    db = _vbuild(vdataset, 64 << 10)
    (db.scan("t").join(db.scan("d"), on="s")
     .agg(c=("count", None)).execute())
    assert db.last_stats.spilled_ops > 0
    assert db.last_stats.varchar_spills > 0

    con = db.connect()
    con.begin()
    res = con.query("SELECT s, k, count(*) AS c, sum(v) AS sv FROM t "
                    "GROUP BY s, k")
    assert res.nrows > 0
    assert db.last_stats is not None
    assert db.last_stats.varchar_spills > 0     # threaded from the snapshot
    con.rollback()


def test_volcano_varchar_spool_estimate():
    """Regression (volcano routing): estimate_bytes assumes 8 bytes per
    column, but volcano rows hold *decoded* strings — a string-heavy
    aggregate under-estimated and stayed fully resident.  The VARCHAR
    surcharge (average decoded heap width) must push it onto the spooled
    path with identical output, counted in varchar_spills."""
    from repro.core.optimizer import optimize
    from repro.core.volcano import VolcanoExecutor
    rng = np.random.default_rng(7)
    n = 4000
    keys = [f"customer-comment-string-{i % 600:04d}-{'y' * 40}"
            for i in range(n)]
    vals = rng.normal(size=n).tolist()
    base = startup()
    db = startup(memory_budget=128 << 10)
    for d in (base, db):
        d.create_table("t", {"s": list(keys), "v": list(vals)})
    # the flat estimate (4000 rows x 2 cols x 8 B = 62.5 KiB) fits the
    # budget; only the ~70 B decoded strings push it over
    from repro.core.optimizer import estimate_bytes
    plan = (db.scan("t").group_by("s")
            .agg(sv=("sum", "v"), c=("count", None)).plan)
    flat = estimate_bytes(optimize(plan, db.catalog).children[0], db.catalog)
    assert flat <= 128 << 10
    rows_mem = VolcanoExecutor(base).execute(optimize(plan, base.catalog))
    rows_ooc = VolcanoExecutor(db).execute(optimize(plan, db.catalog))
    assert rows_mem == rows_ooc
    st = db.buffer_manager.stats
    assert st.spilled_ops > 0 and st.varchar_spills > 0
    assert db.buffer_manager.active_files == 0
