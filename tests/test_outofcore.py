"""Out-of-core execution tier (core/buffers.py + core/spill.py).

Contracts under test:

* spill execution is **byte-identical** to in-memory execution for every
  blocking operator (aggregate / all join flavors / sort, with and without
  limit) across a matrix of memory budgets;
* tracked peak buffer usage stays <= the configured budget;
* every spill file is reclaimed by query end (and the spill dir lives under
  the database directory in persistent mode);
* a query whose intermediates exceed the budget completes instead of
  requiring them resident.
"""

import os

import numpy as np
import pytest

from repro.core import Col, startup

N = 40_000
BUDGETS = [None, 50 << 20, 256 << 10, 32 << 10]


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    fact = {
        "k": rng.integers(0, 500, N),
        "k2": rng.integers(0, 7, N),
        "v": rng.normal(size=N),
        "w": rng.integers(-100, 100, N),
    }
    dim = {"dk": np.arange(500, dtype=np.int64),
           "label": rng.integers(0, 3, 500)}
    return fact, dim


def _build(dataset, budget, **kw):
    fact, dim = dataset
    db = startup(memory_budget=budget, **kw)
    db.create_table("t", fact)
    db.create_table("d", dim)
    return db


def _queries(db):
    """One query per blocking-operator shape the spill tier covers."""
    out = {}
    out["agg"] = (db.scan("t").filter(Col("v") > -1.0).group_by("k", "k2")
                  .agg(s=("sum", "v"), c=("count", None), mn=("min", "w"),
                       mx=("max", "w"), a=("avg", "v"), md=("median", "v"),
                       cd=("count_distinct", "w"))
                  .execute().to_pydict())
    out["join"] = (db.scan("t")
                   .join(db.scan("d"), left_on="k", right_on="dk")
                   .group_by("label").agg(s=("sum", "v"), c=("count", None))
                   .execute().to_pydict())
    out["leftjoin"] = (db.scan("d")
                       .join(db.scan("t"), left_on="dk", right_on="k",
                             how="left")
                       .group_by("label").agg(c=("count", "v"))
                       .execute().to_pydict())
    out["semi"] = (db.scan("t")
                   .join(db.scan("d").filter(Col("label") > 0),
                         left_on="k", right_on="dk", how="semi")
                   .agg(c=("count", None)).execute().to_pydict())
    out["anti"] = (db.scan("t")
                   .join(db.scan("d").filter(Col("label") > 0),
                         left_on="k", right_on="dk", how="anti")
                   .agg(c=("count", None)).execute().to_pydict())
    out["topn"] = (db.scan("t").order_by(("v", True), "w", limit=1000)
                   .select("k", "v", "w").execute().to_pydict())
    out["fullsort"] = (db.scan("t").order_by("k2", ("w", True))
                       .select("k2", "w", "v").execute().to_pydict())
    return out


def _assert_identical(a: dict, b: dict, ctx: str):
    assert list(a) == list(b), ctx
    for c in a:
        if a[c].dtype == object:
            assert list(map(str, a[c])) == list(map(str, b[c])), (ctx, c)
        else:
            np.testing.assert_array_equal(a[c], b[c],
                                          err_msg=f"{ctx} col={c}")


@pytest.fixture(scope="module")
def baseline(dataset):
    return _queries(_build(dataset, None))


@pytest.mark.parametrize("budget", BUDGETS)
def test_budget_matrix_byte_identical(dataset, baseline, budget):
    db = _build(dataset, budget)
    got = _queries(db)
    for qn in baseline:
        _assert_identical(baseline[qn], got[qn], f"budget={budget} q={qn}")
    st = db.buffer_manager.stats
    if budget is not None:
        assert st.peak <= budget, (st.peak, budget)
    if budget is not None and budget <= 256 << 10:
        # working sets above these budgets: the spill tier must engage
        assert st.spilled_ops > 0
        assert st.bytes_spilled > 0
    if budget is None or budget >= 50 << 20:
        assert st.spilled_ops == 0       # fitting inputs: no spill overhead
    # spill-file lifecycle: everything reclaimed by query end
    assert db.buffer_manager.active_files == 0


def test_exceeding_budget_completes(dataset):
    """The acceptance query: aggregate-join over data larger than the
    budget completes with spilling and matches the in-memory result."""
    fact, dim = dataset
    budget = 64 << 10
    assert sum(a.nbytes for a in fact.values()) > budget
    db = _build(dataset, budget)
    base = _build(dataset, None)
    q = lambda d: (d.scan("t")
                   .join(d.scan("d"), left_on="k", right_on="dk")
                   .group_by("k", "w")          # high-cardinality state
                   .agg(s=("sum", "v"), c=("count", None))
                   .order_by(("s", True))
                   .execute().to_pydict())
    _assert_identical(q(base), q(db), "agg-join-sort over budget")
    st = db.buffer_manager.stats
    assert st.spilled_ops >= 3          # join, group and sort all spilled
    assert st.peak <= budget
    assert db.buffer_manager.active_files == 0


def test_spill_dir_under_database_directory(tmp_path):
    """Persistent mode: run files live under <dbdir>/spill and are gone
    after the query; shutdown clears the directory."""
    rng = np.random.default_rng(1)
    db = startup(str(tmp_path / "db"), memory_budget=32 << 10)
    db.create_table("t", {"k": rng.integers(0, 1000, 20_000),
                          "v": rng.normal(size=20_000)})
    spill_dir = os.path.join(str(tmp_path / "db"), "spill")

    seen = {"files": 0}
    bm = db.buffer_manager
    orig = bm.new_spill_file

    def counting(hint="run"):
        seen["files"] += 1
        return orig(hint)

    bm.new_spill_file = counting
    res = (db.scan("t").group_by("k").agg(s=("sum", "v"))
           .execute().to_pydict())
    assert len(res["k"]) == 1000
    assert seen["files"] > 0, "expected the query to spill"
    assert os.path.isdir(spill_dir)
    assert os.listdir(spill_dir) == []       # reclaimed at query end
    db.shutdown()
    assert bm.active_files == 0


def test_memory_budget_api():
    db = startup()
    assert db.memory_budget is None and db.buffer_manager.budget is None
    db2 = startup(memory_budget=1 << 20)
    assert db2.memory_budget == 1 << 20
    with pytest.raises(ValueError):
        startup(memory_budget=0)


def test_sql_path_spills_identically(dataset):
    sql = ("SELECT k2, count(*) AS n, sum(v) AS s FROM t "
           "WHERE w > 0 GROUP BY k2, k ORDER BY s DESC")
    a = _build(dataset, None).connect().query(sql).to_pydict()
    db = _build(dataset, 32 << 10)
    b = db.connect().query(sql).to_pydict()
    for c in a:
        np.testing.assert_array_equal(a[c], b[c], err_msg=c)
    assert db.buffer_manager.stats.spilled_ops > 0


def test_volcano_spooled_aggregation(dataset):
    """The row-at-a-time baseline engine also honors the budget: grouping
    spools pickled row partitions and yields identical output."""
    from repro.core.optimizer import optimize
    from repro.core.volcano import VolcanoExecutor
    base = _build(dataset, None)
    db = _build(dataset, 32 << 10)
    plan = (db.scan("t").group_by("k")
            .agg(s=("sum", "v"), c=("count", None)).plan)
    rows_mem = VolcanoExecutor(base).execute(optimize(plan, base.catalog))
    spilled0 = db.buffer_manager.stats.bytes_spilled
    rows_ooc = VolcanoExecutor(db).execute(optimize(plan, db.catalog))
    assert rows_mem == rows_ooc
    assert db.buffer_manager.stats.bytes_spilled > spilled0
    assert db.buffer_manager.active_files == 0


def test_low_cardinality_group_stays_in_memory(dataset):
    """Grouping state for few distinct keys is tiny: the runtime probe must
    keep it in memory even when the *input* exceeds the budget (spilling
    could never split the dominant groups anyway)."""
    db = _build(dataset, 32 << 10)
    base = _build(dataset, None)
    q = lambda d: (d.scan("t").group_by("k2")
                   .agg(s=("sum", "v")).execute().to_pydict())
    _assert_identical(q(base), q(db), "low-card group")
    st = db.buffer_manager.stats
    assert st.spilled_ops == 0


def test_small_budget_peak_contract(dataset):
    """Sub-32KiB budgets must also hold peak <= budget (regression: the
    old 1024-row morsel/run floors pinned 24KiB regardless of budget)."""
    db = _build(dataset, 16 << 10)
    (db.scan("t").group_by("k", "w").agg(s=("sum", "v"))
     .order_by(("s", True)).execute())
    st = db.buffer_manager.stats
    assert st.spilled_ops >= 2
    assert st.peak <= 16 << 10, st.peak


@pytest.mark.outofcore
def test_sort_cascade_merge_bounded_fds():
    """More sort runs than the merge fan-in (regression: the merge once
    opened every run at once and hit EMFILE on large inputs): cascade
    passes must kick in and the result must stay identical."""
    from repro.core import spill
    rng = np.random.default_rng(11)
    n = 150_000
    vals = {"v": rng.normal(size=n), "k": rng.integers(0, 1000, n)}
    base = startup()
    base.create_table("t", vals)
    db = startup(memory_budget=32 << 10)
    db.create_table("t", vals)
    # 32 KiB budget, 16 B/row -> 1024-row runs -> ~147 runs > fan-in of 64
    assert n / ((32 << 10) // 2 // 16) > spill.SORT_MERGE_FAN_IN
    q = lambda d: (d.scan("t").order_by("v", ("k", True))
                   .select("v", "k").execute().to_pydict())
    _assert_identical(q(base), q(db), "cascade sort")
    assert db.buffer_manager.stats.spilled_ops == 1
    assert db.buffer_manager.active_files == 0


@pytest.mark.outofcore
@pytest.mark.slow
def test_stress_much_larger_than_budget():
    """~10 MB of blocking intermediates through a 1 MB budget."""
    rng = np.random.default_rng(7)
    n = 200_000
    fact = {"k": rng.integers(0, 20_000, n), "v": rng.normal(size=n),
            "w": rng.integers(0, 1_000_000, n)}
    budget = 1 << 20
    base = startup()
    base.create_table("t", fact)
    db = startup(memory_budget=budget)
    db.create_table("t", fact)
    q = lambda d: (d.scan("t").group_by("k")
                   .agg(s=("sum", "v"), mx=("max", "w"))
                   .order_by(("s", True), limit=500).execute().to_pydict())
    _assert_identical(q(base), q(db), "stress-agg")
    q2 = lambda d: (d.scan("t").order_by(("v", True), "w", limit=500)
                    .select("k", "v").execute().to_pydict())
    _assert_identical(q2(base), q2(db), "stress-sort")
    st = db.buffer_manager.stats
    assert st.spilled_ops >= 2
    assert st.peak <= budget
    assert db.buffer_manager.active_files == 0


# ---------------------------------------------------------------------------
# spill pipeline v2: codec, prefetch, recursive repartitioning, leak fixes
# ---------------------------------------------------------------------------


def test_spill_codec_roundtrip_bit_exact():
    """FOR + byte-shuffle blocks decode to the identical bit pattern across
    sorted/clustered/random/sentinel/empty integer streams and float
    passthrough (floats never go through FOR)."""
    from repro.core import buffers
    rng = np.random.default_rng(0)
    cases = [
        np.arange(10_000, dtype=np.int64),                        # sorted
        np.arange(10_000, dtype=np.int64) // 7 + 1_000_000,       # clustered
        rng.integers(-2**62, 2**62, 1000),                        # wide random
        np.array([-2**63, 2**63 - 1, 0, -1], dtype=np.int64),     # sentinels
        np.array([2**53 + 1, 2**53 + 3, 2**62 + 5], dtype=np.int64),
        np.array([2**63, 2**64 - 1, 2**63 + 7], dtype=np.uint64),
        np.arange(100, dtype=np.int32) - 50,
        np.zeros(0, dtype=np.int64),
        rng.normal(size=1000),                                    # float raw
    ]
    for a in cases:
        a = np.asarray(a)
        blk = buffers.encode_block(a, buffers.CODEC_FOR)
        out = buffers.decode_stream(blk, a.dtype)
        assert out.dtype == a.dtype
        np.testing.assert_array_equal(out, a)
    # clustered int64 really shrinks: 0..65535 needs 2 of 8 byte planes
    a = np.arange(65536, dtype=np.int64)
    assert len(buffers.encode_block(a, buffers.CODEC_FOR)) < a.nbytes / 2
    # incompressible data falls back to a raw block (never grows past
    # payload + header)
    r = rng.integers(-2**62, 2**62, 4096)
    assert len(buffers.encode_block(r, buffers.CODEC_FOR)) \
        <= r.nbytes + buffers.BLOCK_HEADER_BYTES


def test_sort_run_index_bit_exact_past_2_53():
    """Regression: run files stored the row index as float64, silently
    rounding indexes past 2^53; the index stream is now native int64 and
    must round-trip bit-exactly."""
    from repro.core import spill
    from repro.core.buffers import BufferManager
    bm = BufferManager(budget=1 << 20)
    idx = np.array([0, 2**53 + 1, 2**53 + 3, 2**62 + 12345], dtype=np.int64)
    assert int(np.float64(2**53 + 1)) != 2**53 + 1   # float64 would corrupt
    keys = [np.array([1.0, 2.0, 3.0, 4.0])]
    path = spill._write_sort_run(bm, keys, idx)
    streamed = [t[-1] for t in spill._iter_sort_run(path, 1)]
    assert streamed == idx.tolist()
    np.testing.assert_array_equal(spill._run_index_column(path, 1), idx)
    bm.cleanup()


def test_spool_error_releases_files():
    """Regression (spill-file leak): an input iterator that raises mid-spool
    must leave zero registered run files — not park them until cleanup()."""
    from repro.core.buffers import BufferManager
    from repro.core.spill import spooled_row_groups

    bm = BufferManager(budget=32 << 10)

    def rows():
        for i in range(5000):
            yield {"k": i % 7, "v": float(i)}
        raise RuntimeError("mid-spool failure")

    with pytest.raises(RuntimeError, match="mid-spool"):
        list(spooled_row_groups(rows(), lambda r: r["k"], bm,
                                est_bytes=1 << 20))
    assert bm.active_files == 0
    bm.cleanup()


def test_query_error_releases_spill_files(dataset, monkeypatch):
    """Regression (spill-file leak): an operator raising while partitions
    are being consumed must release every run file and all pinned bytes."""
    import repro.core.executor as ex
    db = _build(dataset, 32 << 10)
    real = ex._factorize
    calls = {"n": 0}

    def boom(results, idx=None):
        calls["n"] += 1
        if calls["n"] > 2:        # fail once partition processing started
            raise RuntimeError("boom")
        return real(results, idx)

    monkeypatch.setattr(ex, "_factorize", boom)
    with pytest.raises(RuntimeError, match="boom"):
        db.scan("t").group_by("k", "k2").agg(s=("sum", "v")).execute()
    assert db.buffer_manager.active_files == 0
    assert db.buffer_manager.stats.pinned == 0


def test_restart_reclaims_stale_spill_files(tmp_path):
    """Persistent mode: a crash (no shutdown()) leaves run files under
    <dbdir>/spill; reopening the directory reclaims them and queries run."""
    import repro.core.session as session
    rng = np.random.default_rng(2)
    p = str(tmp_path / "db")
    db = startup(p, memory_budget=32 << 10)
    db.create_table("t", {"k": rng.integers(0, 1000, 20_000),
                          "v": rng.normal(size=20_000)})
    # simulate dying mid-query: spill files are never released ...
    db.buffer_manager.release_file = lambda path: None
    db.scan("t").group_by("k").agg(s=("sum", "v")).execute()
    spill_dir = os.path.join(p, "spill")
    assert os.listdir(spill_dir), "expected stale run files on disk"
    # ... and both locks die with the process (process death closes the
    # flock'd fd exactly like release_lock does)
    session._open_dirs.pop(os.path.realpath(p))
    db.storage.release_lock()

    db2 = startup(p, memory_budget=32 << 10)
    assert os.listdir(spill_dir) == []           # reclaimed at open
    res = (db2.scan("t").group_by("k").agg(s=("sum", "v"))
           .execute().to_pydict())
    assert len(res["k"]) == 1000
    db2.shutdown()


def test_cleanup_spares_unregistered_files(tmp_path):
    """Regression: cleanup() on a db-owned spill dir used to unlink every
    file in the directory — including a concurrent query's run files.  Only
    files registered with this manager may be deleted."""
    from repro.core.buffers import BufferManager
    d = str(tmp_path / "spill")
    bm = BufferManager(budget=1 << 20, spill_dir=d)
    mine = bm.new_spill_file("mine")
    open(mine, "wb").write(b"x")
    other = os.path.join(bm.spill_dir, "concurrent.run.bin")
    open(other, "wb").write(b"y")
    bm.cleanup()
    assert not os.path.exists(mine)
    assert os.path.exists(other), "cleanup clobbered an unregistered file"


def test_choose_partitions_unlimited_budget():
    """Regression: choose_partitions(est, None) raised TypeError."""
    from repro.core.buffers import choose_partitions
    assert choose_partitions(1 << 30, None) == 2
    assert choose_partitions(0, 1 << 20) == 2


def test_recursive_repartition_on_oversized_partitions():
    """An input so large that even the maximum fan-out leaves every
    partition over budget: partitions must re-partition recursively (never
    fully resident), keep peak <= budget, and stay byte-identical."""
    rng = np.random.default_rng(5)
    n = 120_000
    data = {"a": rng.integers(0, 50_000, n).astype(np.int64),
            "b": rng.integers(0, 1000, n).astype(np.int64),
            "v": rng.normal(size=n)}
    budget = 16 << 10
    base = startup()
    base.create_table("t", data)
    db = startup(memory_budget=budget)
    db.create_table("t", data)
    q = lambda d: (d.scan("t").group_by("a", "b")
                   .agg(s=("sum", "v"), c=("count", None))
                   .execute().to_pydict())
    _assert_identical(q(base), q(db), "recursive repartition")
    st = db.buffer_manager.stats
    assert st.repartitions > 0, "expected oversized partitions to re-split"
    assert st.peak <= budget, (st.peak, budget)
    assert db.buffer_manager.active_files == 0


def test_prefetch_identity_hits_and_budget(dataset, baseline):
    """Double-buffered prefetch: identical results, prefetch_hits > 0, and
    the pinned double buffer never pushes peak past the budget; with
    spill_prefetch=False the pipeline is strictly sequential (zero hits)."""
    budget = 256 << 10
    db_on = _build(dataset, budget)                  # prefetch defaults on
    got_on = _queries(db_on)
    st_on = db_on.buffer_manager.stats
    db_off = _build(dataset, budget, spill_prefetch=False)
    got_off = _queries(db_off)
    st_off = db_off.buffer_manager.stats
    for qn in baseline:
        _assert_identical(baseline[qn], got_on[qn], f"prefetch-on q={qn}")
        _assert_identical(baseline[qn], got_off[qn], f"prefetch-off q={qn}")
    assert st_on.prefetch_hits > 0
    assert st_off.prefetch_hits == 0
    assert st_on.peak <= budget, (st_on.peak, budget)
    assert db_on.buffer_manager.active_files == 0


def test_codec_reduces_spilled_bytes_on_clustered_keys():
    """Acceptance: >=2x reduction in bytes actually written for a budgeted
    group-by over sorted/clustered int64 keys, with identical results; raw
    (logical) bytes are tracked separately in both modes."""
    rng = np.random.default_rng(9)
    n = 120_000
    data = {"k": np.sort(rng.integers(0, 5000, n)).astype(np.int64),
            "v": rng.normal(size=n)}
    out = {}
    for codec in ("raw", "for"):
        db = startup(memory_budget=256 << 10, spill_codec=codec)
        db.create_table("t", data)
        res = (db.scan("t").group_by("k").agg(s=("sum", "v"))
               .execute().to_pydict())
        st = db.buffer_manager.stats
        assert st.spilled_ops > 0
        assert st.bytes_spilled == st.bytes_spilled_compressed
        out[codec] = (res, st.bytes_spilled, st.bytes_spilled_raw)
    _assert_identical(out["raw"][0], out["for"][0], "codec identity")
    assert out["for"][2] == out["raw"][2]            # same logical bytes
    assert 2 * out["for"][1] <= out["raw"][1], \
        (out["for"][1], out["raw"][1])


def test_exec_stats_expose_per_query_spill_deltas(dataset):
    """ExecStats carries per-query spill-pipeline counters (the buffer
    manager's are database-lifetime cumulative)."""
    db = _build(dataset, 256 << 10)
    (db.scan("t").group_by("k", "w").agg(s=("sum", "v")).execute())
    st = db.last_stats
    assert st.spilled_ops > 0
    assert st.bytes_spilled_raw > 0
    assert st.bytes_spilled_compressed > 0
    assert st.prefetch_hits > 0


def test_giant_group_fallback_identity():
    """Heavy skew: one key tuple owns most rows, so its partition stays over
    budget and is unsplittable by key — recursion must detect the single
    distinct tuple (not rewrite the partition in futile passes) and fall
    back to whole-partition processing with identical results."""
    rng = np.random.default_rng(13)
    n = 120_000
    a = rng.integers(0, 50_000, n).astype(np.int64)
    b = rng.integers(0, 1000, n).astype(np.int64)
    a[:int(n * 0.6)] = 123                  # dominant composite key tuple
    b[:int(n * 0.6)] = 5
    data = {"a": a, "b": b, "v": rng.normal(size=n)}
    base = startup()
    base.create_table("t", data)
    db = startup(memory_budget=16 << 10)
    db.create_table("t", data)
    q = lambda d: (d.scan("t").group_by("a", "b")
                   .agg(s=("sum", "v"), c=("count", None))
                   .execute().to_pydict())
    _assert_identical(q(base), q(db), "giant-group fallback")
    st = db.buffer_manager.stats
    assert st.spilled_ops > 0
    assert st.repartitions > 0
    assert db.buffer_manager.active_files == 0


def test_on_disk_lock_blocks_foreign_process(tmp_path):
    """The "database locked" contract must hold on disk, across processes
    (the in-process registry cannot see other processes): while this
    process holds the flock, a second process is refused — so its
    open-time spill reclaim can never destroy our live run files — and
    after shutdown (or owner death, which drops the flock with the fd) the
    directory opens normally."""
    import subprocess
    import sys
    import repro
    src = os.path.dirname(os.path.dirname(os.path.abspath(repro.__file__)))
    p = str(tmp_path / "db")
    code = ("from repro.core import startup\n"
            "from repro.core.session import DatabaseError\n"
            "try:\n"
            f"    startup({p!r}).shutdown()\n"
            "    print('OPENED')\n"
            "except DatabaseError as e:\n"
            "    assert 'locked' in str(e), e\n"
            "    print('REFUSED')\n")
    env = {**os.environ, "PYTHONPATH": src}
    other = lambda: subprocess.run([sys.executable, "-c", code], env=env,
                                   capture_output=True, text=True)

    db = startup(p)
    db.create_table("t", {"v": np.arange(5, dtype=np.int64)})
    out = other()
    assert out.stdout.strip() == "REFUSED", (out.stdout, out.stderr)
    db.shutdown()                            # drops the flock
    out = other()
    assert out.stdout.strip() == "OPENED", (out.stdout, out.stderr)

    # a failed open (bad knob, validated after locking) must not leave the
    # directory locked forever
    with pytest.raises(ValueError):
        startup(p, spill_codec="bogus")
    db3 = startup(p)                         # still openable
    assert db3.table("t").num_rows == 5
    db3.shutdown()
