"""Out-of-core execution tier (core/buffers.py + core/spill.py).

Contracts under test:

* spill execution is **byte-identical** to in-memory execution for every
  blocking operator (aggregate / all join flavors / sort, with and without
  limit) across a matrix of memory budgets;
* tracked peak buffer usage stays <= the configured budget;
* every spill file is reclaimed by query end (and the spill dir lives under
  the database directory in persistent mode);
* a query whose intermediates exceed the budget completes instead of
  requiring them resident.
"""

import os

import numpy as np
import pytest

from repro.core import Col, startup

N = 40_000
BUDGETS = [None, 50 << 20, 256 << 10, 32 << 10]


@pytest.fixture(scope="module")
def dataset():
    rng = np.random.default_rng(0)
    fact = {
        "k": rng.integers(0, 500, N),
        "k2": rng.integers(0, 7, N),
        "v": rng.normal(size=N),
        "w": rng.integers(-100, 100, N),
    }
    dim = {"dk": np.arange(500, dtype=np.int64),
           "label": rng.integers(0, 3, 500)}
    return fact, dim


def _build(dataset, budget):
    fact, dim = dataset
    db = startup(memory_budget=budget)
    db.create_table("t", fact)
    db.create_table("d", dim)
    return db


def _queries(db):
    """One query per blocking-operator shape the spill tier covers."""
    out = {}
    out["agg"] = (db.scan("t").filter(Col("v") > -1.0).group_by("k", "k2")
                  .agg(s=("sum", "v"), c=("count", None), mn=("min", "w"),
                       mx=("max", "w"), a=("avg", "v"), md=("median", "v"),
                       cd=("count_distinct", "w"))
                  .execute().to_pydict())
    out["join"] = (db.scan("t")
                   .join(db.scan("d"), left_on="k", right_on="dk")
                   .group_by("label").agg(s=("sum", "v"), c=("count", None))
                   .execute().to_pydict())
    out["leftjoin"] = (db.scan("d")
                       .join(db.scan("t"), left_on="dk", right_on="k",
                             how="left")
                       .group_by("label").agg(c=("count", "v"))
                       .execute().to_pydict())
    out["semi"] = (db.scan("t")
                   .join(db.scan("d").filter(Col("label") > 0),
                         left_on="k", right_on="dk", how="semi")
                   .agg(c=("count", None)).execute().to_pydict())
    out["anti"] = (db.scan("t")
                   .join(db.scan("d").filter(Col("label") > 0),
                         left_on="k", right_on="dk", how="anti")
                   .agg(c=("count", None)).execute().to_pydict())
    out["topn"] = (db.scan("t").order_by(("v", True), "w", limit=1000)
                   .select("k", "v", "w").execute().to_pydict())
    out["fullsort"] = (db.scan("t").order_by("k2", ("w", True))
                       .select("k2", "w", "v").execute().to_pydict())
    return out


def _assert_identical(a: dict, b: dict, ctx: str):
    assert list(a) == list(b), ctx
    for c in a:
        if a[c].dtype == object:
            assert list(map(str, a[c])) == list(map(str, b[c])), (ctx, c)
        else:
            np.testing.assert_array_equal(a[c], b[c],
                                          err_msg=f"{ctx} col={c}")


@pytest.fixture(scope="module")
def baseline(dataset):
    return _queries(_build(dataset, None))


@pytest.mark.parametrize("budget", BUDGETS)
def test_budget_matrix_byte_identical(dataset, baseline, budget):
    db = _build(dataset, budget)
    got = _queries(db)
    for qn in baseline:
        _assert_identical(baseline[qn], got[qn], f"budget={budget} q={qn}")
    st = db.buffer_manager.stats
    if budget is not None:
        assert st.peak <= budget, (st.peak, budget)
    if budget is not None and budget <= 256 << 10:
        # working sets above these budgets: the spill tier must engage
        assert st.spilled_ops > 0
        assert st.bytes_spilled > 0
    if budget is None or budget >= 50 << 20:
        assert st.spilled_ops == 0       # fitting inputs: no spill overhead
    # spill-file lifecycle: everything reclaimed by query end
    assert db.buffer_manager.active_files == 0


def test_exceeding_budget_completes(dataset):
    """The acceptance query: aggregate-join over data larger than the
    budget completes with spilling and matches the in-memory result."""
    fact, dim = dataset
    budget = 64 << 10
    assert sum(a.nbytes for a in fact.values()) > budget
    db = _build(dataset, budget)
    base = _build(dataset, None)
    q = lambda d: (d.scan("t")
                   .join(d.scan("d"), left_on="k", right_on="dk")
                   .group_by("k", "w")          # high-cardinality state
                   .agg(s=("sum", "v"), c=("count", None))
                   .order_by(("s", True))
                   .execute().to_pydict())
    _assert_identical(q(base), q(db), "agg-join-sort over budget")
    st = db.buffer_manager.stats
    assert st.spilled_ops >= 3          # join, group and sort all spilled
    assert st.peak <= budget
    assert db.buffer_manager.active_files == 0


def test_spill_dir_under_database_directory(tmp_path):
    """Persistent mode: run files live under <dbdir>/spill and are gone
    after the query; shutdown clears the directory."""
    rng = np.random.default_rng(1)
    db = startup(str(tmp_path / "db"), memory_budget=32 << 10)
    db.create_table("t", {"k": rng.integers(0, 1000, 20_000),
                          "v": rng.normal(size=20_000)})
    spill_dir = os.path.join(str(tmp_path / "db"), "spill")

    seen = {"files": 0}
    bm = db.buffer_manager
    orig = bm.new_spill_file

    def counting(hint="run"):
        seen["files"] += 1
        return orig(hint)

    bm.new_spill_file = counting
    res = (db.scan("t").group_by("k").agg(s=("sum", "v"))
           .execute().to_pydict())
    assert len(res["k"]) == 1000
    assert seen["files"] > 0, "expected the query to spill"
    assert os.path.isdir(spill_dir)
    assert os.listdir(spill_dir) == []       # reclaimed at query end
    db.shutdown()
    assert bm.active_files == 0


def test_memory_budget_api():
    db = startup()
    assert db.memory_budget is None and db.buffer_manager.budget is None
    db2 = startup(memory_budget=1 << 20)
    assert db2.memory_budget == 1 << 20
    with pytest.raises(ValueError):
        startup(memory_budget=0)


def test_sql_path_spills_identically(dataset):
    sql = ("SELECT k2, count(*) AS n, sum(v) AS s FROM t "
           "WHERE w > 0 GROUP BY k2, k ORDER BY s DESC")
    a = _build(dataset, None).connect().query(sql).to_pydict()
    db = _build(dataset, 32 << 10)
    b = db.connect().query(sql).to_pydict()
    for c in a:
        np.testing.assert_array_equal(a[c], b[c], err_msg=c)
    assert db.buffer_manager.stats.spilled_ops > 0


def test_volcano_spooled_aggregation(dataset):
    """The row-at-a-time baseline engine also honors the budget: grouping
    spools pickled row partitions and yields identical output."""
    from repro.core.optimizer import optimize
    from repro.core.volcano import VolcanoExecutor
    base = _build(dataset, None)
    db = _build(dataset, 32 << 10)
    plan = (db.scan("t").group_by("k")
            .agg(s=("sum", "v"), c=("count", None)).plan)
    rows_mem = VolcanoExecutor(base).execute(optimize(plan, base.catalog))
    spilled0 = db.buffer_manager.stats.bytes_spilled
    rows_ooc = VolcanoExecutor(db).execute(optimize(plan, db.catalog))
    assert rows_mem == rows_ooc
    assert db.buffer_manager.stats.bytes_spilled > spilled0
    assert db.buffer_manager.active_files == 0


def test_low_cardinality_group_stays_in_memory(dataset):
    """Grouping state for few distinct keys is tiny: the runtime probe must
    keep it in memory even when the *input* exceeds the budget (spilling
    could never split the dominant groups anyway)."""
    db = _build(dataset, 32 << 10)
    base = _build(dataset, None)
    q = lambda d: (d.scan("t").group_by("k2")
                   .agg(s=("sum", "v")).execute().to_pydict())
    _assert_identical(q(base), q(db), "low-card group")
    st = db.buffer_manager.stats
    assert st.spilled_ops == 0


def test_small_budget_peak_contract(dataset):
    """Sub-32KiB budgets must also hold peak <= budget (regression: the
    old 1024-row morsel/run floors pinned 24KiB regardless of budget)."""
    db = _build(dataset, 16 << 10)
    (db.scan("t").group_by("k", "w").agg(s=("sum", "v"))
     .order_by(("s", True)).execute())
    st = db.buffer_manager.stats
    assert st.spilled_ops >= 2
    assert st.peak <= 16 << 10, st.peak


@pytest.mark.outofcore
def test_sort_cascade_merge_bounded_fds():
    """More sort runs than the merge fan-in (regression: the merge once
    opened every run at once and hit EMFILE on large inputs): cascade
    passes must kick in and the result must stay identical."""
    from repro.core import spill
    rng = np.random.default_rng(11)
    n = 150_000
    vals = {"v": rng.normal(size=n), "k": rng.integers(0, 1000, n)}
    base = startup()
    base.create_table("t", vals)
    db = startup(memory_budget=32 << 10)
    db.create_table("t", vals)
    # 32 KiB budget, 16 B/row -> 1024-row runs -> ~147 runs > fan-in of 64
    assert n / ((32 << 10) // 2 // 16) > spill.SORT_MERGE_FAN_IN
    q = lambda d: (d.scan("t").order_by("v", ("k", True))
                   .select("v", "k").execute().to_pydict())
    _assert_identical(q(base), q(db), "cascade sort")
    assert db.buffer_manager.stats.spilled_ops == 1
    assert db.buffer_manager.active_files == 0


@pytest.mark.outofcore
@pytest.mark.slow
def test_stress_much_larger_than_budget():
    """~10 MB of blocking intermediates through a 1 MB budget."""
    rng = np.random.default_rng(7)
    n = 200_000
    fact = {"k": rng.integers(0, 20_000, n), "v": rng.normal(size=n),
            "w": rng.integers(0, 1_000_000, n)}
    budget = 1 << 20
    base = startup()
    base.create_table("t", fact)
    db = startup(memory_budget=budget)
    db.create_table("t", fact)
    q = lambda d: (d.scan("t").group_by("k")
                   .agg(s=("sum", "v"), mx=("max", "w"))
                   .order_by(("s", True), limit=500).execute().to_pydict())
    _assert_identical(q(base), q(db), "stress-agg")
    q2 = lambda d: (d.scan("t").order_by(("v", True), "w", limit=500)
                    .select("k", "v").execute().to_pydict())
    _assert_identical(q2(base), q2(db), "stress-sort")
    st = db.buffer_manager.stats
    assert st.spilled_ops >= 2
    assert st.peak <= budget
    assert db.buffer_manager.active_files == 0
